"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps
with the full substrate — prefetching data pipeline, donated jitted train
step, async checkpoints, fault-tolerant supervisor.

    PYTHONPATH=src python examples/train_lm.py            # full (~100M, 300 steps)
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized

Equivalent to: python -m repro.launch.train --arch paper-lm-100m ...
"""

import sys

sys.argv = [sys.argv[0]] + (
    ["--arch", "paper-lm-100m", "--steps", "20", "--batch", "2", "--seq", "64",
     "--reduced", "--ckpt-dir", "/tmp/repro_ckpt_quick"]
    if "--quick" in sys.argv[1:]
    else ["--arch", "paper-lm-100m", "--steps", "300", "--batch", "4",
          "--seq", "256", "--ckpt-dir", "/tmp/repro_ckpt",
          "--ckpt-every", "50"]
)

from repro.launch.train import main

main()
