"""The configuration wall, end to end: from the paper's simulated
accelerators to a live JAX serving loop.

1. §4.6 worked example — Gemmini's output-stationary matmul is configuration
   bound at 41.5% (theoretical) / 26.7% (effective BW) of peak.
2. Figure 11 — compiler passes buy ~2× on the concurrent-configuration
   target.
3. The same wall on a real runtime — single-token decode throughput vs
   tokens-per-launch (configuration hoisting raises I_OC k× and climbs the
   roofline).

    PYTHONPATH=src:. python examples/config_wall_demo.py
"""

from benchmarks import decode_config_wall, paper_figures
from repro.core import roofline as rl

print("=== 1. the wall, analytically (paper §4.6) ===")
bw_t, i_oc, util_t = rl.gemmini_example_theoretical()
bw_e, _, util_e = rl.gemmini_example_effective()
print(f"BW_config = {bw_t:.2f} B/cycle, I_OC = {i_oc:.1f} ops/B "
      f"-> {util_t*100:.1f}% of peak (paper: 41.49%)")
print(f"BW_eff    = {bw_e:.2f} B/cycle (bit-packing tax, Eq. 4) "
      f"-> {util_e*100:.1f}% of peak (paper: 26.78%)")

print("\n=== 2. the wall, eliminated by the compiler (Fig. 11) ===")
rows, geo = paper_figures.opengemm_sweep(sizes=(32, 64, 128))
for r in rows:
    print(f"K={r['size']:4d}: dedup {r['dedup_speedup']:.2f}x, "
          f"overlap {r['overlap_speedup']:.2f}x, both {r['both_speedup']:.2f}x")
print(f"geomean(both) = {geo['both']:.2f}x (paper: 1.99x)")

print("\n=== 3. the wall, live on the JAX runtime (decode) ===")
print("tokens/launch   us/token   tok/s")
for r in decode_config_wall.run(total_tokens=32, fuse_levels=(1, 4, 16)):
    print(f"{r['tokens_per_launch']:13d} {r['us_per_token']:10.1f} "
          f"{r['tok_per_s']:7.0f}")
print("\nFusing k steps into one launch amortizes one configuration over k")
print("macro-ops — I_OC rises x k, throughput climbs toward the compute roof.")
