"""Cluster quickstart: open-loop traffic over a multi-host pool in ~30 lines.

Builds a 12-tenant mix (two model-zoo architectures + anonymous bulk
tenants), synthesizes a bursty open-loop arrival stream, serves it on a
4-host Gemmini+OpenGeMM cluster with the config-affinity router, and prints
the tail-latency/SLO view plus each host's configuration-roofline point.

Run: ``PYTHONPATH=src python examples/cluster_quickstart.py``
"""

from repro.cluster import Cluster, TenantProfile, generate, slo_targets

profiles = [
    # decode-step tiles derived from the configs/ model zoo
    TenantProfile.from_arch("qwen", "qwen2-0.5b", accel="opengemm",
                            weight=3.0, slo_cycles=2_000.0),
    TenantProfile.from_arch("whisper", "whisper-medium", accel="gemmini",
                            weight=2.0, slo_cycles=4_000.0),
    # a latency-critical tenant that may preempt staged bulk launches
    TenantProfile("vip", dims=(8, 16, 16), accel="opengemm",
                  priority=2, slo_cycles=600.0),
] + [
    TenantProfile(f"bulk{i}", dims=(8, 16, 16),
                  accel="opengemm" if i % 2 else "gemmini")
    for i in range(9)
]

requests = generate(profiles, rate=1 / 45, horizon=100_000,
                    process="bursty", seed=42)
cluster = Cluster.uniform(4, {"gemmini": 1, "opengemm": 1}, policy="affinity")
report = cluster.run(requests, slo=slo_targets(profiles))

print(f"{report.launches} launches over {report.makespan:.0f} cycles, "
      f"{report.preemptions} preemptions")
print(f"config bytes sent {report.bytes_sent} "
      f"(elision ratio {report.elision_ratio:.2f})")
print(f"cluster p99 queue delay {report.queue_delay_percentile(99):.0f} cycles, "
      f"SLO attainment {report.attainment:.3f}, goodput "
      f"{report.goodput:.1f} ops/cycle")

print("\ntenant                p50q    p99q    p99lat  attain")
for t in report.tenants.values():
    if t.slo_cycles is not None:
        print(f"{t.tenant:<16} {t.p50_queue:>8.0f} {t.p99_queue:>8.0f} "
              f"{t.p99_latency:>8.0f} {t.attainment:>7.3f}")

print("\nper-host configuration roofline (serialized config port):")
for pt in report.roofline:
    print(f"{pt.name}: I_OC={pt.i_oc:.1f}, perf={pt.performance:.1f} ops/cyc, "
          f"BW_cfg={pt.bw_config:.2f} B/cyc, bound={pt.bound}")
