"""Fabric quickstart: link classes, burst-vs-MMIO transport, a warm tenant
migration, and cross-run context persistence in ~40 lines.

Warms a tenant with a large register context on a NoC-attached host, then
(1) shows the transport layer choosing burst DMA over per-register MMIO for
its write plans, (2) migrates the tenant to a second host via register-
snapshot hand-off and compares it against a cold resend, and (3) persists
the context through the checkpoint layer so a fresh "run" resumes warm.

Run: ``PYTHONPATH=src python examples/fabric_migration_quickstart.py``
"""

import tempfile

from repro.cluster import Host
from repro.core.accelerators import REGISTRY
from repro.fabric import (
    LINKS, ContextStore, MigrationPlanner, capture_contexts,
    install_contexts, plan_fields,
)
from repro.sched import LaunchRequest

# a tenant whose launches carry 24 static fields + one advancing pointer
def request(i):
    extra = {f"scale{j}": 3 * j for j in range(24)}
    extra["A"] = 0x1000 + 64 * i
    return LaunchRequest("llm-a", (8, 16, 16), extra, accel="gemmini")

# 1. transport: what does one launch's write plan cost on each link class?
gem = REGISTRY["gemmini"]
print("write plan of 28 registers, per link class:")
for name in ("csr", "noc", "pcie"):
    s = plan_fields(28, gem, LINKS[name])
    print(f"  {name:<5} -> {s.mode:<5} T_set={s.t_set:.0f} cycles "
          f"(host {s.host_cycles:.0f} + wire {s.link_cycles:.0f})")

# 2. migration: warm the source, then hand the register snapshot off
src = Host.from_registry("src", {"gemmini": 1}, link="noc")
for i in range(4):
    src.dispatch(request(i))
dst = Host.from_registry("dst", {"gemmini": 1}, link="noc")

planner = MigrationPlanner(link="noc")  # policy="auto"
probe = request(4)  # the tenant's next launch
est = planner.estimate("llm-a", src, dst, probe)
print(f"\nmigration estimate: warm {est.warm_cycles:.0f} vs cold "
      f"{est.cold_cycles:.0f} cycles -> {est.mode} "
      f"(context {est.context_fields} fields / {est.context_bytes} B; "
      f"first-launch port bytes {est.warm_port_bytes} vs {est.cold_port_bytes})")
rec = planner.migrate("llm-a", src, dst, probe, now=src.clock)
dev = dst.dispatch(probe)
print(f"executed: snapshot shipped in {rec.transfer.cycles:.0f} cycles, "
      f"first launch at dst was a context "
      f"{'hit' if dev.cache.stats.hits else 'miss'}")

# 3. persistence: the same warmth survives a restart
with tempfile.TemporaryDirectory() as ckpt_dir:
    ContextStore(ckpt_dir).save(1, capture_contexts(dst))
    fresh = Host.from_registry("dst", {"gemmini": 1}, link="noc")
    install_contexts(fresh, ContextStore(ckpt_dir).restore().values())
    d = fresh.dispatch(request(5))
    rec2 = d.telemetry.launch_log[-1]
    print(f"\nafter restart + restore: first launch sent "
          f"{rec2.bytes_sent} B of config (vs a cold "
          f"{(len(probe.regs_for(gem)) + 1) * gem.bytes_per_field} B)")
