"""Observability quickstart: trace a run, attribute every cycle, export.

Attaches one :class:`repro.obs.Tracer` to a 2-host overlapped cluster,
serves a small open-loop mix, then:

* prints the **cycle attribution** — each resource lane's makespan split
  into named components (exposed vs. overlapped config, captive wire time,
  stalls, compute, idle) under the conservation invariant (components sum
  to the makespan on every lane, residual ~0);
* prints the run's headline seven-way summary — the generalization of
  ``exposed_config_cycles`` the paper's characterization is built on;
* writes ``obs_trace.json`` — open it at https://ui.perfetto.dev or in
  ``chrome://tracing`` to see host / ``cfg[...]`` / ``compute[...]`` lanes
  per host, plus per-tenant launch lanes.

Run: ``PYTHONPATH=src python examples/obs_quickstart.py``
"""

from repro.cluster import Cluster, TenantProfile, generate, slo_targets
from repro.obs import Tracer, attribute, write_trace

profiles = [
    TenantProfile(f"t{i}", dims=(16, 16, 16),
                  accel="opengemm" if i % 2 else "gemmini",
                  slo_cycles=2_000.0)
    for i in range(6)
]
requests = generate(profiles, rate=1 / 40, horizon=40_000, seed=11)

tracer = Tracer()
cluster = Cluster.uniform(2, {"gemmini": 1, "opengemm": 1},
                          policy="affinity", link="noc",
                          overlap="overlapped", tracer=tracer)
report = cluster.run(requests, slo=slo_targets(profiles))

# -- cycle attribution: where did the makespan go, per resource lane --------
att = attribute(report).check()  # enforces conservation before printing
print(f"makespan {att.makespan:.0f} cycles, "
      f"worst lane residual {att.max_residual:.2e}")
print(f"{'lane':34s} {'kind':8s} busy%   components")
for name, lane in sorted(att.lanes.items()):
    comps = {k: round(v, 1) for k, v in lane.components.items() if v > 0.0}
    busy = 100.0 * lane.busy_cycles / lane.makespan
    print(f"{name:34s} {lane.kind:8s} {busy:5.1f}   {comps}")

print("\nrun summary (the seven-way generalization of exposed_config_cycles):")
for key, val in att.summary.items():
    print(f"  {key:20s} {val:12.1f}")
assert att.exposed_config == report.exposed_config_cycles

# -- unified metrics: one registry across every layer -----------------------
m = report.metrics
print(f"\nmetrics registry: {len(m)} series, e.g.")
print(f"  sched.bytes_sent (all hosts)  {m.total('sched.bytes_sent'):.0f}")
for host_id in report.hosts:
    print(f"  sched.exposed_config_cycles host={host_id}  "
          f"{m.total('sched.exposed_config_cycles', host=host_id):.1f}")
print(f"  cluster.latency p99  "
      f"{m.histogram('cluster.latency', tenant='t0').percentile(99):.1f}")

# -- export: Perfetto-loadable, attribution + metrics embedded --------------
doc = write_trace(tracer, "obs_trace.json", attribution=att, metrics=m)
print(f"\nwrote obs_trace.json ({len(doc['traceEvents'])} events) — "
      f"load it at https://ui.perfetto.dev")
