"""Energy quickstart: meter a run, attribute its joules, cap the pool,
re-run, and diff the two in joules.

The loop the energy stack is meant to close:

1. attach a :class:`repro.power.PowerSpec` and run a cluster — every
   engine resource now carries an :class:`EnergyModel`, every fabric
   transfer is priced in pJ at plan time;
2. :func:`repro.power.attribute_energy` splits each lane's joules into
   components under the conservation invariant (residual ≤ 0.1%, the
   same bar the cycle attribution holds);
3. place the run on the *energy roofline* — ops/pJ against ops per
   config byte, ridge at ``peak_ops_per_joule / bw_e``;
4. re-run the same request stream under a watt budget
   (:func:`repro.cluster.powercap.run_power_capped`) and read off what
   the cap cost — in cycles (queueing delay) *and* joules.

Run: ``PYTHONPATH=src python examples/energy_quickstart.py``
"""

from repro.cluster import Cluster
from repro.cluster.powercap import run_power_capped
from repro.core.roofline import energy_roofline_point
from repro.power import PowerSpec, attribute_energy, max_window_energy
from repro.sched import LaunchRequest

WINDOW = 1024.0  # cycles per power-enforcement window

requests = [
    LaunchRequest(f"t{i % 3}", (8, 16, 16),
                  {f"f{j}": 96 * i + j for j in range(10)},
                  accel="opengemm" if i % 2 else "gemmini",
                  arrival_time=12.0 * i)
    for i in range(48)
]


def pool():
    return Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                           power=PowerSpec.default())


# -- 1. meter: run with a power spec attached --------------------------------
cluster = pool()
report = cluster.run(list(requests))

# -- 2. attribute: conservation-checked joules per lane ----------------------
energy = attribute_energy(report).check()  # raises if any lane drifts >0.1%
print(f"total {energy.total_energy:.0f} pJ over {energy.makespan:.0f} cycles "
      f"(mean draw {energy.mean_power:.2f} pJ/cycle)")
for name, lane in sorted(energy.lanes.items()):
    parts = ", ".join(f"{k} {v:.0f}" for k, v in sorted(lane.components.items())
                      if v > 0.0)
    print(f"  {name:<22} {lane.total:9.0f} pJ  [{parts}]")
config_share = energy.summary["config_energy"] / energy.total_energy
print(f"configuration burns {config_share:.0%} of the pool's joules\n")

# -- 3. the energy roofline: where does this run sit? ------------------------
ops = sum(r.ops for r in report.records)
nbytes = sum(r.bytes_sent for r in report.records)
pt = energy_roofline_point(
    "quickstart", total_ops=ops, config_bytes=max(nbytes, 1),
    config_energy=energy.summary["config_energy"],
    total_energy=energy.total_energy,
    compute_power=1.0, p_peak=2.0)
print(f"energy roofline: I_OC {pt.i_oc:.0f} ops/byte, ridge {pt.ridge:.0f} "
      f"-> {pt.energy_bound}-energy-bound "
      f"({pt.efficiency:.3f} of {pt.attainable:.3f} attainable ops/pJ)\n")

# -- 4. cap: same stream under 70% of the uncapped peak ----------------------
peak, _ = max_window_energy(cluster.hosts, WINDOW)
budget = 0.7 * peak / WINDOW

capped_cluster = pool()
capped_report, cap = run_power_capped(
    capped_cluster, list(requests), budget_power=budget, window=WINDOW)
capped_energy = attribute_energy(capped_report).check()

print(f"cap at {budget:.1f} pJ/cycle (70% of peak {peak / WINDOW:.1f}): "
      f"held={cap.held}, {cap.delayed} admissions delayed "
      f"(p50 {cap.p50_delay:.0f} cycles)")

# -- 5. diff in joules: what did the watt budget cost? -----------------------
d_makespan = capped_report.makespan - report.makespan
d_joules = capped_energy.total_energy - energy.total_energy
d_idle = capped_energy.summary["idle_energy"] - energy.summary["idle_energy"]
print(f"diff: makespan {d_makespan:+.0f} cycles, total {d_joules:+.0f} pJ "
      f"(idle {d_idle:+.0f} pJ — a stretched run idles longer), "
      f"worst window {cap.max_window_power:.1f} vs uncapped "
      f"{peak / WINDOW:.1f} pJ/cycle")

assert cap.held
assert cap.max_window_power <= budget + 1e-9
