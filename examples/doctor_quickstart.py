"""Doctor quickstart: diagnose a config-bound run, apply the top
recommendation, re-run, and diff.

The loop every recommendation is meant to close:

1. run a serialized scheduler and let :func:`repro.obs.diagnose.diagnose`
   classify it (config-bound) and *price* its mitigations by replaying
   the recorded launch log with one knob flipped;
2. apply the top recommendation's ``knob`` — literally splat it into the
   scheduler constructor — and re-run the same stream;
3. check the prediction against reality and decompose the win per lane
   with :func:`repro.obs.diff.diff`.

Run: ``PYTHONPATH=src python examples/doctor_quickstart.py``
"""

from repro.obs import attribute, diagnose_report
from repro.obs.diff import diff, render
from repro.sched import LaunchRequest, Scheduler

requests = [
    LaunchRequest(f"t{i % 3}", (16, 16, 16),
                  {f"f{j}": 96 * i + j for j in range(24)},
                  accel="opengemm" if i % 2 else "gemmini")
    for i in range(14)
]


def run(**knobs):
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1}, link="noc",
                                **knobs)
    return s.run_open_loop(list(requests))


# -- 1. diagnose the serialized run -----------------------------------------
before = run(overlap="serialized")
diag = diagnose_report(before)
print(diag.render())

top = diag.recommendations[0]
assert top.predicted_savings is not None and top.knob, top

# -- 2. apply the top recommendation's knob and re-run ----------------------
print(f"\napplying {top.action}: Scheduler(..., "
      f"{', '.join(f'{k}={v!r}' for k, v in top.knob.items())})")
after = run(**top.knob)

actual = before.makespan - after.makespan
err = abs(top.predicted_savings - actual) / actual if actual else 0.0
print(f"predicted savings {top.predicted_savings:.1f} cycles, "
      f"actual {actual:.1f} ({err:.1%} error — the tests pin ≤ 15%)")

# -- 3. decompose the win per lane ------------------------------------------
print()
print(render(diff(attribute(before), attribute(after))))
