"""Serving-bridge quickstart: real decode descriptors drive the cluster.

Three tiny `ServingEngine` tenants (one shared compiled decode step) run
*closed-loop* against a 2-host cluster over a NoC config fabric: every
continuous-batching step's descriptor becomes a cluster launch, and each
tenant's next step is released only when its previous one retires —
queueing delay throttles token throughput, instead of just fattening a
percentile as in the open-loop ``cluster_quickstart``.

The engines run in their default **fused-sampling** mode: the decode
launch samples on-device and keeps the ids device-resident, so the
steady-state descriptor is ``{positions}`` plus elided residents (no
``tokens`` leaf), and the per-step sync the driver prices on the feedback
edge is a few id bytes instead of the full logits. Admission goes through
masked **chunked prefill** launches (``prefill_chunk`` tokens per launch).

Run: ``PYTHONPATH=src python examples/serving_bridge_quickstart.py``
"""

import dataclasses

import jax

from repro.bridge import ClosedLoopDriver, TenantEngine
from repro.cluster import Cluster
from repro.configs import get
from repro.models.model import Model
from repro.serving import Request, ServingEngine

cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), remat="none")
model = Model(cfg)
params = model.init(jax.random.key(0))
# one JIT each for decode (fused sampling) and prefill, shared by all tenants
decode = ServingEngine.compile_decode(model)
prefill = ServingEngine.compile_prefill(model)

tenants = []
for i in range(3):
    engine = ServingEngine(model, params, max_slots=4, max_len=64,
                           decode_fn=decode, prefill_fn=prefill)
    for uid, prompt in enumerate([[3 + i, 5, 2], [7, 1 + i]]):
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
    tenants.append(TenantEngine(f"t{i}", engine, accel="opengemm",
                                slo_cycles=2_000.0))

# sticky=True: each tenant's decode launches bind to the host holding its
# KV cache (slot residency) — the home device's config cache stays warm
cluster = Cluster.uniform(2, {"opengemm": 1}, policy="affinity",
                          sticky=True, link="noc")
report = ClosedLoopDriver(tenants, cluster).run()

print(f"{report.tokens} tokens over {report.cluster.makespan:.0f} cycles "
      f"= {report.tokens_per_kcycle:.1f} tokens/kcycle "
      f"({report.cluster.launches} launches, elision ratio "
      f"{report.cluster.elision_ratio:.2f})")

print("\ntenant   tokens  p50dec  p99dec  home")
for name, s in sorted(report.serving.items()):
    home = cluster.router.home(name)
    print(f"{name:<8} {s.tokens:>6} {s.p50_decode:>7.0f} {s.p99_decode:>7.0f}"
          f"  {home.id if home else '-'}")

print("\nper-step descriptor bytes for t0 (sent / elided):")
for arrival, sent, elided in report.step_timeline("t0")[:5]:
    print(f"  cycle {arrival:>6.0f}: {sent:>4} sent, {elided:>4} elided")
print("  (cold full send on step 1, then only the positions delta — fused"
      "\n   sampling keeps token ids on-device, so no tokens leaf at all)")

print("\ntime-to-first-token (admission prefill chain + first decode):")
for name, ttft in sorted(report.ttft_cycles().items()):
    print(f"  {name}: {ttft:.0f} cycles")

print("\nengine↔cluster config-byte accounting parity:")
for name, p in report.config_parity().items():
    print(f"  {name}: cluster {p['cluster_bytes_sent']:.0f}B sent "
          f"vs expected {p['expected_bytes_sent']:.0f}B — "
          f"{'MATCH' if p['matched'] else 'MISMATCH'}")

print("\nserving configuration-roofline points (token work / descriptor bytes):")
for pt in report.serving_roofline():
    print(f"  {pt.name}: I_OC={pt.i_oc:.0f}, perf={pt.performance:.1f} "
          f"ops/cyc, bound={pt.bound}")
