"""Batched serving example: the configuration wall at the dispatch layer.

Runs the same decode workload three ways and prints the throughput ladder:

  sequential   block per token, full descriptor per launch   (the wall)
  concurrent   async dispatch + deduped descriptors          (overlap+dedup)
  fused        k tokens per launch via on-device loop        (config hoisting)

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen2-0.5b]
"""

import subprocess
import sys

arch = "qwen2-0.5b"
if "--arch" in sys.argv:
    arch = sys.argv[sys.argv.index("--arch") + 1]

for mode in ("sequential", "concurrent", "fused"):
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
         "--reduced", "--batch", "4", "--steps", "48", "--mode", mode],
        check=True,
    )
