"""Autotune quickstart: from calibrated compute predictions to picked knobs.

1. Load the committed calibration (`engine/calibration.json` — fitted once
   against the real Pallas kernels, deterministic ever after) and predict
   a few kernel shapes' compute cycles.
2. Ask the autotuner for overlap knobs per link class and print which
   decision-table row fired.
3. Run the same launch stream under default and autotuned knobs and show
   the makespan delta, plus the model-predicted roofline placement.

Run: ``PYTHONPATH=src python examples/autotune_quickstart.py``
"""

from repro.core.accelerators import REGISTRY
from repro.core.roofline import predicted_roofline_point
from repro.engine import ComputeModel, tune
from repro.sched import LaunchRequest, Scheduler

model = REGISTRY["opengemm"]
cm = ComputeModel.calibrated()

# 1. shape-aware compute predictions (vs the flat per-launch constant)
flat = ComputeModel.flat()
print("predicted compute cycles (calibrated vs flat constant):")
for kernel, dims in [("decode", (4, 128, 512)),
                     ("prefill", (32, 128, 512)),
                     ("matmul", (256, 256, 256))]:
    regs = dict(zip(model.dim_fields, dims))
    print(f"  {kernel:>8} {str(dims):>16}: "
          f"{cm.macro_cycles(model, regs, kernel):>10.0f}  vs  "
          f"{flat.macro_cycles(model, regs):>8.0f}")

# 2. knobs per link class — the decision table in action
N_FIELDS = 48
dims = (16, 16, 16)
print(f"\nautotuned knobs for {dims} GEMMs, {N_FIELDS} fields/launch:")
for link in ("csr", "noc", "pcie"):
    k = tune(model, link, dims, N_FIELDS, compute_model=cm)
    print(f"  {link:>4}: {k.overlap}/{k.staging_buffers} "
          f"(wire/compute {k.ratio:.2f}) — {k.reason}")

# 3. default vs autotuned knobs on a PCIe host, same stream
reqs = [LaunchRequest("t0", dims,
                      {f"p{j}": 64 * i + j for j in range(N_FIELDS)})
        for i in range(24)]
knobs = tune(model, "pcie", dims, N_FIELDS, compute_model=cm)


def makespan(**kw) -> float:
    s = Scheduler.from_registry({"opengemm": 1}, link="pcie",
                                compute_model="calibrated", **kw)
    return s.run(list(reqs)).makespan


default = makespan()  # serialized / 2 buffers
tuned = makespan(**knobs.scheduler_kwargs())
print(f"\npcie makespan: default {default:.0f} → autotuned {tuned:.0f} "
      f"cycles ({default / tuned:.2f}x)")

# model-predicted roofline placement — before any launch ran
point = predicted_roofline_point(
    "pcie/decode", ops=2 * dims[0] * dims[1] * dims[2],
    config_bytes=N_FIELDS * model.bytes_per_field,
    compute_cycles=knobs.compute_cycles,
    config_cycles=knobs.wire_cycles,
    p_peak=model.p_peak, concurrent=model.concurrent)
print(f"predicted roofline: I_OC {point.i_oc:.1f}, "
      f"{point.performance:.0f} ops/cycle — {point.bound}-bound")
