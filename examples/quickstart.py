"""Quickstart: the configuration wall in 60 seconds.

Builds the paper's tiled-matmul workload as accfg IR, runs the optimization
pipeline (state tracing → dedup → overlap), executes both versions on the
cycle-approximate OpenGeMM model, and places the measurements on the
configuration roofline.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import accelerators, evaluate_levels, ir, matmul_driver, speedup, timeline
from repro.core.roofline import knee_point

K = 64
models = {"opengemm": accelerators.opengemm_like()}

print(f"=== tiled {K}x{K}x{K} int8 matmul on an OpenGeMM-class accelerator ===\n")

module = matmul_driver.opengemm_tiled_matmul(K)
print("Raw accfg IR (first tile's configuration):")
print("\n".join(ir.print_module(module).splitlines()[:26]))

results = evaluate_levels(lambda: matmul_driver.opengemm_tiled_matmul(K), models)

print(f"\n{'level':10s} {'cycles':>10s} {'ops/cycle':>10s} {'I_OC':>8s} {'bound':>14s}")
for level, r in results.items():
    p = r.point
    print(f"{level:10s} {r.trace.total_cycles:10.0f} {p.performance:10.1f} "
          f"{p.i_oc:8.1f} {p.bound:>14s}")

print("\nFigure-2 timelines ('#' accelerator busy, '.' idle while configuring):")
print(timeline.compare({lvl: r.trace for lvl, r in results.items()}, width=64))

acc = models["opengemm"]
print(f"\nknee point I_OC = {knee_point(acc.p_peak, acc.bw_config):.1f} ops/byte")
print(f"dedup speedup   = {speedup(results, 'dedup'):.2f}x")
print(f"overlap speedup = {speedup(results, 'overlap'):.2f}x")
print(f"both            = {speedup(results, 'both'):.2f}x   (paper: ~2x geomean)")
print("\nInvocation logs verified identical across all levels — the optimized")
print("programs configure the accelerator to exactly the same register states.")
