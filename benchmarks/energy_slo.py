"""Energy SLO benchmark: serving under a watt budget.

The paper's per-Watt motivation cuts both ways: configuration overhead
burns joules a power-provisioned pool cannot spend. This bench sweeps an
open-loop tenant mix (config-bound decode-step tiles, the same regime as
``cluster_slo``) across arrival rates on a 2-host NoC pool with the
default :class:`~repro.power.model.PowerSpec` attached, and runs every
load cell twice:

* **uncapped** — the ordinary :meth:`Cluster.run` drain; its worst
  windowed pool power (``max_window_energy`` over the committed engine
  logs) defines the cell's unconstrained peak.
* **capped** — :func:`~repro.cluster.powercap.run_power_capped` at
  ``BUDGET_FRAC`` × that peak, with a :class:`PowerCapTrigger` shedding
  the hottest host through the warm-migration planner. Admission delay
  holds the pool under the watt budget in *every* window (asserted by the
  cap itself, re-asserted here, and gated in CI by ``doctor_gate.py``
  over the emitted artifact).

Per cell the artifact records SLO attainment, queueing percentiles,
tokens/joule (a launch's M rows are its decode-step tokens), the energy
attribution summary, and the cap's own accounting (delays, sheds, worst
window) — the quantified cost of the watt budget is the attainment and
p99-queue gap between the two runs of the same request stream.

Acceptance (asserted below, ISSUE 8): every capped cell holds its budget
in every window, and the cap is *binding* (it delayed admissions in at
least one cell — zero-cost caps quantify nothing).

Usage: ``PYTHONPATH=src python benchmarks/energy_slo.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.cluster import Cluster, TenantProfile, generate, slo_targets
from repro.cluster.powercap import PowerCapTrigger, run_power_capped
from repro.fabric.migrate import MigrationPlanner
from repro.obs.monitor import StreamMonitor
from repro.power import PowerSpec, attribute_energy, max_window_energy
from repro.sched import geomean

# Small decode-step tiles (2·8·16·16 ops/launch): T_set rivals the
# macro-op, the config-bound regime where joules track the wall
TILE = (8, 16, 16)
TOKENS_PER_LAUNCH = TILE[0]  # a decode GEMM's M rows = batch tokens
POOL = {"gemmini": 1, "opengemm": 1}
WINDOW = 2048.0  # cycles per power-enforcement window
BUDGET_FRAC = 0.7  # capped budget as a fraction of the uncapped peak


def tenant_mix() -> list[TenantProfile]:
    profiles: list[TenantProfile] = []
    for i in range(4):
        profiles.append(TenantProfile(
            f"og{i}", dims=TILE, accel="opengemm",
            weight=2.0 if i == 0 else 1.0, slo_cycles=600.0))
    for i in range(4):
        profiles.append(TenantProfile(
            f"gem{i}", dims=TILE, accel="gemmini",
            weight=2.0 if i == 0 else 1.0, slo_cycles=1200.0))
    return profiles


def _pool(n_hosts: int, tracer=None) -> Cluster:
    return Cluster.uniform(n_hosts, dict(POOL), policy="affinity",
                           link="noc", power=PowerSpec.default(),
                           tracer=tracer)


def _measure(rep, cluster: Cluster) -> dict:
    """The shared per-run scorecard: serving stats + joule attribution
    (conservation-checked) + the worst windowed pool power."""
    er = attribute_energy(rep).check()
    tokens = rep.launches * TOKENS_PER_LAUNCH
    worst, at = max_window_energy(cluster.hosts, WINDOW)
    return {
        "launches": rep.launches,
        "makespan": rep.makespan,
        "p50_queue_delay": rep.queue_delay_percentile(50),
        "p99_queue_delay": rep.queue_delay_percentile(99),
        "p99_latency": rep.latency_percentile(99),
        "slo_attainment": rep.attainment,
        "tokens": tokens,
        "total_energy": er.total_energy,
        "mean_power": er.mean_power,
        "tokens_per_joule": er.tokens_per_joule(tokens),
        "config_energy": er.summary["config_energy"],
        "config_energy_share": (er.summary["config_energy"]
                                / er.total_energy if er.total_energy else 0.0),
        "idle_energy": er.summary["idle_energy"],
        "wake_energy": er.summary["wake_energy"],
        "peak_window_power": worst / WINDOW,
        "peak_window_at": at,
    }


def run_cell(requests, profiles, *, n_hosts: int) -> dict:
    slo = slo_targets(profiles)

    uncapped_cluster = _pool(n_hosts)
    uncapped_rep = uncapped_cluster.run(list(requests), slo=slo)
    uncapped = _measure(uncapped_rep, uncapped_cluster)

    budget = BUDGET_FRAC * uncapped["peak_window_power"]
    capped_cluster = _pool(n_hosts)
    trigger = PowerCapTrigger(
        MigrationPlanner(link="noc", policy="warm"),
        budget_power=budget, window=WINDOW,
        monitor=StreamMonitor(window=WINDOW))
    capped_rep, cap = run_power_capped(
        capped_cluster, list(requests), budget_power=budget, window=WINDOW,
        slo=slo, trigger=trigger)
    capped = _measure(capped_rep, capped_cluster)
    capped["cap"] = cap.to_dict()
    assert cap.held, "power cap violated (run_power_capped must assert first)"
    assert capped["peak_window_power"] <= budget + 1e-9

    return {
        "budget_power": budget,
        "uncapped": uncapped,
        "capped": capped,
        # the quantified cost of the watt budget, same request stream
        "slo_cost": uncapped["slo_attainment"] - capped["slo_attainment"],
        "p99_queue_cost": (capped["p99_queue_delay"]
                           - uncapped["p99_queue_delay"]),
    }


def run(smoke: bool = False) -> dict:
    profiles = tenant_mix()
    horizon = 24_000.0 if smoke else 60_000.0
    rates = [1 / 48, 1 / 14] if smoke else [1 / 48, 1 / 24, 1 / 14]
    cells = []
    for rate in rates:
        requests = generate(profiles, rate=rate, horizon=horizon, seed=11)
        cell = {"rate": rate, "interarrival_cycles": 1 / rate,
                "hosts": 2, "requests": len(requests)}
        cell.update(run_cell(requests, profiles, n_hosts=2))
        cells.append(cell)
    return {
        "benchmark": "energy_slo",
        "pool_per_host": dict(POOL),
        "tile": list(TILE),
        "window_cycles": WINDOW,
        "budget_frac": BUDGET_FRAC,
        "tenants": len(profiles),
        "horizon_cycles": horizon,
        "smoke": smoke,
        "cells": cells,
        # cross-cell summary (CI requires every BENCH_*.json to carry one;
        # every key is higher-is-better for the geomean floor gate)
        "geomean": {
            "uncapped_tokens_per_joule": geomean(
                [c["uncapped"]["tokens_per_joule"] for c in cells]),
            "capped_tokens_per_joule": geomean(
                [c["capped"]["tokens_per_joule"] for c in cells]),
            "capped_attainment": geomean(
                [max(c["capped"]["slo_attainment"], 1e-9) for c in cells]),
            "peak_power_reduction": geomean(
                [c["uncapped"]["peak_window_power"]
                 / max(c["capped"]["peak_window_power"], 1e-9)
                 for c in cells]),
        },
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Re-run one representative *capped* cell instrumented and export the
    trace with both conservation-checked attributions (cycles and joules)
    plus ``power[...]`` counter tracks embedded."""
    profiles = tenant_mix()
    horizon = 24_000.0 if smoke else 60_000.0
    requests = generate(profiles, rate=1 / 14, horizon=horizon, seed=11)
    slo = slo_targets(profiles)

    probe = _pool(2)
    probe_rep = probe.run(list(requests), slo=slo)
    budget = BUDGET_FRAC * _measure(probe_rep, probe)["peak_window_power"]

    def scenario(tracer):
        cluster = _pool(2, tracer=tracer)
        rep, _cap = run_power_capped(cluster, list(requests),
                                     budget_power=budget, window=WINDOW,
                                     slo=slo)
        return rep

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small horizon / fewer cells (CI time budget)")
    ap.add_argument("--out", default="BENCH_energy_slo.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented capped cell (power counter tracks "
                         "+ embedded energy attribution)")
    args = ap.parse_args()

    result = run(smoke=args.smoke)
    print(f"# energy SLO sweep: {result['tenants']} tenants, "
          f"tile {tuple(result['tile'])}, window {WINDOW:.0f} cycles, "
          f"budget {BUDGET_FRAC:.0%} of uncapped peak")
    print("rate,mode,attainment,p99_queue,tokens_per_joule,peak_power,held")
    for cell in result["cells"]:
        for mode in ("uncapped", "capped"):
            c = cell[mode]
            held = c.get("cap", {}).get("held", "-")
            print(f"1/{cell['interarrival_cycles']:.0f},{mode},"
                  f"{c['slo_attainment']:.3f},{c['p99_queue_delay']:.0f},"
                  f"{c['tokens_per_joule']:.3e},"
                  f"{c['peak_window_power']:.1f},{held}")
        print(f"  -> budget {cell['budget_power']:.1f} pJ/cycle, "
              f"slo_cost {cell['slo_cost']:+.3f}, "
              f"p99_queue_cost {cell['p99_queue_cost']:+.0f} cycles, "
              f"delayed {cell['capped']['cap']['delayed']}, "
              f"sheds {cell['capped']['cap']['sheds']}")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 8): the capped pool holds the watt budget in every
    # window of every cell, and the cap is binding somewhere — otherwise
    # the reported SLO cost quantifies nothing
    for cell in result["cells"]:
        cap = cell["capped"]["cap"]
        assert cap["held"], (
            f"cell 1/{cell['interarrival_cycles']:.0f}: worst window "
            f"{cap['max_window_power']:.1f} pJ/cycle exceeds budget "
            f"{cell['budget_power']:.1f}")
        assert (cell["capped"]["peak_window_power"]
                <= cell["budget_power"] + 1e-9)
    assert any(c["capped"]["cap"]["delayed"] > 0 for c in result["cells"]), (
        "acceptance: the cap never delayed an admission — budget not binding")


if __name__ == "__main__":
    main()
