"""Benchmark harness — one entry per paper table/figure plus the
framework-level configuration-wall benchmarks.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call is simulated
cycles for the paper-figure benches, wall-clock microseconds for the runtime
benches; ``derived`` is the headline metric of that table).
"""

from __future__ import annotations

import argparse


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str) -> None:
    """Write a Perfetto/chrome-trace JSON of one small instrumented
    mixed-pool scheduler run (NoC fabric, overlapped staging), with its
    conservation-checked cycle attribution embedded."""
    from repro.sched import LaunchRequest, Scheduler

    def scenario(tracer):
        s = Scheduler.from_registry({"gemmini": 1, "opengemm": 1},
                                    link="noc", overlap="overlapped",
                                    tracer=tracer)
        reqs = [
            LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": 64 * i + j for j in range(16)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=40.0 * i)
            for i in range(12)
        ]
        return s.run_open_loop(reqs)

    _export(path, scenario)


def main() -> None:
    from benchmarks import decode_config_wall, dispatch_overlap, paper_figures

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of a "
                         "small instrumented scheduler run")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    # Figure 10 — Gemmini sequential-configuration sweep
    rows, g = paper_figures.gemmini_sweep()
    for r in rows:
        print(f"fig10_gemmini_k{r['size']},{r['base_cycles']:.0f},"
              f"speedup={r['speedup']:.3f}")
    print(f"fig10_gemmini_geomean,0,geomean={g:.3f}(paper=1.105)")

    # Figure 11 — OpenGeMM concurrent-configuration sweep
    rows, geo = paper_figures.opengemm_sweep()
    for r in rows:
        print(f"fig11_opengemm_k{r['size']},{r['base_cycles']:.0f},"
              f"both={r['both_speedup']:.3f}")
    print(f"fig11_opengemm_geomean,0,geomean={geo['both']:.3f}(paper=1.99)")

    # Figure 12 — roofline placement
    for r in paper_figures.roofline_placement(sizes=(64, 128)):
        print(f"fig12_place_k{r['size']}_{r['level']},"
              f"{r['perf_ops_per_cycle']:.1f},i_oc={r['i_oc']:.1f};{r['bound']}")

    # §4.6 worked example
    from repro.core import roofline as rl
    _, _, util_t = rl.gemmini_example_theoretical()
    _, _, util_e = rl.gemmini_example_effective()
    print(f"sec4.6_worked_theoretical,0,util={util_t*100:.2f}%(paper=41.49%)")
    print(f"sec4.6_worked_effective,0,util={util_e*100:.2f}%(paper=26.78%)")

    # dispatch overlap (wall clock, real runtime)
    r = dispatch_overlap.run(n_steps=20)
    print(f"dispatch_sequential,{r['sequential_s']/20*1e6:.0f},steps=20")
    print(f"dispatch_concurrent,{r['concurrent_s']/20*1e6:.0f},"
          f"overlap_speedup={r['overlap_speedup']:.2f}")
    print(f"dispatch_dedup,0,i_oc_gain={r['dedup_i_oc_gain']:.1f}x"
          f"({r['dedup_bytes_baseline']}B->{r['dedup_bytes_dynamic']}B)")

    # decode config wall (tokens per launch)
    for row in decode_config_wall.run(total_tokens=32, fuse_levels=(1, 4, 16)):
        print(f"decode_wall_k{row['tokens_per_launch']},"
              f"{row['us_per_token']:.1f},tok_per_s={row['tok_per_s']:.0f}")

    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
