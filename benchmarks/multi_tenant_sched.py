"""Multi-tenant scheduling benchmark: the configuration wall at the pool level.

Six tenants each run the paper's tiled-matmul workload (§6) — compiled accfg
programs whose invocation logs are replayed into `repro.sched` as concurrent
launch streams onto a mixed two-device pool (one Gemmini-style sequential
device + one OpenGeMM-style concurrent device, the paper's two design
points).

Two runtimes face the same stream:

* **naive** — round-robin placement, no configuration-state cache: every
  launch re-sends its full register file, the runtime configuration wall.
* **sched** — config-affinity placement + per-tenant state caching + depth-k
  staged launches: only register deltas cross the host→device boundary.

Reported: config bytes sent (the acceptance bar is ≥ 1.5× reduction),
per-device and geomean utilization, cache hit rate, Figure-2-style timelines
via ``timeline.compare`` and per-device configuration-roofline placements.
"""

from __future__ import annotations

import argparse

from repro.core import accelerators, matmul_driver, timeline
from repro.core.interp import run as interp_run
from repro.core.passes import baseline
from repro.sched import LaunchRequest, Scheduler, requests_from_trace

try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export

MODELS = {
    "gemmini": accelerators.gemmini_like(),
    "opengemm": accelerators.opengemm_like(),
}


def tenant_streams() -> dict[str, list[LaunchRequest]]:
    """Each tenant compiles its own tiled matmul; the invocation log (the
    interpreter's observable) becomes the tenant's launch stream."""
    streams: dict[str, list[LaunchRequest]] = {}
    for t in range(3):
        module = matmul_driver.gemmini_tiled_matmul(128, max_tile=64)
        baseline(module)
        trace = interp_run(module, MODELS)
        streams[f"gem-tenant{t}"] = requests_from_trace(trace, f"gem-tenant{t}")
    for t in range(3):
        module = matmul_driver.opengemm_tiled_matmul(32)
        baseline(module)
        trace = interp_run(module, MODELS)
        streams[f"og-tenant{t}"] = requests_from_trace(trace, f"og-tenant{t}")
    return streams


def interleave(streams: dict[str, list[LaunchRequest]]) -> list[LaunchRequest]:
    """Round-robin arrival order across tenants (concurrent streams)."""
    out: list[LaunchRequest] = []
    queues = {t: list(reqs) for t, reqs in streams.items()}
    while any(queues.values()):
        for t, q in queues.items():
            if q:
                out.append(q.pop(0))
    return out


def run(depth: int = 2, max_contexts: int = 4) -> dict:
    requests = interleave(tenant_streams())
    pool = {"gemmini:0": MODELS["gemmini"], "opengemm:0": MODELS["opengemm"]}

    naive = Scheduler(dict(pool), policy="round_robin", cache_enabled=False,
                      depth=depth, max_contexts=max_contexts)
    rep_naive = naive.run(list(requests))

    sched = Scheduler(dict(pool), policy="affinity", cache_enabled=True,
                      depth=depth, max_contexts=max_contexts)
    rep_sched = sched.run(list(requests))

    reduction = rep_naive.bytes_sent / max(rep_sched.bytes_sent, 1)
    return {
        "requests": len(requests),
        "naive": rep_naive,
        "sched": rep_sched,
        "config_bytes_naive": rep_naive.bytes_sent,
        "config_bytes_sched": rep_sched.bytes_sent,
        "config_bytes_reduction": reduction,
        "cache_hit_rate": rep_sched.hit_rate(),
        "geomean_util_naive": rep_naive.geomean_utilization(),
        "geomean_util_sched": rep_sched.geomean_utilization(),
        "makespan_naive": rep_naive.makespan,
        "makespan_sched": rep_sched.makespan,
    }


def export_trace(path: str) -> None:
    """Re-run the cached-affinity configuration instrumented: six compiled
    tenant streams interleaved onto the mixed pool, with the cycle
    attribution and metrics registry embedded in the exported trace."""
    requests = interleave(tenant_streams())
    pool = {"gemmini:0": MODELS["gemmini"], "opengemm:0": MODELS["opengemm"]}

    def scenario(tracer):
        sched = Scheduler(dict(pool), policy="affinity", cache_enabled=True,
                          tracer=tracer)
        return sched.run(list(requests))

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None,
                    help="also export an instrumented trace of the cached "
                         "affinity configuration to this path")
    args = ap.parse_args()
    r = run()
    naive, sched = r["naive"], r["sched"]
    print("# multi-tenant scheduling on {gemmini, opengemm} pool "
          f"({r['requests']} launches, 6 tenants)")
    print(f"config_bytes_naive,{r['config_bytes_naive']}")
    print(f"config_bytes_sched,{r['config_bytes_sched']}")
    print(f"config_bytes_reduction,{r['config_bytes_reduction']:.2f}x")
    print(f"cache_hit_rate,{r['cache_hit_rate']:.3f}")
    print(f"makespan_naive,{r['makespan_naive']:.0f}")
    print(f"makespan_sched,{r['makespan_sched']:.0f}")
    print(f"geomean_util_naive,{r['geomean_util_naive']:.4f}")
    print(f"geomean_util_sched,{r['geomean_util_sched']:.4f}")
    print()
    print("## timelines (naive round-robin, no state cache)")
    print(timeline.compare(naive.traces(), width=64))
    print("## timelines (affinity + config-state cache)")
    print(timeline.compare(sched.traces(), width=64))
    print()
    print("## configuration-roofline placement (per device)")
    for rep, tag in ((naive, "naive"), (sched, "sched")):
        for pt in rep.roofline_points():
            print(f"{tag},{pt.name},I_OC={pt.i_oc:.1f},perf={pt.performance:.1f}"
                  f",bound={pt.bound},util={pt.utilization:.3f}")
    assert r["config_bytes_reduction"] >= 1.5, "acceptance: >=1.5x byte reduction"
    assert r["geomean_util_sched"] > r["geomean_util_naive"], (
        "acceptance: higher geomean utilization"
    )
    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
