"""CI gate: the config-wall doctor must classify the overlap sweep right.

``BENCH_config_overlap.json`` is the repo's cleanest ground truth about
the configuration wall: every serialized cell keeps the host captive
through its transfers (the paper's Eq. 4 worst case), and every
overlapped fabric cell hides wire time behind compute. The doctor's
classification rule (:func:`repro.obs.diagnose.classify_cell`) is gated
against exactly that:

* every **serialized** cell classifies **config-bound** — even the huge
  intensities where compute busies 98% of the run, because the exposed
  T_set share stays ≥ 10%;
* every **overlapped fabric** cell has *moved toward compute-bound*:
  its overlap-adjusted ridge ``I_OC = P_peak / BW_cfg_exposed`` strictly
  decreased (the config-bound region shrank) and part of its T_set is no
  longer host-visible (``exposed_fraction < 1``);
* **CSR** cells are mode-identical (a core-local port has no wire to
  hide), so both modes classify the same.

Run after the bench: ``python benchmarks/doctor_gate.py [--dir .]``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.diagnose import classify_cell  # noqa: E402

FABRIC = ("noc", "noc2", "pcie")


def check(doc: dict) -> list[str]:
    problems: list[str] = []
    for cell in doc["cells"]:
        tag = f"{cell['link']}/{cell['intensity']}"
        ser = classify_cell(cell["serialized"])
        ov = classify_cell(cell["overlapped"])
        if ser.label != "config_bound":
            problems.append(
                f"{tag}: serialized classified {ser.label} "
                f"(exposed share {ser.exposed_share:.3f}) — every "
                f"serialized cell must be config_bound")
        if cell["link"] in FABRIC:
            ridge_ser = cell["serialized"]["ridge_i_oc"]
            ridge_ov = cell["overlapped"]["ridge_i_oc"]
            if not ridge_ov < ridge_ser:
                problems.append(
                    f"{tag}: overlapped ridge {ridge_ov:.1f} did not drop "
                    f"below serialized {ridge_ser:.1f}")
            if not ov.exposed_fraction < 1.0:
                problems.append(
                    f"{tag}: overlapped exposed_fraction "
                    f"{ov.exposed_fraction:.3f} — nothing hidden")
        else:
            if cell["serialized"] != cell["overlapped"]:
                problems.append(f"{tag}: csr cells differ across modes")
            if ser.label != ov.label:
                problems.append(
                    f"{tag}: csr classification differs across modes "
                    f"({ser.label} vs {ov.label})")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding BENCH_config_overlap.json")
    args = ap.parse_args()
    path = os.path.join(args.dir, "BENCH_config_overlap.json")
    if not os.path.exists(path):
        print(f"doctor_gate: {path} missing — run "
              f"benchmarks/config_overlap.py first", file=sys.stderr)
        return 1
    with open(path) as f:
        doc = json.load(f)
    problems = check(doc)
    n = len(doc["cells"])
    if problems:
        print(f"doctor_gate: FAIL ({len(problems)} problems over {n} cells)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"doctor_gate: OK — {n} cells: every serialized cell "
          f"config-bound; every overlapped fabric cell moved toward "
          f"compute-bound (ridge down, T_set partly hidden)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
