"""CI gate: the config-wall doctor must classify the overlap sweep right.

``BENCH_config_overlap.json`` is the repo's cleanest ground truth about
the configuration wall: every serialized cell keeps the host captive
through its transfers (the paper's Eq. 4 worst case), and every
overlapped fabric cell hides wire time behind compute. The doctor's
classification rule (:func:`repro.obs.diagnose.classify_cell`) is gated
against exactly that:

* every **serialized** cell classifies **config-bound** — even the huge
  intensities where compute busies 98% of the run, because the exposed
  T_set share stays ≥ 10%;
* every **overlapped fabric** cell has *moved toward compute-bound*:
  its overlap-adjusted ridge ``I_OC = P_peak / BW_cfg_exposed`` strictly
  decreased (the config-bound region shrank) and part of its T_set is no
  longer host-visible (``exposed_fraction < 1``);
* **CSR** cells are mode-identical (a core-local port has no wire to
  hide), so both modes classify the same.

Run after the bench: ``python benchmarks/doctor_gate.py [--dir .]``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.diagnose import classify_cell  # noqa: E402

FABRIC = ("noc", "noc2", "pcie")


def check(doc: dict) -> list[str]:
    problems: list[str] = []
    for cell in doc["cells"]:
        tag = f"{cell['link']}/{cell['intensity']}"
        ser = classify_cell(cell["serialized"])
        ov = classify_cell(cell["overlapped"])
        if ser.label != "config_bound":
            problems.append(
                f"{tag}: serialized classified {ser.label} "
                f"(exposed share {ser.exposed_share:.3f}) — every "
                f"serialized cell must be config_bound")
        if cell["link"] in FABRIC:
            ridge_ser = cell["serialized"]["ridge_i_oc"]
            ridge_ov = cell["overlapped"]["ridge_i_oc"]
            if not ridge_ov < ridge_ser:
                problems.append(
                    f"{tag}: overlapped ridge {ridge_ov:.1f} did not drop "
                    f"below serialized {ridge_ser:.1f}")
            if not ov.exposed_fraction < 1.0:
                problems.append(
                    f"{tag}: overlapped exposed_fraction "
                    f"{ov.exposed_fraction:.3f} — nothing hidden")
        else:
            if cell["serialized"] != cell["overlapped"]:
                problems.append(f"{tag}: csr cells differ across modes")
            if ser.label != ov.label:
                problems.append(
                    f"{tag}: csr classification differs across modes "
                    f"({ser.label} vs {ov.label})")
    return problems


def check_energy(doc: dict) -> list[str]:
    """ISSUE 8 acceptance, re-asserted from the shipped artifact: every
    capped cell held its watt budget in *every* window (the cap's own
    ``held`` flag and the independently measured peak window), and the
    cap was binding somewhere — a never-binding budget quantifies
    nothing."""
    problems: list[str] = []
    delayed_anywhere = False
    for cell in doc["cells"]:
        tag = f"rate 1/{cell['interarrival_cycles']:.0f}"
        cap = cell["capped"]["cap"]
        budget = cell["budget_power"]
        if not cap["held"]:
            problems.append(
                f"{tag}: cap reports a violated budget "
                f"({cap['max_window_power']:.1f} > {budget:.1f} pJ/cycle)")
        if cap["max_window_power"] > budget + 1e-9:
            problems.append(
                f"{tag}: worst cap window {cap['max_window_power']:.1f} "
                f"pJ/cycle exceeds budget {budget:.1f}")
        if cell["capped"]["peak_window_power"] > budget + 1e-9:
            problems.append(
                f"{tag}: measured peak window "
                f"{cell['capped']['peak_window_power']:.1f} pJ/cycle "
                f"exceeds budget {budget:.1f}")
        if not cell["uncapped"]["peak_window_power"] > budget:
            problems.append(
                f"{tag}: uncapped peak never exceeded the budget — the "
                f"cell caps nothing")
        delayed_anywhere = delayed_anywhere or cap["delayed"] > 0
    if not delayed_anywhere:
        problems.append("no cell delayed a single admission — the watt "
                        "budget was never binding")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding BENCH_config_overlap.json")
    args = ap.parse_args()
    path = os.path.join(args.dir, "BENCH_config_overlap.json")
    if not os.path.exists(path):
        print(f"doctor_gate: {path} missing — run "
              f"benchmarks/config_overlap.py first", file=sys.stderr)
        return 1
    with open(path) as f:
        doc = json.load(f)
    problems = check(doc)
    n = len(doc["cells"])

    energy_path = os.path.join(args.dir, "BENCH_energy_slo.json")
    n_energy = 0
    if os.path.exists(energy_path):
        with open(energy_path) as f:
            energy_doc = json.load(f)
        problems += check_energy(energy_doc)
        n_energy = len(energy_doc["cells"])

    if problems:
        print(f"doctor_gate: FAIL ({len(problems)} problems over {n} cells)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"doctor_gate: OK — {n} cells: every serialized cell "
          f"config-bound; every overlapped fabric cell moved toward "
          f"compute-bound (ridge down, T_set partly hidden)"
          + (f"; {n_energy} energy cells held the watt budget in every "
             f"window" if n_energy else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
