"""Wall-clock benchmark: sequential vs concurrent configuration on the real
JAX runtime (§2.2 / §5.5 at the dispatch layer).

The device step is a jitted matmul chain; host 'configuration' packs a
descriptor (NumPy bit-twiddling — Eq. 4's T_calc). Sequential blocks per
launch; concurrent lets JAX's async dispatch queue stage the next launch.
Measured on the CPU device — the *relative* gap is the paper's point.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dispatch import ConcurrentExecutor, ConfigPlan, SequentialExecutor, StepDescriptor

try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def make_device_fn(n: int = 512, depth: int = 2):
    @jax.jit
    def device_fn(state, args):
        x = state
        for _ in range(depth):
            x = jnp.tanh(x @ state) + args["bias"]
        return x / jnp.linalg.norm(x)

    return device_fn


def make_host_prep(n: int = 512, calc_us: int = 4000):
    def host_prep(step):
        # descriptor calculation (T_calc). Modeled as a blocking wait rather
        # than a spin so that, on a single-core container where the CPU
        # "device" shares the core with the host thread, overlap remains
        # observable — on a real TPU host the device computes regardless.
        time.sleep(calc_us / 1e6)
        acc = (np.uint64(step) << np.uint64(16)) | np.uint64(step % 7)
        return {"bias": jnp.float32(float(acc % 97) * 1e-4)}

    return host_prep


def run(n_steps: int = 30, n: int = 512) -> dict:
    device_fn = make_device_fn(n)
    host_prep = make_host_prep(n)
    state = jnp.eye(n) * 0.5 + 0.01
    jax.block_until_ready(device_fn(state, host_prep(0)))  # warmup

    _, seq = SequentialExecutor(device_fn, host_prep).run(state, n_steps)
    _, conc = ConcurrentExecutor(device_fn, host_prep, depth=2).run(state, n_steps)

    # descriptor dedup accounting on a serving-like descriptor
    descs = [
        StepDescriptor({
            "pos": i,
            "temperature": 0.7,
            "top_k": 40,
            "cache_layout": np.arange(64, dtype=np.int32),
            "rng": np.uint64(1234),
        })
        for i in range(8)
    ]
    plan = ConfigPlan.trace(descs)

    return {
        "sequential_s": seq.wall_s,
        "concurrent_s": conc.wall_s,
        "overlap_speedup": seq.wall_s / conc.wall_s,
        "host_prep_s": seq.host_prep_s,
        "dedup_bytes_baseline": plan.bytes_baseline(descs[0]),
        "dedup_bytes_dynamic": plan.bytes_deduped(descs[0]),
        "dedup_i_oc_gain": plan.i_oc_gain(descs[0]),
    }


def export_trace(path: str) -> None:
    """Instrumented simulator analogue of the measured overlap: the same
    mixed sequential/concurrent pool under overlapped staging — Gemmini's
    launches keep the host captive while OpenGeMM's burst configs stream
    behind its compute (the gap the wall-clock numbers show)."""
    from repro.sched import LaunchRequest, Scheduler

    def scenario(tracer):
        s = Scheduler.from_registry({"gemmini": 1, "opengemm": 1},
                                    link="noc", overlap="overlapped",
                                    tracer=tracer)
        reqs = [
            LaunchRequest("steps", (16, 16, 16),
                          {f"d{j}": 96 * i + j for j in range(24)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=0.0)
            for i in range(16)
        ]
        return s.run_open_loop(reqs)

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None,
                    help="export an instrumented simulator analogue of "
                         "the sequential-vs-concurrent overlap scenario")
    args = ap.parse_args()
    r = run()
    print("# dispatch overlap (sequential vs concurrent configuration)")
    for k, v in r.items():
        print(f"{k},{v:.4f}" if isinstance(v, float) else f"{k},{v}")
    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
