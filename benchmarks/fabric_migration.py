"""Fabric benchmark: burst DMA vs. per-register MMIO across link classes,
and warm register-snapshot migration vs. cold resend.

Two sweeps over the mixed Gemmini+OpenGeMM pool:

* **Transport** — link class (core-local CSR / NoC hop / PCIe) × write-plan
  size × device kind: T_set for per-register MMIO vs. one coalesced burst
  descriptor (``fabric.transport``). On the CSR port MMIO always wins (and
  equals the pre-fabric cost exactly); on a fabric, burst DMA wins once the
  plan exceeds a few registers — each MMIO write pays the full link
  latency, the burst pays it once.

* **Migration** — link class × context size × device kind: a tenant with a
  large register context is moved between hosts, measuring an *executed*
  warm hand-off (snapshot shipped over the migration link, first launch at
  the destination sends only its delta) against an executed cold resend
  (first launch re-sends the full register file through the destination's
  config port). Warm wins once the context amortizes the hand-off's
  per-transfer overhead — easily over a NoC, only for much larger contexts
  over PCIe (the ship and the delta each pay the ~350-cycle latency) — and
  always moves strictly fewer config-port bytes.

Plus a cross-run persistence demo: contexts checkpointed via
``fabric.ContextStore`` restore warm in a fresh run.

Acceptance (asserted below, ISSUE 3):
* burst DMA beats MMIO on multi-register plans for every fabric link class;
* warm migration strictly cheaper than cold resend — modeled cycles *and*
  config-port bytes — for at least one link class.

Emits ``BENCH_fabric_migration.json`` (with a ``geomean`` summary).

Usage: ``PYTHONPATH=src python benchmarks/fabric_migration.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

from repro.cluster import Host
from repro.core.accelerators import REGISTRY
from repro.fabric import (
    LINKS,
    ContextStore,
    MigrationPlanner,
    burst_schedule,
    capture_contexts,
    crossover_fields,
    install_contexts,
    mmio_schedule,
)
from repro.sched import LaunchRequest, geomean

TILE = (8, 16, 16)
POOL = {"gemmini": 1, "opengemm": 1}


def big_ctx_request(tenant: str, accel: str, n_static: int,
                    ptr: int = 0x1000) -> LaunchRequest:
    """A launch with a large register file: ``n_static`` static fields
    (scales, zero-points, strides...) plus one advancing pointer."""
    extra = {f"w{i}": 7 * i for i in range(n_static)}
    extra["A"] = ptr
    return LaunchRequest(tenant, TILE, extra, accel=accel)


# ------------------------------------------------------------- transport


def transport_sweep(sizes) -> dict:
    cells, crossovers = [], {}
    for link_name in ("csr", "noc", "pcie"):
        link = LINKS[link_name]
        for kind in POOL:
            model = REGISTRY[kind]
            crossovers[f"{link_name}/{kind}"] = crossover_fields(model, link)
            for n in sizes:
                mmio = mmio_schedule(n, model, link)
                burst = burst_schedule(n, model, link)
                cells.append({
                    "link": link_name,
                    "accel": kind,
                    "n_fields": n,
                    "mmio_t_set": mmio.t_set,
                    "burst_t_set": burst.t_set if burst else None,
                    "winner": ("burst" if burst and burst.t_set < mmio.t_set
                               else "mmio"),
                })
    return {"cells": cells, "crossover_fields": crossovers}


# ------------------------------------------------------------- migration


def _warm_src(link: str, tenant: str, accel: str, n_static: int) -> Host:
    src = Host.from_registry("src", dict(POOL), link=link)
    for i in range(3):
        src.dispatch(big_ctx_request(tenant, accel, n_static, 0x1000 + 64 * i))
    return src


def _first_launch_cost(host: Host, probe: LaunchRequest) -> tuple[float, int]:
    """(config cycles, config-port bytes) of one executed dispatch."""
    dev = host.dispatch(probe)
    rec = dev.telemetry.launch_log[-1]
    return rec.config_cycles, rec.bytes_sent


def migration_cell(link: str, accel: str, n_static: int) -> dict:
    probe = big_ctx_request("t0", accel, n_static, ptr=0x2000)

    # the auto planner's modeled estimate
    planner = MigrationPlanner(link=link)
    est = planner.estimate("t0", _warm_src(link, "t0", accel, n_static),
                           Host.from_registry("dst", dict(POOL), link=link),
                           probe)

    # executed cold: fresh destination, first launch re-sends everything
    cold_cycles, cold_bytes = _first_launch_cost(
        Host.from_registry("dst", dict(POOL), link=link), probe)

    # executed warm: hand the snapshot off, then the same first launch
    src = _warm_src(link, "t0", accel, n_static)
    dst = Host.from_registry("dst", dict(POOL), link=link)
    warm_planner = MigrationPlanner(link=link, policy="warm")
    rec = warm_planner.migrate("t0", src, dst, probe, now=src.clock)
    delta_cycles, warm_bytes = _first_launch_cost(dst, probe)
    warm_cycles = rec.transfer.cycles + delta_cycles

    return {
        "link": link,
        "accel": accel,
        "context_fields": rec.snapshot.n_fields,
        "context_bytes": rec.snapshot.context_bytes,
        "auto_mode": est.mode,
        "est_warm_cycles": est.warm_cycles,
        "est_cold_cycles": est.cold_cycles,
        "warm_cycles": warm_cycles,
        "cold_cycles": cold_cycles,
        "warm_port_bytes": warm_bytes,
        "cold_port_bytes": cold_bytes,
        "warm_wins_cycles": warm_cycles < cold_cycles,
    }


# ----------------------------------------------------------- persistence


def persistence_demo(link: str, accel: str, n_static: int) -> dict:
    """Contexts persisted through the checkpoint layer restore warm: the
    recurring tenant's first dispatch of the next run sends only a delta."""
    run1 = _warm_src(link, "t0", accel, n_static)
    probe = big_ctx_request("t0", accel, n_static, ptr=0x2000)
    cold_cycles, cold_bytes = _first_launch_cost(
        Host.from_registry("h0", dict(POOL), link=link), probe)
    with tempfile.TemporaryDirectory() as d:
        ContextStore(d).save(1, capture_contexts(run1))
        run2 = Host.from_registry("h0", dict(POOL), link=link)
        installed = install_contexts(run2, ContextStore(d).restore().values())
        resume_cycles, resume_bytes = _first_launch_cost(run2, probe)
    return {
        "link": link,
        "accel": accel,
        "contexts_restored": installed,
        "cold_start_cycles": cold_cycles,
        "cold_start_port_bytes": cold_bytes,
        "warm_resume_cycles": resume_cycles,
        "warm_resume_port_bytes": resume_bytes,
    }


# ------------------------------------------------------------------ main


def run(smoke: bool = False) -> dict:
    sizes = [2, 8, 32] if smoke else [1, 2, 4, 8, 16, 32, 64]
    contexts = [8, 64] if smoke else [8, 32, 128, 256]

    transport = transport_sweep(sizes)
    migration = [
        migration_cell(link, accel, n)
        for link in ("noc", "pcie")
        for accel in POOL
        for n in contexts
    ]
    persist = persistence_demo("noc", "gemmini", contexts[-1])

    multi = [c for c in transport["cells"]
             if c["n_fields"] >= 4 and c["burst_t_set"] is not None]
    warm_wins = [c for c in migration if c["warm_wins_cycles"]]
    summary = {
        "mmio_over_burst_t_set": geomean(
            [c["mmio_t_set"] / c["burst_t_set"] for c in multi]),
        "cold_over_warm_cycles": geomean(
            [c["cold_cycles"] / c["warm_cycles"] for c in migration]),
        "cold_over_warm_port_bytes": geomean(
            [c["cold_port_bytes"] / c["warm_port_bytes"] for c in migration]),
        "warm_winning_cells": len(warm_wins),
    }
    return {
        "benchmark": "fabric_migration",
        "pool": POOL,
        "tile": list(TILE),
        "smoke": smoke,
        "transport": transport,
        "migration": {"cells": migration},
        "persistence": persist,
        "geomean": summary,
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Instrument one executed warm migration (NoC, Gemmini, 64-field
    context): the snapshot burst shows up on the migration wire lane and is
    classified ``other_transfer`` by the attribution (it belongs to no
    launch), while the delta launch traces normally on the destination."""
    n_static = 8 if smoke else 64

    def scenario(tracer):
        src = Host.from_registry("src", dict(POOL), link="noc",
                                 tracer=tracer)
        for i in range(3):
            src.dispatch(big_ctx_request("t0", "gemmini", n_static,
                                         0x1000 + 64 * i))
        dst = Host.from_registry("dst", dict(POOL), link="noc",
                                 tracer=tracer)
        planner = MigrationPlanner(link="noc", policy="warm")
        planner.port.tracer = tracer
        probe = big_ctx_request("t0", "gemmini", n_static, ptr=0x2000)
        planner.migrate("t0", src, dst, probe, now=src.clock)
        dst.dispatch(probe)
        return dst.report()

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer plan sizes / context sizes (CI time budget)")
    ap.add_argument("--out", default="BENCH_fabric_migration.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented warm migration")
    args = ap.parse_args()

    result = run(smoke=args.smoke)

    print("# transport: MMIO vs burst DMA T_set (cycles)")
    print("link,accel,n_fields,mmio,burst,winner")
    for c in result["transport"]["cells"]:
        burst = f"{c['burst_t_set']:.1f}" if c["burst_t_set"] is not None else "-"
        print(f"{c['link']},{c['accel']},{c['n_fields']},"
              f"{c['mmio_t_set']:.1f},{burst},{c['winner']}")
    print(f"burst/MMIO crossover fields: {result['transport']['crossover_fields']}")

    print("\n# migration: executed warm hand-off vs cold resend")
    print("link,accel,ctx_fields,auto,warm_cycles,cold_cycles,"
          "warm_port_B,cold_port_B")
    for c in result["migration"]["cells"]:
        print(f"{c['link']},{c['accel']},{c['context_fields']},"
              f"{c['auto_mode']},{c['warm_cycles']:.1f},{c['cold_cycles']:.1f},"
              f"{c['warm_port_bytes']},{c['cold_port_bytes']}")

    p = result["persistence"]
    print(f"\n# persistence ({p['link']}/{p['accel']}): cold start "
          f"{p['cold_start_cycles']:.1f} cyc / {p['cold_start_port_bytes']} B "
          f"vs warm resume {p['warm_resume_cycles']:.1f} cyc / "
          f"{p['warm_resume_port_bytes']} B")

    g = result["geomean"]
    print(f"\ngeomean: mmio/burst T_set {g['mmio_over_burst_t_set']:.2f}x, "
          f"cold/warm cycles {g['cold_over_warm_cycles']:.2f}x, "
          f"cold/warm port bytes {g['cold_over_warm_port_bytes']:.2f}x")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 3a): burst DMA beats per-register MMIO on
    # multi-register plans, on every fabric link class and device kind
    for c in result["transport"]["cells"]:
        if c["link"] != "csr" and c["n_fields"] >= 8:
            assert c["winner"] == "burst", c
    # acceptance (ISSUE 3b): warm register-snapshot migration strictly
    # cheaper than cold resend — cycles AND config-port bytes — for at
    # least one link class (small contexts rightly go cold: that is the
    # planner's whole point; the win must appear once contexts are large)
    winning_links = {
        c["link"] for c in result["migration"]["cells"]
        if c["warm_cycles"] < c["cold_cycles"]
        and c["warm_port_bytes"] < c["cold_port_bytes"]
    }
    assert winning_links, (
        "acceptance: warm migration must beat cold resend (cycles + port "
        f"bytes) for at least one link class; cells={result['migration']}")
    for c in result["migration"]["cells"]:
        # port bytes shrink for every cell: the delta is a strict subset
        assert c["warm_port_bytes"] < c["cold_port_bytes"], c
        # planner fidelity: auto picks exactly the measured-cheaper mode,
        # and its estimates match the executed costs
        assert c["auto_mode"] == ("warm" if c["warm_wins_cycles"] else "cold"), c
        assert abs(c["est_warm_cycles"] - c["warm_cycles"]) < 1e-6, c
        assert abs(c["est_cold_cycles"] - c["cold_cycles"]) < 1e-6, c
    # persistence: a restored context resumes strictly cheaper than cold
    assert p["warm_resume_cycles"] < p["cold_start_cycles"]
    assert p["warm_resume_port_bytes"] < p["cold_start_port_bytes"]


if __name__ == "__main__":
    main()
