"""Cluster SLO benchmark: where multi-host serving leaves the
configuration-bound region.

An open-loop tenant mix (17 tenants of small decode-step GEMM tiles — the
config-bound regime, T_set ≥ macro-op time) arrives on a Poisson clock and
is routed across a cluster of hosts, each carrying one Gemmini-like
(sequential) and one OpenGeMM-like (concurrent) device behind a serialized
config port. Sweeping arrival rate × host count for two routers:

* **round_robin** — spreads every tenant over every host: each device ends
  up juggling more tenant contexts than its ``ConfigStateCache`` holds, so
  launches keep paying full config re-sends, the port serializes the extra
  T_set, and queues blow up early (offload amplification).
* **affinity** — the config-affinity router (port congestion + context
  residency): tenants pin to warm hosts, only register deltas cross the
  boundary, and the same hardware sustains a higher arrival rate before the
  p99 queueing delay leaves the SLO region.

Acceptance (asserted below, ISSUE 2): on ≥2 arrival rates the affinity
router strictly beats round_robin on p99 queueing delay *and* SLO
attainment. Emits ``BENCH_cluster_slo.json`` with percentile + config-byte
metrics per cell.

Usage: ``PYTHONPATH=src python benchmarks/cluster_slo.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.cluster import Cluster, TenantProfile, generate, slo_targets
from repro.sched import geomean

# Small decode-step tiles: 2·8·16·16 = 4096 ops/launch ⇒ 4–24 device cycles
# against ~21–39 cycles of config writes — left of the knee point (§4.2).
TILE = (8, 16, 16)


def tenant_mix() -> list[TenantProfile]:
    """17 tenants, 8 per device kind + one high-priority interactive tenant.
    Deliberately more tenants per kind than ``max_contexts`` (4): a router
    that shuffles tenants across hosts forces LRU context churn."""
    profiles: list[TenantProfile] = []
    for i in range(8):
        profiles.append(TenantProfile(
            f"og{i}", dims=TILE, accel="opengemm",
            weight=2.0 if i < 2 else 1.0, slo_cycles=600.0))
    for i in range(8):
        profiles.append(TenantProfile(
            f"gem{i}", dims=TILE, accel="gemmini",
            weight=2.0 if i < 2 else 1.0, slo_cycles=1200.0))
    profiles.append(TenantProfile(
        "vip", dims=TILE, accel="opengemm", weight=1.0, priority=2,
        slo_cycles=300.0))
    return profiles


def run_cell(requests, profiles, *, n_hosts: int, policy: str) -> dict:
    cluster = Cluster.uniform(n_hosts, {"gemmini": 1, "opengemm": 1},
                              policy=policy)
    rep = cluster.run(list(requests), slo=slo_targets(profiles))
    return {
        "policy": policy,
        "hosts": n_hosts,
        "launches": rep.launches,
        "makespan": rep.makespan,
        "p50_queue_delay": rep.queue_delay_percentile(50),
        "p95_queue_delay": rep.queue_delay_percentile(95),
        "p99_queue_delay": rep.queue_delay_percentile(99),
        "p99_latency": rep.latency_percentile(99),
        "slo_attainment": rep.attainment,
        "goodput_ops_per_cycle": rep.goodput,
        "config_bytes_sent": rep.bytes_sent,
        "config_bytes_elided": rep.bytes_elided,
        "elision_ratio": rep.elision_ratio,
        "preemptions": rep.preemptions,
        "port_utilization": rep.port_utilization,
        "vip_p99_queue_delay": rep.tenants["vip"].p99_queue,
        "vip_attainment": rep.tenants["vip"].attainment,
    }


def run(smoke: bool = False) -> dict:
    profiles = tenant_mix()
    horizon = 60_000.0 if smoke else 200_000.0
    rates = [1 / 20, 1 / 15] if smoke else [1 / 30, 1 / 20, 1 / 17, 1 / 15]
    host_counts = [2] if smoke else [2, 4]
    cells = []
    for n_hosts in host_counts:
        for rate in rates:
            requests = generate(profiles, rate=rate, horizon=horizon, seed=7)
            row = {"rate": rate, "interarrival_cycles": 1 / rate,
                   "hosts": n_hosts, "requests": len(requests)}
            for policy in ("affinity", "round_robin"):
                row[policy] = run_cell(requests, profiles,
                                       n_hosts=n_hosts, policy=policy)
            cells.append(row)
    return {
        "benchmark": "cluster_slo",
        "pool_per_host": {"gemmini": 1, "opengemm": 1},
        "tile": list(TILE),
        "tenants": len(profiles),
        "horizon_cycles": horizon,
        "smoke": smoke,
        "cells": cells,
        # cross-cell summary (CI requires every BENCH_*.json to carry one)
        "geomean": {
            "affinity_over_rr_goodput": geomean(
                [c["affinity"]["goodput_ops_per_cycle"]
                 / max(c["round_robin"]["goodput_ops_per_cycle"], 1e-9)
                 for c in cells]),
            "affinity_slo_attainment": geomean(
                [c["affinity"]["slo_attainment"] for c in cells]),
            "rr_over_affinity_p99_queue": geomean(
                [(1.0 + c["round_robin"]["p99_queue_delay"])
                 / (1.0 + c["affinity"]["p99_queue_delay"])
                 for c in cells]),
        },
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Re-run one representative cell (affinity router, 2 hosts, lowest
    swept rate) with a tracer attached and export the Perfetto trace with
    its conservation-checked cycle attribution embedded."""
    profiles = tenant_mix()
    horizon = 60_000.0 if smoke else 200_000.0
    requests = generate(profiles, rate=1 / 20, horizon=horizon, seed=7)

    def scenario(tracer):
        cluster = Cluster.uniform(2, {"gemmini": 1, "opengemm": 1},
                                  policy="affinity", tracer=tracer)
        return cluster.run(list(requests), slo=slo_targets(profiles))

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small horizon / fewer cells (CI time budget)")
    ap.add_argument("--out", default="BENCH_cluster_slo.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented representative cell")
    args = ap.parse_args()

    result = run(smoke=args.smoke)
    print(f"# cluster SLO sweep: {result['tenants']} tenants, "
          f"tile {tuple(result['tile'])}, horizon {result['horizon_cycles']:.0f} cycles")
    print("hosts,rate,policy,p99_queue,slo_attainment,goodput,config_bytes,"
          "preemptions")
    for cell in result["cells"]:
        for policy in ("affinity", "round_robin"):
            c = cell[policy]
            print(f"{cell['hosts']},1/{cell['interarrival_cycles']:.0f},"
                  f"{policy},{c['p99_queue_delay']:.0f},"
                  f"{c['slo_attainment']:.3f},"
                  f"{c['goodput_ops_per_cycle']:.1f},"
                  f"{c['config_bytes_sent']},{c['preemptions']}")
    # where the cluster leaves the configuration-bound region: per-host
    # roofline knee comparison at the highest swept rate
    base = result["cells"][-1]
    print(f"\nelision_ratio affinity={base['affinity']['elision_ratio']:.3f} "
          f"round_robin={base['round_robin']['elision_ratio']:.3f}")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 2): affinity routing with per-host serialization
    # modeled beats round-robin on p99 queueing delay and SLO attainment at
    # >= 2 arrival rates
    strict = [
        cell for cell in result["cells"]
        if cell["affinity"]["p99_queue_delay"] < cell["round_robin"]["p99_queue_delay"]
        and cell["affinity"]["slo_attainment"] >= cell["round_robin"]["slo_attainment"]
    ]
    assert len({c["rate"] for c in strict}) >= 2, (
        f"acceptance: affinity must win p99 queue delay + attainment at >=2 "
        f"arrival rates, got {len(strict)} winning cells"
    )


if __name__ == "__main__":
    main()
