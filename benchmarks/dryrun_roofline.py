"""Deliverables (e)+(g): run every (arch × shape × mesh) dry-run cell and
emit the roofline table.

Each cell runs in a fresh subprocess (jax locks the host-device count at
first init, and a crashed cell must not take the sweep down). Results land in
``results/dryrun/<arch>__<shape>__<mesh>.json``; ``--report`` renders the
markdown table for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m benchmarks.dryrun_roofline --run [--only-missing]
    PYTHONPATH=src python -m benchmarks.dryrun_roofline --report
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
PRESET = ""


def results_dir() -> str:
    return RESULTS_DIR + ("_opt" if PRESET == "optimized" else "")


def cell_path(arch: str, shape: str, mesh: str) -> str:
    safe = arch.replace("/", "_")
    return os.path.join(results_dir(), f"{safe}__{shape}__{mesh}.json")


def all_cells():
    from repro.configs import ARCHS, SHAPES

    # smallest-first so results stream in early
    order = sorted(ARCHS.values(), key=lambda c: c.param_count())
    for cfg in order:
        for shape in SHAPES.values():
            for mesh, flag in (("pod16x16", []), ("pod2x16x16", ["--multi-pod"])):
                yield cfg.name, shape.name, mesh, flag


def run_all(only_missing: bool = True, timeout: int = 3600) -> None:
    os.makedirs(results_dir(), exist_ok=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    for arch, shape, mesh, flag in all_cells():
        out = cell_path(arch, shape, mesh)
        if only_missing and os.path.exists(out):
            continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--out", out, *flag,
            *(["--preset", PRESET] if PRESET else []),
        ]
        t0 = time.time()
        print(f"[sweep] {arch} × {shape} × {mesh} ...", flush=True)
        try:
            proc = subprocess.run(
                cmd, env=env, timeout=timeout, capture_output=True, text=True
            )
            if proc.returncode != 0:
                err = (proc.stderr or "").strip().splitlines()
                with open(out, "w") as f:
                    json.dump(
                        {"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "failed", "error": err[-15:]}, f, indent=2)
                print(f"  FAILED in {time.time()-t0:.0f}s: {err[-1] if err else '?'}",
                      flush=True)
            else:
                print(f"  done in {time.time()-t0:.0f}s", flush=True)
        except subprocess.TimeoutExpired:
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "timeout"}, f, indent=2)
            print("  TIMEOUT", flush=True)


def load_records() -> list[dict]:
    recs = []
    if not os.path.isdir(results_dir()):
        return recs
    for fn in sorted(os.listdir(results_dir())):
        if fn.endswith(".json"):
            with open(os.path.join(results_dir(), fn)) as f:
                recs.append(json.load(f))
    return recs


def improvement_hint(r: dict) -> str:
    dom = r.get("dominant", "?")
    kind = r.get("kind", "?")
    if dom == "collective":
        return "reshard the offending dim (kv-heads/cache) to kill the per-layer regather"
    if dom == "memory":
        return "chunked (flash) attention + remat policy to cut bytes accessed"
    if kind == "decode":
        return "fuse k decode steps per launch (raises I_OC k×, paper §4.2)"
    return "already compute-bound: increase per-chip tile occupancy"


def report() -> str:
    """memory-lb: analytic HBM floor — per-device argument+output bytes
    (params/opt/cache read once, results written once) over 819 GB/s; the
    'memory s' column is the unfused per-op upper bound. Truth is between."""
    lines = [
        "| arch | shape | mesh | compute s | memory s (ub) | memory s (lb) | "
        "collective s | dominant | MODEL_FLOPS | useful/HLO | roofline frac | "
        "GiB/dev | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records():
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | skipped | "
                f"— | — | — | — | {r['reason']} |")
            continue
        if r.get("status") in ("failed", "timeout"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"{r['status'].upper()} | — | — | — | — | see error log |")
            continue
        mem = r.get("memory_analysis", {})
        lb_bytes = mem.get("argument_size_in_bytes", 0) + mem.get(
            "output_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0)
        mem_lb_s = lb_bytes / 819e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | {mem_lb_s:.2e} "
            f"| {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['per_device_bytes']/2**30:.1f} "
            f"| {improvement_hint(r)} |")
    return "\n".join(lines)


def export_trace(path: str) -> None:
    """Instrumented simulator counterpart of the sweep's operating point:
    a mixed Gemmini+OpenGeMM pool draining interleaved tenant streams over
    the NoC, so the exported trace carries one roofline-relevant run the
    doctor can classify next to the dry-run table."""
    try:
        from benchmarks.trace_util import export_trace as _export
    except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
        from trace_util import export_trace as _export
    from repro.sched import LaunchRequest, Scheduler

    def scenario(tracer):
        s = Scheduler.from_registry({"gemmini": 1, "opengemm": 1},
                                    link="noc", overlap="overlapped",
                                    tracer=tracer)
        reqs = [
            LaunchRequest(f"arch{i % 2}", (32, 32, 32),
                          {f"f{j}": 48 * i + j for j in range(20)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=64.0 * i)
            for i in range(14)
        ]
        return s.run_open_loop(reqs)

    _export(path, scenario)


def main() -> None:
    global PRESET
    p = argparse.ArgumentParser()
    p.add_argument("--run", action="store_true")
    p.add_argument("--report", action="store_true")
    p.add_argument("--all", action="store_true", help="re-run existing cells too")
    p.add_argument("--preset", default="", choices=("", "optimized"))
    p.add_argument("--timeout", type=int, default=3600)
    p.add_argument("--trace-out", default=None,
                   help="export an instrumented mixed-pool simulator run "
                        "matching the sweep's operating point")
    args = p.parse_args()
    PRESET = args.preset
    if args.run:
        run_all(only_missing=not args.all, timeout=args.timeout)
    if args.report:
        print(report())
    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
