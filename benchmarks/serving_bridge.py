"""Serving-bridge benchmark: the real decode launch path on the cluster.

N `serving.ServingEngine` tenants (one small model-zoo architecture,
shared compiled decode step) run **closed-loop** against a multi-host
cluster over a NoC config fabric: every continuous-batching step's
descriptor — ``{positions}`` plus elided residents under fused sampling;
``{tokens, positions, live-mask}`` under host sampling — is the config
payload of a cluster launch, and a tenant only emits its next step after
the previous one retires — queueing delay throttles token throughput
directly.

Two routers A/B, more tenants than any device's ``max_contexts`` so the
context-churn regime is real:

* **slot-residency sticky affinity** — a tenant's decode launches bind to
  the host holding its KV cache; the home device's config-state cache
  stays warm, so steady-state launches ship only the tokens/positions
  delta (the §5.4 deduplicated-configuration serving design end to end).
* **round_robin** — every launch lands on the next host; more tenants
  than context slots churn the LRU, so launches keep paying full
  descriptor re-sends (tile registers and invariant sampling config
  included), and the extra T_set lands on every step's critical path.

Acceptance (asserted below, ISSUE 4):

* sticky affinity beats round-robin on **p99 decode-step latency at
  every load cell** (geomean summarized for CI);
* bridged config-bytes match ``engine.config_traffic()`` accounting
  exactly for every tenant under sticky routing (two independent cache
  implementations, one stream);
* token output is identical under both routers (the bridge never
  perturbs model output).

Two further A/B cells (ISSUE 9): **fused vs host sampling** — the fused
decode launch drops the ``tokens`` leaf (device-resident token loopback)
and must produce bit-identical token streams while raising
tokens/kcycle — and **batched vs token-at-a-time prefill** — chunked
prefill must shorten closed-loop time-to-first-token.

Usage: ``PYTHONPATH=src python benchmarks/serving_bridge.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.bridge import ClosedLoopDriver, TenantEngine
from repro.cluster import Cluster
from repro.configs import get
from repro.models.model import Model
from repro.sched import geomean
from repro.serving import Request, ServingEngine

MAX_SLOTS = 4  # int32 leaves ⇒ exact byte parity on 4-byte-field devices
MAX_CONTEXTS = 4  # per-device context slots; load cells exceed this


def build_model():
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    fns = {
        "fused": ServingEngine.compile_decode(model, sampling="fused"),
        "host": ServingEngine.compile_decode(model, sampling="host"),
        "prefill": ServingEngine.compile_prefill(model),
    }
    return model, params, fns


def make_tenants(model, params, fns, n_tenants: int, max_new: int,
                 sampling: str = "fused",
                 prefill_chunk: int = 8) -> list[TenantEngine]:
    """Deterministic per-tenant request mixes (distinct prompts ⇒ distinct
    token streams ⇒ distinct descriptor deltas)."""
    tenants = []
    for i in range(n_tenants):
        eng = ServingEngine(model, params, max_slots=MAX_SLOTS, max_len=64,
                            decode_fn=fns[sampling], prefill_fn=fns["prefill"],
                            sampling=sampling, prefill_chunk=prefill_chunk)
        prompts = [[3 + i, 5, 2 + (i % 3)], [7, 1 + i], [11, 2, 4, 1 + i]]
        for uid, prompt in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
        tenants.append(TenantEngine(f"t{i}", eng, accel="opengemm",
                                    slo_cycles=2_000.0))
    return tenants


def run_cell(model, params, fns, *, n_hosts: int, n_tenants: int,
             max_new: int, policy: str, sticky: bool,
             sampling: str = "fused", prefill_chunk: int = 8) -> dict:
    tenants = make_tenants(model, params, fns, n_tenants, max_new,
                           sampling=sampling, prefill_chunk=prefill_chunk)
    cluster = Cluster.uniform(n_hosts, {"opengemm": 1}, policy=policy,
                              sticky=sticky, link="noc",
                              max_contexts=MAX_CONTEXTS)
    rep = ClosedLoopDriver(tenants, cluster).run()
    parity = rep.config_parity()
    decode_p99 = [s.p99_decode for s in rep.serving.values()]
    tokens_by_tenant = {
        t: [r.generated for r in sorted(te.engine.finished,
                                        key=lambda r: r.uid)]
        for t, te in ((te.tenant, te) for te in tenants)
    }
    ttfts = list(rep.ttft_cycles().values())
    return {
        "policy": policy,
        "sticky": sticky,
        "hosts": n_hosts,
        "tenants": n_tenants,
        "sampling": sampling,
        "prefill_chunk": prefill_chunk,
        "ttft": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "tokens": rep.tokens,
        "steps": len(rep.steps),
        "launches": rep.cluster.launches,
        "makespan": rep.cluster.makespan,
        "tokens_per_kcycle": rep.tokens_per_kcycle,
        "p99_decode": max(decode_p99),
        "p50_decode": sorted(
            s.p50_decode for s in rep.serving.values())[len(decode_p99) // 2],
        "config_bytes_sent": rep.cluster.bytes_sent,
        "config_bytes_elided": rep.cluster.bytes_elided,
        "elision_ratio": rep.cluster.elision_ratio,
        "parity_matched": all(p["matched"] for p in parity.values()),
        "port_utilization": rep.cluster.port_utilization,
        "serving_roofline": [
            {"name": pt.name, "i_oc": pt.i_oc, "performance": pt.performance,
             "bound": pt.bound}
            for pt in rep.serving_roofline()
        ],
        "_tokens_by_tenant": tokens_by_tenant,  # stripped before JSON
    }


def run(smoke: bool = False) -> dict:
    model, params, fns = build_model()
    max_new = 6 if smoke else 10
    cells_spec = ([(2, 6), (2, 8)] if smoke
                  else [(2, 6), (2, 8), (4, 8)])
    cells = []
    fused_ref = fused_tokens = None
    for n_hosts, n_tenants in cells_spec:
        row = {"hosts": n_hosts, "tenants": n_tenants, "max_new": max_new}
        row["affinity"] = run_cell(model, params, fns,
                                   n_hosts=n_hosts, n_tenants=n_tenants,
                                   max_new=max_new, policy="affinity",
                                   sticky=True)
        row["round_robin"] = run_cell(model, params, fns,
                                      n_hosts=n_hosts, n_tenants=n_tenants,
                                      max_new=max_new, policy="round_robin",
                                      sticky=False)
        # the bridge may never perturb model output: both routers saw the
        # same engines, so the generated tokens must be identical
        toks_aff = row["affinity"].pop("_tokens_by_tenant")
        toks_rr = row["round_robin"].pop("_tokens_by_tenant")
        assert toks_aff == toks_rr, (
            "router choice changed generated tokens — bridge perturbed output")
        if (n_hosts, n_tenants) == (2, 6):
            # the first cell's sticky arm doubles as the fused+batched arm
            # of both A/B comparisons below
            fused_ref, fused_tokens = row["affinity"], toks_aff
        cells.append(row)

    # -- A/B 1: fused vs host-side sampling (same cell shape, sticky) ------
    host_cell = run_cell(model, params, fns, n_hosts=2, n_tenants=6,
                         max_new=max_new, policy="affinity", sticky=True,
                         sampling="host")
    assert host_cell.pop("_tokens_by_tenant") == fused_tokens, (
        "fused sampling changed generated tokens vs host-side argmax — "
        "the tie-break/loopback parity contract is broken")

    # -- A/B 2: batched vs token-at-a-time prefill (fused both arms) -------
    tat_cell = run_cell(model, params, fns, n_hosts=2, n_tenants=6,
                        max_new=max_new, policy="affinity", sticky=True,
                        prefill_chunk=1)
    assert tat_cell.pop("_tokens_by_tenant") == fused_tokens, (
        "prefill chunking changed generated tokens")

    return {
        "benchmark": "serving_bridge",
        "arch": "qwen2-0.5b (reduced)",
        "pool_per_host": {"opengemm": 1},
        "link": "noc",
        "max_slots": MAX_SLOTS,
        "max_contexts": MAX_CONTEXTS,
        "smoke": smoke,
        "cells": cells,
        "sampling_ab": {"fused": fused_ref, "host": host_cell},
        "prefill_ab": {"batched": fused_ref, "token_at_a_time": tat_cell},
        # cross-cell summary (CI requires every BENCH_*.json to carry one)
        "geomean": {
            "rr_over_affinity_p99_decode": geomean(
                [c["round_robin"]["p99_decode"]
                 / max(c["affinity"]["p99_decode"], 1e-9) for c in cells]),
            "affinity_over_rr_tokens_per_kcycle": geomean(
                [c["affinity"]["tokens_per_kcycle"]
                 / max(c["round_robin"]["tokens_per_kcycle"], 1e-9)
                 for c in cells]),
            "affinity_elision_ratio": geomean(
                [c["affinity"]["elision_ratio"] for c in cells]),
            "fused_over_host_tokens_per_kcycle": (
                fused_ref["tokens_per_kcycle"]
                / max(host_cell["tokens_per_kcycle"], 1e-9)),
            "batched_over_tat_ttft": (
                tat_cell["ttft"] / max(fused_ref["ttft"], 1e-9)),
        },
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Re-run the first cell's sticky-affinity configuration closed-loop
    with a tracer attached: the exported trace carries host/wire/compute
    lanes plus per-tenant step and token lanes, with the conservation-
    checked cycle attribution and the unified metrics registry embedded."""
    model, params, fns = build_model()
    tenants = make_tenants(model, params, fns, n_tenants=6,
                           max_new=6 if smoke else 10)

    def scenario(tracer):
        cluster = Cluster.uniform(2, {"opengemm": 1}, policy="affinity",
                                  sticky=True, link="noc",
                                  max_contexts=MAX_CONTEXTS, tracer=tracer)
        return ClosedLoopDriver(tenants, cluster).run()

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer cells / shorter generations (CI time budget)")
    ap.add_argument("--out", default="BENCH_serving_bridge.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented closed-loop cell")
    args = ap.parse_args()

    result = run(smoke=args.smoke)
    print(f"# serving bridge: {result['arch']} engines closed-loop over "
          f"{result['link']} fabric, {MAX_SLOTS} slots/engine")
    print("hosts,tenants,policy,tokens,tok_per_kcycle,p99_decode,"
          "config_bytes,elision,parity")
    for cell in result["cells"]:
        for policy in ("affinity", "round_robin"):
            c = cell[policy]
            print(f"{cell['hosts']},{cell['tenants']},{policy},"
                  f"{c['tokens']},{c['tokens_per_kcycle']:.2f},"
                  f"{c['p99_decode']:.0f},{c['config_bytes_sent']},"
                  f"{c['elision_ratio']:.3f},{c['parity_matched']}")
    ab = result["sampling_ab"]
    print("\n# sampling A/B (2 hosts, 6 tenants, sticky affinity)")
    for mode in ("fused", "host"):
        c = ab[mode]
        print(f"{mode},tok_per_kcycle={c['tokens_per_kcycle']:.2f},"
              f"bytes_sent={c['config_bytes_sent']},ttft={c['ttft']:.0f}")
    pf = result["prefill_ab"]
    print("# prefill A/B (fused; chunk=8 vs chunk=1)")
    for mode in ("batched", "token_at_a_time"):
        c = pf[mode]
        print(f"{mode},chunk={c['prefill_chunk']},ttft={c['ttft']:.0f},"
              f"launches={c['launches']}")
    g = result["geomean"]
    print(f"\ngeomean rr/affinity p99 decode  {g['rr_over_affinity_p99_decode']:.2f}x")
    print(f"geomean affinity/rr tokens/kcyc {g['affinity_over_rr_tokens_per_kcycle']:.2f}x")
    print(f"fused/host tokens per kcycle    {g['fused_over_host_tokens_per_kcycle']:.2f}x")
    print(f"tat/batched prefill ttft        {g['batched_over_tat_ttft']:.2f}x")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 4)
    for cell in result["cells"]:
        aff, rr = cell["affinity"], cell["round_robin"]
        assert aff["p99_decode"] < rr["p99_decode"], (
            f"acceptance: sticky affinity must beat round-robin on p99 "
            f"decode latency at every cell; lost at hosts={cell['hosts']} "
            f"tenants={cell['tenants']}: {aff['p99_decode']:.0f} vs "
            f"{rr['p99_decode']:.0f}")
        assert aff["parity_matched"], (
            f"acceptance: bridged config bytes must match "
            f"engine.config_traffic() accounting under sticky routing "
            f"(cell hosts={cell['hosts']} tenants={cell['tenants']})")
    assert g["rr_over_affinity_p99_decode"] > 1.0
    # acceptance (ISSUE 9): fused sampling must improve tokens/kcycle and
    # batched prefill must reduce closed-loop TTFT vs token-at-a-time —
    # both arms parity-matched (asserted inside run())
    assert result["sampling_ab"]["host"]["parity_matched"], (
        "host-sampling arm lost byte-accounting parity")
    assert result["prefill_ab"]["token_at_a_time"]["parity_matched"], (
        "token-at-a-time arm lost byte-accounting parity")
    assert g["fused_over_host_tokens_per_kcycle"] > 1.0, (
        f"acceptance: fused sampling must beat host-side sampling on "
        f"tokens/kcycle, got {g['fused_over_host_tokens_per_kcycle']:.3f}x")
    assert g["batched_over_tat_ttft"] > 1.0, (
        f"acceptance: batched prefill must reduce TTFT vs token-at-a-time, "
        f"got {g['batched_over_tat_ttft']:.3f}x")


if __name__ == "__main__":
    main()
