"""The configuration wall in LM serving: tokens-per-launch sweep.

One decoded token is a tiny macro-operation behind a full host dispatch —
the faster the accelerator, the more configuration-bound single-token decode
becomes (the paper's thesis). Fusing k decode steps into one launch
(``lax.scan`` inside jit) amortizes one configuration over k macro-ops:
I_OC rises ×k and throughput climbs toward the compute roofline, mirroring
Figure 4's rightward escape from the configuration-bound region.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models.model import Model


def run(arch: str = "qwen2-0.5b", batch: int = 4, cache_len: int = 128,
        total_tokens: int = 64, fuse_levels=(1, 2, 4, 8, 16)) -> list[dict]:
    cfg = dataclasses.replace(get(arch).reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    def fused(params, cache, tokens, pos0, k):
        def body(carry, i):
            cache, toks = carry
            logits, cache = model.decode_step(params, cache, toks, pos0 + i)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return (cache, nxt), None
        (cache, toks), _ = jax.lax.scan(
            body, (cache, tokens), jnp.arange(k, dtype=jnp.int32))
        return toks, cache

    step = jax.jit(fused, static_argnames=("k",), donate_argnums=(1,))
    rows = []
    for k in fuse_levels:
        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        toks, cache = step(params, cache, tokens, jnp.int32(0), k)  # warmup+compile
        jax.block_until_ready(toks)

        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        t0 = time.perf_counter()
        pos = 0
        while pos < total_tokens:
            tokens, cache = step(params, cache, tokens, jnp.int32(pos), k)
            pos += k
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        rows.append({
            "tokens_per_launch": k,
            "total_s": dt,
            "tok_per_s": total_tokens * batch / dt,
            "us_per_token": dt / (total_tokens * batch) * 1e6,
        })
    return rows


def main() -> None:
    print("# decode config wall: tokens-per-launch sweep (reduced qwen2-0.5b)")
    print("tokens_per_launch,total_s,tok_per_s,us_per_token")
    for r in run():
        print(f"{r['tokens_per_launch']},{r['total_s']:.4f},"
              f"{r['tok_per_s']:.1f},{r['us_per_token']:.1f}")


if __name__ == "__main__":
    main()
