"""The configuration wall in LM serving: tokens-per-launch sweep.

One decoded token is a tiny macro-operation behind a full host dispatch —
the faster the accelerator, the more configuration-bound single-token decode
becomes (the paper's thesis). Fusing k decode steps into one launch
(``lax.scan`` inside jit) amortizes one configuration over k macro-ops:
I_OC rises ×k and throughput climbs toward the compute roofline, mirroring
Figure 4's rightward escape from the configuration-bound region.

The second sweep attacks the *other* boundary crossing of the k=1 loop:
where the sampled token comes from. Host-side sampling launches one decode,
pulls the full ``(B, vocab)`` logits device→host, and argmaxes on the host —
every step pays a full sync of data that is immediately reduced to B ids.
Fused sampling (``Model.decode_and_sample``, the ``kernels/sampling.py``
epilogue) argmaxes on-device and loops the ids straight back into the next
launch; the host never touches logits. Same launch count, same tokens —
only the per-step sync payload shrinks, which is the serving engine's
default mode (``sampling="fused"``).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models.model import Model

try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def run(arch: str = "qwen2-0.5b", batch: int = 4, cache_len: int = 128,
        total_tokens: int = 64, fuse_levels=(1, 2, 4, 8, 16)) -> list[dict]:
    cfg = dataclasses.replace(get(arch).reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    def fused(params, cache, tokens, pos0, k):
        def body(carry, i):
            cache, toks = carry
            logits, cache = model.decode_step(params, cache, toks, pos0 + i)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return (cache, nxt), None
        (cache, toks), _ = jax.lax.scan(
            body, (cache, tokens), jnp.arange(k, dtype=jnp.int32))
        return toks, cache

    step = jax.jit(fused, static_argnames=("k",), donate_argnums=(1,))
    rows = []
    for k in fuse_levels:
        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        toks, cache = step(params, cache, tokens, jnp.int32(0), k)  # warmup+compile
        jax.block_until_ready(toks)

        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        t0 = time.perf_counter()
        pos = 0
        while pos < total_tokens:
            tokens, cache = step(params, cache, tokens, jnp.int32(pos), k)
            pos += k
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        rows.append({
            "tokens_per_launch": k,
            "total_s": dt,
            "tok_per_s": total_tokens * batch / dt,
            "us_per_token": dt / (total_tokens * batch) * 1e6,
        })
    return rows


def run_sampling_ab(arch: str = "qwen2-0.5b", batch: int = 4,
                    cache_len: int = 128, total_tokens: int = 64,
                    sample_backend: str = "xla") -> list[dict]:
    """Host-side argmax vs the fused on-device sampling epilogue, one
    launch per token in both arms — the A/B isolates the sampling sync."""
    cfg = dataclasses.replace(get(arch).reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    host_step = jax.jit(model.decode_step, donate_argnums=(1,))
    fused_step = jax.jit(
        functools.partial(model.decode_and_sample,
                          sample_backend=sample_backend),
        donate_argnums=(1,))
    no_override = (jnp.zeros((batch,), jnp.int32),
                   jnp.zeros((batch,), bool))

    def run_host():
        cache = model.init_cache(batch, cache_len)
        tok = np.ones((batch, 1), np.int32)
        for pos in range(total_tokens):
            logits, cache = host_step(
                params, cache, jnp.asarray(tok), jnp.int32(pos))
            # the sync: full logits cross the boundary to be argmaxed here
            tok = np.asarray(logits[:, 0], np.float32).argmax(-1) \
                    .astype(np.int32)[:, None]
        return tok

    def run_fused():
        cache = model.init_cache(batch, cache_len)
        ids = jnp.ones((batch, 1), jnp.int32)
        for pos in range(total_tokens):
            # device-resident loopback: only (B,) ids would ever need sync
            ids, cache = fused_step(params, cache, ids, *no_override,
                                    jnp.int32(pos))
        return np.asarray(jax.block_until_ready(ids))

    rows = []
    vocab_bytes = batch * cfg.vocab_size * 2  # bf16 logits
    for mode, fn, sync in (("host", run_host, vocab_bytes),
                           ("fused", run_fused, batch * 4)):
        fn()  # warmup + compile
        t0 = time.perf_counter()
        last = fn()
        dt = time.perf_counter() - t0
        rows.append({
            "sampling": mode,
            "total_s": dt,
            "tok_per_s": total_tokens * batch / dt,
            "sync_bytes_per_step": sync,
            "last_token": [int(t) for t in np.asarray(last).ravel()],
        })
    assert rows[0]["last_token"] == rows[1]["last_token"], \
        "host and fused sampling diverged — the streams must be bit-identical"
    return rows


def export_trace(path: str) -> None:
    """Instrumented simulator analogue of the wall-clock sweep: a
    single-token decode stream is one tiny macro-op behind a full
    per-launch config (k=1, deep inside the config-bound region) — the
    trace shows its host lane captive in config writes, exactly the shape
    the tokens-per-launch fusion escapes."""
    from repro.sched import LaunchRequest, Scheduler

    def scenario(tracer):
        s = Scheduler.from_registry({"opengemm": 1}, link="noc",
                                    tracer=tracer)
        reqs = [
            LaunchRequest("decode", (8, 8, 8),
                          {f"pos{j}": 32 * i + j for j in range(12)},
                          arrival_time=0.0)
            for i in range(24)
        ]
        return s.run_open_loop(reqs)

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None,
                    help="export an instrumented simulator analogue of "
                         "the single-token (k=1) decode stream")
    ap.add_argument("--sample-backend", default="xla",
                    choices=("xla", "pallas", "pallas_interpret"),
                    help="backend for the fused sampling epilogue")
    args = ap.parse_args()
    print("# decode config wall: tokens-per-launch sweep (reduced qwen2-0.5b)")
    print("tokens_per_launch,total_s,tok_per_s,us_per_token")
    for r in run():
        print(f"{r['tokens_per_launch']},{r['total_s']:.4f},"
              f"{r['tok_per_s']:.1f},{r['us_per_token']:.1f}")
    print("# sampling sync A/B: host argmax vs fused epilogue (k=1 launches)")
    print("sampling,total_s,tok_per_s,sync_bytes_per_step")
    for r in run_sampling_ab(sample_backend=args.sample_backend):
        print(f"{r['sampling']},{r['total_s']:.4f},{r['tok_per_s']:.1f},"
              f"{r['sync_bytes_per_step']}")
    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
