"""The configuration wall in LM serving: tokens-per-launch sweep.

One decoded token is a tiny macro-operation behind a full host dispatch —
the faster the accelerator, the more configuration-bound single-token decode
becomes (the paper's thesis). Fusing k decode steps into one launch
(``lax.scan`` inside jit) amortizes one configuration over k macro-ops:
I_OC rises ×k and throughput climbs toward the compute roofline, mirroring
Figure 4's rightward escape from the configuration-bound region.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models.model import Model

try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def run(arch: str = "qwen2-0.5b", batch: int = 4, cache_len: int = 128,
        total_tokens: int = 64, fuse_levels=(1, 2, 4, 8, 16)) -> list[dict]:
    cfg = dataclasses.replace(get(arch).reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    def fused(params, cache, tokens, pos0, k):
        def body(carry, i):
            cache, toks = carry
            logits, cache = model.decode_step(params, cache, toks, pos0 + i)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return (cache, nxt), None
        (cache, toks), _ = jax.lax.scan(
            body, (cache, tokens), jnp.arange(k, dtype=jnp.int32))
        return toks, cache

    step = jax.jit(fused, static_argnames=("k",), donate_argnums=(1,))
    rows = []
    for k in fuse_levels:
        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        toks, cache = step(params, cache, tokens, jnp.int32(0), k)  # warmup+compile
        jax.block_until_ready(toks)

        cache = model.init_cache(batch, cache_len)
        tokens = jnp.ones((batch, 1), jnp.int32)
        t0 = time.perf_counter()
        pos = 0
        while pos < total_tokens:
            tokens, cache = step(params, cache, tokens, jnp.int32(pos), k)
            pos += k
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        rows.append({
            "tokens_per_launch": k,
            "total_s": dt,
            "tok_per_s": total_tokens * batch / dt,
            "us_per_token": dt / (total_tokens * batch) * 1e6,
        })
    return rows


def export_trace(path: str) -> None:
    """Instrumented simulator analogue of the wall-clock sweep: a
    single-token decode stream is one tiny macro-op behind a full
    per-launch config (k=1, deep inside the config-bound region) — the
    trace shows its host lane captive in config writes, exactly the shape
    the tokens-per-launch fusion escapes."""
    from repro.sched import LaunchRequest, Scheduler

    def scenario(tracer):
        s = Scheduler.from_registry({"opengemm": 1}, link="noc",
                                    tracer=tracer)
        reqs = [
            LaunchRequest("decode", (8, 8, 8),
                          {f"pos{j}": 32 * i + j for j in range(12)},
                          arrival_time=0.0)
            for i in range(24)
        ]
        return s.run_open_loop(reqs)

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None,
                    help="export an instrumented simulator analogue of "
                         "the single-token (k=1) decode stream")
    args = ap.parse_args()
    print("# decode config wall: tokens-per-launch sweep (reduced qwen2-0.5b)")
    print("tokens_per_launch,total_s,tok_per_s,us_per_token")
    for r in run():
        print(f"{r['tokens_per_launch']},{r['total_s']:.4f},"
              f"{r['tok_per_s']:.1f},{r['us_per_token']:.1f}")
    if args.trace_out:
        export_trace(args.trace_out)


if __name__ == "__main__":
    main()
