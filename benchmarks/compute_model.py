"""Compute-model benchmark: autotuned overlap knobs vs hand-picked defaults.

The calibrated analytical cost model (``engine.costmodel``) prices each
launch's compute from its kernel shape instead of a flat per-launch
constant, and the overlap autotuner (``engine.autotune``) reads the
predicted wire/compute ratio per link class to pick ``overlap`` and
``staging_buffers``. This benchmark A/Bs three knob arms over the same
**compute intensity × link class** grid as ``config_overlap.py`` (single
OpenGeMM-like device, descriptor-heavy launches):

* ``default`` — the scheduler's constructor defaults (serialized
  configuration, 2 staging buffers): what a user gets with no tuning;
* ``handpicked`` — the hand-picked overlap knobs every committed BENCH
  uses (``overlap="overlapped"``, ``staging_buffers=2``);
* ``autotuned`` — ``engine.autotune.tune()``'s choice per cell, driven by
  the calibrated model's predicted compute interval against the link's
  transfer plan.

All three arms price compute through the same calibrated model, so the
makespans are directly comparable and only the knobs differ. Acceptance
(asserted below, ISSUE 10): autotuned **matches or beats both arms in
every cell** (the autotuner may only pick serialized where nothing can
hide — where the arms tie bit-exactly — and more buffers where the wire
outruns compute, which is pinned monotone in ``tests/test_engine.py``).

A **closed-loop cell** replays the serving bridge (real JAX decode steps,
two tenants on one PCIe host) under default vs autotuned knobs and reads
the win off ``tokens_per_kcycle`` — the feedback metric open-loop
makespans cannot show.

A **flat-compat pin** asserts the cost model is strictly opt-in: every
spelling of flat mode (``None`` — the default everywhere — ``"flat"``,
``ComputeModel.flat()``) is **bit-identical** across the grid, and the
``config_overlap`` smoke sweep re-run in-process (it never opts in)
still clears every committed geomean floor. (The serving-bridge twin of
this pin is CI's own ``serving_bridge.py`` run: that benchmark never
opts in either, so its floors gate the same property.)

Emits ``BENCH_compute_model.json`` (with a ``geomean`` summary).

Usage: ``PYTHONPATH=src python benchmarks/compute_model.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core.accelerators import REGISTRY
from repro.core.roofline import predicted_roofline_point
from repro.engine import ComputeModel, tune
from repro.sched import LaunchRequest, Scheduler, geomean

N_FIELDS = 48  # advancing register fields per launch (descriptor-heavy)
INTENSITIES = {  # label -> GEMM dims; ops = 2*M*K*N on a 1024 ops/cycle datapath
    "tiny": (8, 8, 8),
    "low": (16, 16, 16),
    "mid": (24, 24, 24),
    "high": (32, 32, 32),
    "huge": (64, 64, 64),
}
LINKS = ("csr", "noc", "pcie")
ACCEL = "opengemm"


def stream(dims, n: int) -> list[LaunchRequest]:
    return [
        LaunchRequest("t0", dims, {f"p{j}": 64 * i + j for j in range(N_FIELDS)},
                      kernel="matmul")
        for i in range(n)
    ]


def run_arm(link: str, dims, n: int, *, overlap: str, staging_buffers: int,
            transport: str = "auto") -> dict:
    s = Scheduler.from_registry({ACCEL: 1}, link=link, overlap=overlap,
                                staging_buffers=staging_buffers,
                                transport=transport,
                                compute_model="calibrated")
    rep = s.run(stream(dims, n))
    return {
        "overlap": overlap,
        "staging_buffers": staging_buffers,
        "makespan": rep.makespan,
        "config_cycles": rep.config_cycles,
        "exposed_config_cycles": rep.exposed_config_cycles,
    }


def run_cell(cm: ComputeModel, link: str, label: str, n: int) -> dict:
    dims = INTENSITIES[label]
    knobs = tune(REGISTRY[ACCEL], link, dims, N_FIELDS, kernel="matmul",
                 compute_model=cm)
    default = run_arm(link, dims, n, overlap="serialized", staging_buffers=2)
    handpicked = run_arm(link, dims, n, overlap="overlapped",
                         staging_buffers=2)
    autotuned = run_arm(link, dims, n, **knobs.scheduler_kwargs())
    model = REGISTRY[ACCEL]
    point = predicted_roofline_point(
        f"{link}/{label}",
        ops=2 * dims[0] * dims[1] * dims[2],
        config_bytes=N_FIELDS * model.bytes_per_field,
        compute_cycles=knobs.compute_cycles,
        config_cycles=max(knobs.wire_cycles, 1e-12),
        p_peak=model.p_peak,
        concurrent=model.concurrent,
    )
    return {
        "link": link,
        "intensity": label,
        "dims": list(dims),
        "knobs": {
            "overlap": knobs.overlap,
            "staging_buffers": knobs.staging_buffers,
            "transport": knobs.transport,
            "xfer_mode": knobs.xfer_mode,
            "reason": knobs.reason,
        },
        "predicted": {
            "wire_cycles": knobs.wire_cycles,
            "compute_cycles": knobs.compute_cycles,
            "wire_over_compute": knobs.ratio,
            "i_oc": point.i_oc,
            "performance": point.performance,
            "bound": point.bound,
        },
        "default": default,
        "handpicked": handpicked,
        "autotuned": autotuned,
        "default_over_autotuned": default["makespan"] / autotuned["makespan"],
        "handpicked_over_autotuned": (handpicked["makespan"]
                                      / autotuned["makespan"]),
    }


def closed_loop(smoke: bool) -> dict:
    """Default vs autotuned knobs under the real serving bridge: two
    tenant engines closed-loop on one PCIe host, tokens/kcycle as the
    metric. Import inside so the open-loop sweep stays jax-free."""
    import dataclasses

    import jax

    from repro.bridge import ClosedLoopDriver, TenantEngine
    from repro.bridge.tenant import decode_tile
    from repro.cluster import Cluster
    from repro.configs import get
    from repro.models.model import Model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    decode_fn = ServingEngine.compile_decode(model, sampling="fused")
    prefill_fn = ServingEngine.compile_prefill(model)
    max_new = 4 if smoke else 8

    def tenants() -> list[TenantEngine]:
        out = []
        for i in range(2):
            eng = ServingEngine(model, params, max_slots=4, max_len=64,
                                decode_fn=decode_fn, prefill_fn=prefill_fn,
                                sampling="fused", prefill_chunk=2)
            for uid, prompt in enumerate([[3 + i, 5, 2], [7, 1 + i]]):
                eng.submit(Request(uid=uid, prompt=prompt,
                                   max_new_tokens=max_new))
            out.append(TenantEngine(f"t{i}", eng, accel=ACCEL))
        return out

    # autotune on the decode tile; n_fields approximates one decode
    # descriptor's register count (exact counts only shift the predicted
    # ratio, not its regime on a PCIe wire)
    dims = decode_tile(tenants()[0].engine)
    knobs = tune(REGISTRY[ACCEL], "pcie", dims, 16, kernel="decode",
                 compute_model=ComputeModel.calibrated())

    def run_with(**kw) -> dict:
        cluster = Cluster.uniform(1, {ACCEL: 1}, sticky=True, link="pcie",
                                  compute_model="calibrated", **kw)
        rep = ClosedLoopDriver(tenants(), cluster).run()
        return {"tokens": rep.tokens,
                "tokens_per_kcycle": rep.tokens_per_kcycle,
                "makespan": rep.cluster.makespan}

    default = run_with()  # serialized / 2 buffers
    tuned_kw = knobs.scheduler_kwargs()
    tuned_kw.pop("transport")  # Cluster.uniform default "auto" == tuned
    autotuned = run_with(overlap=tuned_kw["overlap"],
                         staging_buffers=tuned_kw["staging_buffers"])
    return {
        "decode_dims": list(dims),
        "knobs": {"overlap": knobs.overlap,
                  "staging_buffers": knobs.staging_buffers,
                  "reason": knobs.reason},
        "default": default,
        "autotuned": autotuned,
        "tokens_per_kcycle_gain": (autotuned["tokens_per_kcycle"]
                                   / default["tokens_per_kcycle"]),
    }


def flat_compat() -> dict:
    """The flat-constant compat pin, two halves:

    * **identity** — ``compute_model=None`` (the default everywhere),
      ``"flat"``, and an explicit ``ComputeModel.flat()`` produce
      bit-identical makespans over the whole link × intensity grid: the
      cost model is opt-in and the legacy path is literally untouched;
    * **committed floors** — the config_overlap smoke sweep re-run
      in-process (it never opts in) still clears every committed geomean
      floor in ``benchmarks/geomean_baseline.json``: the numbers every
      prior PR pinned survive this one unchanged.
    """
    try:
        from benchmarks import config_overlap
    except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
        import config_overlap

    def makespan(link, dims, spec) -> float:
        s = Scheduler.from_registry({ACCEL: 1}, link=link,
                                    overlap="overlapped",
                                    compute_model=spec)
        return s.run(stream(dims, 8)).makespan

    identical = all(
        makespan(link, dims, None)
        == makespan(link, dims, "flat")
        == makespan(link, dims, ComputeModel.flat())
        for link in LINKS for dims in INTENSITIES.values()
    )
    floors = json.loads(
        (Path(__file__).parent / "geomean_baseline.json").read_text()
    )["config_overlap"]
    fresh = config_overlap.run(smoke=True)["geomean"]
    floors_ok = all(fresh[key] >= floor for key, floor in floors.items())
    return {
        "identical": identical,
        "floors": floors,
        "fresh": fresh,
        "floors_ok": floors_ok,
    }


def run(smoke: bool = False) -> dict:
    n = 8 if smoke else 24
    labels = ("low", "mid", "huge") if smoke else tuple(INTENSITIES)
    cm = ComputeModel.calibrated()
    cells = [run_cell(cm, link, label, n)
             for link in LINKS for label in labels]
    cl = closed_loop(smoke)
    summary = {
        "default_over_autotuned_makespan": geomean(
            [c["default_over_autotuned"] for c in cells]),
        "handpicked_over_autotuned_makespan": geomean(
            [c["handpicked_over_autotuned"] for c in cells]),
        "tokens_per_kcycle_gain": cl["tokens_per_kcycle_gain"],
    }
    return {
        "benchmark": "compute_model",
        "smoke": smoke,
        "n_launches": n,
        "n_fields": N_FIELDS,
        "calibration": {k: f.as_dict() for k, f in sorted(cm.fits.items())},
        "cells": cells,
        "closed_loop": cl,
        "flat_compat": flat_compat(),
        "geomean": summary,
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Re-run the most autotune-sensitive cell (PCIe, mid intensity,
    autotuned knobs) instrumented and export its trace + attribution."""
    n = 8 if smoke else 24
    knobs = tune(REGISTRY[ACCEL], "pcie", INTENSITIES["mid"], N_FIELDS,
                 compute_model=ComputeModel.calibrated())

    def scenario(tracer):
        s = Scheduler.from_registry({ACCEL: 1}, link="pcie",
                                    compute_model="calibrated",
                                    tracer=tracer,
                                    **knobs.scheduler_kwargs())
        return s.run(stream(INTENSITIES["mid"], n))

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer launches / intensities (CI time budget)")
    ap.add_argument("--out", default="BENCH_compute_model.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented representative cell")
    args = ap.parse_args()

    result = run(smoke=args.smoke)

    print("# autotuned overlap knobs vs defaults (calibrated compute model)")
    print("link,intensity,default,handpicked,autotuned,knobs,wire/compute")
    for c in result["cells"]:
        k = c["knobs"]
        print(f"{c['link']},{c['intensity']},{c['default']['makespan']:.1f},"
              f"{c['handpicked']['makespan']:.1f},"
              f"{c['autotuned']['makespan']:.1f},"
              f"{k['overlap']}/{k['staging_buffers']},"
              f"{c['predicted']['wire_over_compute']:.2f}")

    cl = result["closed_loop"]
    print(f"\n# closed loop (pcie, 2 tenants): default "
          f"{cl['default']['tokens_per_kcycle']:.3f} vs autotuned "
          f"{cl['autotuned']['tokens_per_kcycle']:.3f} tokens/kcycle "
          f"({cl['tokens_per_kcycle_gain']:.2f}x, knobs "
          f"{cl['knobs']['overlap']}/{cl['knobs']['staging_buffers']})")

    g = result["geomean"]
    print(f"\ngeomean: default/autotuned {g['default_over_autotuned_makespan']:.2f}x, "
          f"handpicked/autotuned {g['handpicked_over_autotuned_makespan']:.2f}x, "
          f"closed-loop tokens/kcycle gain {g['tokens_per_kcycle_gain']:.2f}x")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 10)
    eps = 1e-9
    for c in result["cells"]:
        auto = c["autotuned"]["makespan"]
        # autotuned knobs match or beat both arms in EVERY cell
        assert auto <= c["default"]["makespan"] + eps, c
        assert auto <= c["handpicked"]["makespan"] + eps, c
        if c["link"] == "csr":
            # nothing to hide on a core-local port: the tuner must say so
            assert c["knobs"]["overlap"] == "serialized", c
    assert result["geomean"]["default_over_autotuned_makespan"] >= 1.0 - eps
    assert result["geomean"]["handpicked_over_autotuned_makespan"] >= 1.0 - eps
    # the closed loop never loses tokens/kcycle under autotuned knobs,
    # and token output is identical (knobs shift cycles, never tokens)
    assert result["closed_loop"]["tokens_per_kcycle_gain"] >= 1.0 - eps
    assert (result["closed_loop"]["autotuned"]["tokens"]
            == result["closed_loop"]["default"]["tokens"])
    # flat-constant compat: the legacy path is bit-identical under every
    # spelling of "flat", and the committed geomean floors still hold
    assert result["flat_compat"]["identical"], result["flat_compat"]
    assert result["flat_compat"]["floors_ok"], result["flat_compat"]


if __name__ == "__main__":
    main()
