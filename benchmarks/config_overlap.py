"""Engine benchmark: runtime config overlap — serialized vs. double-buffered.

Sweeps **compute intensity × link class × overlap mode** on a single
concurrent-configuration (OpenGeMM-like) device behind each fabric:

* *Compute intensity* scales the macro-op (GEMM dims) while the launch's
  write plan stays descriptor-heavy (48 advancing register fields), moving
  the workload from configuration-bound (tiny tiles: the wire dominates)
  through the balanced ridge to compute-bound (large tiles: the datapath
  dominates and the staging ring already hides config).
* *Link class* prices the wire: the core-local CSR port has nothing to
  hide (overlapped ≡ serialized, bit-exactly); NoC and PCIe carry real
  burst-DMA time that the overlapped engine streams behind compute.

Per cell the serialized engine keeps the host captive for its transfers'
wire time (T_set fully exposed, Eq. 4's worst case), while the overlapped
engine releases the host at descriptor enqueue and double-buffers the DMA
behind the previous launch's compute — the §5.5 compiler pass replayed at
dispatch time. The sweep shows the characteristic shape: the win peaks
where wire time and compute time are comparable (neither resource can hide
inside the other under serialization) and tapers at both ends.

Also reported per cell: exposed vs. hidden config cycles and the
overlap-adjusted roofline point (BW_cfg over *exposed* T_set only — the
ridge shifts left as config hides). A contention section prices the shared
cluster LinkPort (two hosts behind one PCIe switch vs. private wires).

Acceptance (asserted below, ISSUE 5):
* overlapped makespan ≤ serialized makespan in **every** cell (the CI gate
  re-checks this from the JSON);
* geomean makespan reduction > 1x over the NoC and PCIe cells;
* CSR cells identical across modes (nothing to hide, bit-exact);
* per-resource busy cycles conserved between modes in every cell;
* the overlap-adjusted roofline's BW_cfg ≥ the serialized one wherever
  cycles hid.

Emits ``BENCH_config_overlap.json`` (with a ``geomean`` summary).

Usage: ``PYTHONPATH=src python benchmarks/config_overlap.py [--smoke] [--out F]``
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.cluster import Cluster
from repro.sched import LaunchRequest, Scheduler, geomean

N_FIELDS = 48  # advancing register fields per launch (descriptor-heavy)
INTENSITIES = {  # label -> GEMM dims; ops = 2*M*K*N on a 1024 ops/cycle datapath
    "tiny": (8, 8, 8),
    "low": (16, 16, 16),
    "mid": (24, 24, 24),
    "high": (32, 32, 32),
    "huge": (64, 64, 64),
}
LINKS = ("csr", "noc", "pcie")
MODES = ("serialized", "overlapped")


def stream(dims, n: int) -> list[LaunchRequest]:
    return [
        LaunchRequest("t0", dims, {f"p{j}": 64 * i + j for j in range(N_FIELDS)})
        for i in range(n)
    ]


def run_cell(link: str, dims, mode: str, n: int) -> dict:
    s = Scheduler.from_registry({"opengemm": 1}, link=link, overlap=mode)
    rep = s.run(stream(dims, n))
    host = rep.resources["host"]
    wire = next(t for t in rep.resources.values() if t.kind == "wire")
    compute = next(t for t in rep.resources.values() if t.kind == "compute")
    point = None
    for dev in rep.devices.values():
        from repro.core.roofline import overlap_roofline_point

        point = overlap_roofline_point(
            f"{link}/{mode}",
            total_ops=dev.total_ops,
            config_bytes=max(dev.bytes_sent, 1),
            exposed_cycles=dev.exposed_config_cycles,
            makespan=rep.makespan,
            p_peak=dev.model.p_peak,
        )
    return {
        "makespan": rep.makespan,
        "config_cycles": rep.config_cycles,
        "exposed_config_cycles": rep.exposed_config_cycles,
        "hidden_config_cycles": rep.hidden_config_cycles,
        "hidden_fraction": rep.overlap_summary()["hidden_fraction"],
        "host_busy": host.busy_cycles,
        "wire_busy": wire.busy_cycles,
        "compute_busy": compute.busy_cycles,
        "bytes_sent": rep.bytes_sent,
        "bw_config_exposed": point.bw_config,
        "ridge_i_oc": point.p_peak / point.bw_config,
    }


def sweep(n: int, intensities) -> list[dict]:
    cells = []
    for link in LINKS:
        for label in intensities:
            dims = INTENSITIES[label]
            by_mode = {mode: run_cell(link, dims, mode, n) for mode in MODES}
            ser, ov = by_mode["serialized"], by_mode["overlapped"]
            cells.append({
                "link": link,
                "intensity": label,
                "dims": list(dims),
                "serialized": ser,
                "overlapped": ov,
                "speedup": ser["makespan"] / ov["makespan"],
            })
    return cells


def contention(n: int) -> dict:
    """Two hosts behind one shared PCIe switch vs. private wires: the
    shared port serializes both hosts' transfers on one resource, so
    completion can only move later — never earlier."""
    reqs = [LaunchRequest(f"t{i % 2}", (16, 16, 16),
                          {f"p{j}": 64 * i + j for j in range(N_FIELDS)},
                          arrival_time=float(5 * i)) for i in range(n)]

    def makespan(shared: bool) -> float:
        cl = Cluster.uniform(2, {"opengemm": 1}, policy="round_robin",
                             link="pcie", overlap="overlapped",
                             shared_port=shared)
        return cl.run(list(reqs)).makespan

    private, shared = makespan(False), makespan(True)
    return {"private_makespan": private, "shared_makespan": shared,
            "contention_slowdown": shared / private}


def run(smoke: bool = False) -> dict:
    n = 8 if smoke else 24
    intensities = ("low", "mid", "huge") if smoke else tuple(INTENSITIES)
    cells = sweep(n, intensities)
    fabric = [c for c in cells if c["link"] != "csr"]
    summary = {
        "serialized_over_overlapped_makespan": geomean(
            [c["speedup"] for c in fabric]),
        "noc_speedup": geomean(
            [c["speedup"] for c in fabric if c["link"] == "noc"]),
        "pcie_speedup": geomean(
            [c["speedup"] for c in fabric if c["link"] == "pcie"]),
        "hidden_fraction": geomean(
            [c["overlapped"]["hidden_fraction"] for c in fabric]),
        "bw_config_gain_exposed": geomean(
            [c["overlapped"]["bw_config_exposed"]
             / c["serialized"]["bw_config_exposed"] for c in fabric]),
    }
    return {
        "benchmark": "config_overlap",
        "smoke": smoke,
        "n_launches": n,
        "n_fields": N_FIELDS,
        "cells": cells,
        "contention": contention(n),
        "geomean": summary,
    }


try:
    from benchmarks.trace_util import export_trace as _export
except ImportError:  # executed as a script: benchmarks/ is sys.path[0]
    from trace_util import export_trace as _export


def export_trace(path: str, smoke: bool) -> None:
    """Re-run the sweep's most overlap-sensitive cell (PCIe, mid intensity,
    overlapped) instrumented and export its trace + cycle attribution."""
    n = 8 if smoke else 24

    def scenario(tracer):
        s = Scheduler.from_registry({"opengemm": 1}, link="pcie",
                                    overlap="overlapped", tracer=tracer)
        return s.run(stream(INTENSITIES["mid"], n))

    _export(path, scenario)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer launches / intensities (CI time budget)")
    ap.add_argument("--out", default="BENCH_config_overlap.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write a Perfetto/chrome-trace JSON of one "
                         "instrumented representative cell")
    args = ap.parse_args()

    result = run(smoke=args.smoke)

    print("# runtime config overlap: serialized vs double-buffered makespan")
    print("link,intensity,serialized,overlapped,speedup,hidden/config")
    for c in result["cells"]:
        ov = c["overlapped"]
        print(f"{c['link']},{c['intensity']},{c['serialized']['makespan']:.1f},"
              f"{ov['makespan']:.1f},{c['speedup']:.2f}x,"
              f"{ov['hidden_config_cycles']:.0f}/{ov['config_cycles']:.0f}")

    ct = result["contention"]
    print(f"\n# shared PCIe switch (2 hosts): private {ct['private_makespan']:.1f}"
          f" vs shared {ct['shared_makespan']:.1f}"
          f" ({ct['contention_slowdown']:.2f}x slower — contention priced)")

    g = result["geomean"]
    print(f"\ngeomean: serialized/overlapped makespan "
          f"{g['serialized_over_overlapped_makespan']:.2f}x "
          f"(noc {g['noc_speedup']:.2f}x, pcie {g['pcie_speedup']:.2f}x), "
          f"exposed-BW_cfg gain {g['bw_config_gain_exposed']:.2f}x")

    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2, sort_keys=True))
    print(f"wrote {out}")

    if args.trace_out:
        export_trace(args.trace_out, smoke=args.smoke)

    # acceptance (ISSUE 5): overlap never regresses, strictly wins on fabric
    for c in result["cells"]:
        ser, ov = c["serialized"], c["overlapped"]
        assert ov["makespan"] <= ser["makespan"], c
        # conservation: only placement moves — total work per resource fixed
        for key in ("host_busy", "wire_busy", "compute_busy", "bytes_sent",
                    "config_cycles"):
            assert abs(ov[key] - ser[key]) < 1e-6, (key, c)
        if c["link"] == "csr":
            # nothing to hide on a core-local port: bit-identical
            assert ov["makespan"] == ser["makespan"], c
            assert ov["hidden_config_cycles"] == 0.0, c
        else:
            # the overlap-adjusted roofline reflects only exposed T_set
            assert ov["exposed_config_cycles"] < ov["config_cycles"], c
            assert ov["bw_config_exposed"] > ser["bw_config_exposed"], c
            assert ov["ridge_i_oc"] < ser["ridge_i_oc"], c
    assert result["geomean"]["serialized_over_overlapped_makespan"] > 1.0
    assert result["geomean"]["noc_speedup"] > 1.0
    assert result["geomean"]["pcie_speedup"] > 1.0
    # shared-port contention is real and never negative
    assert result["contention"]["contention_slowdown"] >= 1.0


if __name__ == "__main__":
    main()
