"""CI observability gate — conservation, trace schema, geomean floors.

Three checks over the artifacts the bench-smoke job just produced, all
stdlib-only so the gate can run before (or without) the repo's deps:

1. **Trace schema** — every ``TRACE_*.json`` must be a loadable Chrome
   Trace Event Format document (the same invariants
   ``repro.obs.export.validate_trace`` enforces at write time, re-checked
   here from the serialized artifact so a drifting exporter cannot pass
   its own test).
2. **Conservation** — each trace's embedded cycle attribution must satisfy
   the invariant: worst per-lane residual (|classified − occupancy-union|
   as a fraction of makespan) at most ``MAX_RESIDUAL`` (0.1%). A residual
   means a lane has cycles that were dropped or double-booked — exactly
   the failure mode that lets configuration cost hide from profilers.
3. **Geomean floors** — every ``BENCH_*.json`` ``geomean`` key is compared
   against ``benchmarks/geomean_baseline.json`` (committed floors = 0.9 ×
   the seeded smoke values; every key is higher-is-better). A key below
   its floor, or a baselined key missing from the artifact, fails.

Usage: ``python benchmarks/obs_gate.py [--dir .]``
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

MAX_RESIDUAL = 1e-3  # worst lane residual / makespan the gate tolerates

EVENT_REQUIRED = {
    "X": ("name", "cat", "ph", "ts", "dur", "pid", "tid"),
    "i": ("name", "ph", "ts", "pid", "tid"),
    "C": ("name", "ph", "ts", "pid", "tid", "args"),
    "M": ("name", "ph", "pid"),
}


def check_trace(path: str) -> list[str]:
    problems: list[str] = []
    try:
        doc = json.load(open(path))
    except (OSError, ValueError) as exc:
        return [f"{path}: unreadable ({exc})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents"]
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        required = EVENT_REQUIRED.get(ph)
        if required is None:
            problems.append(f"{path}: event {i} has unknown ph {ph!r}")
            continue
        missing = [k for k in required if k not in ev]
        if missing:
            problems.append(f"{path}: event {i} ({ph}) missing {missing}")
        if ph == "X" and ev.get("dur", 0) < 0:
            problems.append(f"{path}: event {i} has negative dur")
    lanes = {(ev["pid"], ev["tid"]) for ev in events if ev.get("ph") == "X"}
    if not lanes:
        problems.append(f"{path}: no span lanes")

    att = doc.get("attribution")
    if att is None:
        problems.append(f"{path}: no embedded attribution")
    else:
        residual = att.get("max_residual")
        if residual is None:
            problems.append(f"{path}: attribution has no max_residual")
        elif residual > MAX_RESIDUAL:
            problems.append(
                f"{path}: conservation drifted — max lane residual "
                f"{residual:.3e} > {MAX_RESIDUAL:.0e} of makespan")
        for name, lane in att.get("lanes", {}).items():
            if lane["components"].get("idle", 0.0) < -1e-9:
                problems.append(f"{path}: lane {name} has negative idle")
    return problems


def check_geomeans(bench_paths: list[str], baseline_path: str) -> list[str]:
    problems: list[str] = []
    baseline = json.load(open(baseline_path))
    seen: set[str] = set()
    for path in bench_paths:
        doc = json.load(open(path))
        name = doc.get("benchmark")
        floors = baseline.get(name)
        if floors is None:
            continue  # benches without committed floors only need the key
        seen.add(name)
        geomean = doc.get("geomean", {})
        for key, floor in sorted(floors.items()):
            got = geomean.get(key)
            if got is None:
                problems.append(f"{path}: geomean key {key!r} disappeared "
                                f"(baseline floor {floor})")
            elif got < floor:
                problems.append(f"{path}: geomean {key} = {got:.4f} below "
                                f"committed floor {floor:.4f}")
    for name in sorted(set(baseline) - seen):
        problems.append(f"baselined benchmark {name!r} produced no "
                        f"BENCH artifact")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding TRACE_*.json / BENCH_*.json")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "geomean_baseline.json"))
    args = ap.parse_args()

    traces = sorted(glob.glob(os.path.join(args.dir, "TRACE_*.json")))
    benches = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not traces:
        sys.exit(f"obs gate: no TRACE_*.json artifacts in {args.dir}")

    problems: list[str] = []
    for path in traces:
        problems += check_trace(path)
    problems += check_geomeans(benches, args.baseline)

    if problems:
        print("\n".join(problems))
        sys.exit(f"obs gate: {len(problems)} problem(s)")
    print(f"obs gate ok: {len(traces)} trace(s) schema-valid, conservation "
          f"within {MAX_RESIDUAL:.0e}; geomean floors held across "
          f"{len(benches)} bench artifact(s)")


if __name__ == "__main__":
    main()
