"""CI observability gate — conservation, trace schema, geomean floors.

Three checks over the artifacts the bench-smoke job just produced, all
stdlib-only so the gate can run before (or without) the repo's deps:

1. **Trace schema** — every ``TRACE_*.json`` must be a loadable Chrome
   Trace Event Format document (the same invariants
   ``repro.obs.export.validate_trace`` enforces at write time, re-checked
   here from the serialized artifact so a drifting exporter cannot pass
   its own test).
2. **Conservation** — each trace's embedded cycle attribution must satisfy
   the invariant: worst per-lane residual (|classified − occupancy-union|
   as a fraction of makespan) at most ``MAX_RESIDUAL`` (0.1%). A residual
   means a lane has cycles that were dropped or double-booked — exactly
   the failure mode that lets configuration cost hide from profilers.
3. **Geomean floors** — every ``BENCH_*.json`` ``geomean`` key is compared
   against ``benchmarks/geomean_baseline.json`` (committed floors = 0.9 ×
   the seeded smoke values; every key is higher-is-better). A key below
   its floor, or a baselined key missing from the artifact, fails.

When a floor fails for a bench listed under the baseline's
``_recorded_traces`` map, the gate additionally runs the differential
doctor (``src/repro/obs/diff.py``, loaded by file path — it is standalone
stdlib) between the committed known-good trace and the just-produced
``TRACE_<name>.json``, and writes the decomposition to
``DIAG_<name>.json`` so CI uploads *why* the regression happened, not
just that it did.

Usage: ``python benchmarks/obs_gate.py [--dir .]``
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

MAX_RESIDUAL = 1e-3  # worst lane residual / makespan the gate tolerates

# every closed-loop step span must carry these (driver-emitted) tags
STEP_SPAN_ARGS = ("tenant", "tokens", "launches", "prefill_launches")

EVENT_REQUIRED = {
    "X": ("name", "cat", "ph", "ts", "dur", "pid", "tid"),
    "i": ("name", "ph", "ts", "pid", "tid"),
    "C": ("name", "ph", "ts", "pid", "tid", "args"),
    "M": ("name", "ph", "pid"),
}


def check_trace(path: str) -> list[str]:
    problems: list[str] = []
    try:
        doc = json.load(open(path))
    except (OSError, ValueError) as exc:
        return [f"{path}: unreadable ({exc})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents"]
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        required = EVENT_REQUIRED.get(ph)
        if required is None:
            problems.append(f"{path}: event {i} has unknown ph {ph!r}")
            continue
        missing = [k for k in required if k not in ev]
        if missing:
            problems.append(f"{path}: event {i} ({ph}) missing {missing}")
        if ph == "X" and ev.get("dur", 0) < 0:
            problems.append(f"{path}: event {i} has negative dur")
        if ph == "X" and ev.get("cat") == "step":
            # closed-loop step spans must stay attributable: token count,
            # launch fan-out, and the prefill/decode split are what the
            # serving dashboards (TTFT, launches-per-token) are built from
            missing = [k for k in STEP_SPAN_ARGS
                       if k not in ev.get("args", {})]
            if missing:
                problems.append(
                    f"{path}: step span {i} missing args {missing}")
    lanes = {(ev["pid"], ev["tid"]) for ev in events if ev.get("ph") == "X"}
    if not lanes:
        problems.append(f"{path}: no span lanes")

    att = doc.get("attribution")
    if att is None:
        problems.append(f"{path}: no embedded attribution")
    else:
        residual = att.get("max_residual")
        if residual is None:
            problems.append(f"{path}: attribution has no max_residual")
        elif residual > MAX_RESIDUAL:
            problems.append(
                f"{path}: conservation drifted — max lane residual "
                f"{residual:.3e} > {MAX_RESIDUAL:.0e} of makespan")
        for name, lane in att.get("lanes", {}).items():
            if lane["components"].get("idle", 0.0) < -1e-9:
                problems.append(f"{path}: lane {name} has negative idle")

    energy = doc.get("energy")
    if energy is not None:
        # joule edition of the same conservation invariant: per-lane
        # components must sum to the independently metered lane total
        residual = energy.get("max_residual")
        if residual is None:
            problems.append(f"{path}: energy block has no max_residual")
        elif residual > MAX_RESIDUAL:
            problems.append(
                f"{path}: energy conservation drifted — max lane residual "
                f"{residual:.3e} > {MAX_RESIDUAL:.0e} of lane energy")
        for name, lane in energy.get("lanes", {}).items():
            for comp, val in lane.get("components", {}).items():
                if val < -1e-9:
                    problems.append(
                        f"{path}: energy lane {name} has negative "
                        f"{comp} ({val})")
    return problems


def check_geomeans(bench_paths: list[str], baseline_path: str,
                   artifact_dir: str = ".") -> list[str]:
    problems: list[str] = []
    baseline = json.load(open(baseline_path))
    regressed: set[str] = set()
    seen: set[str] = set()
    for path in bench_paths:
        doc = json.load(open(path))
        name = doc.get("benchmark")
        floors = baseline.get(name)
        if floors is None:
            continue  # benches without committed floors only need the key
        seen.add(name)
        geomean = doc.get("geomean", {})
        for key, floor in sorted(floors.items()):
            got = geomean.get(key)
            if got is None:
                problems.append(f"{path}: geomean key {key!r} disappeared "
                                f"(baseline floor {floor})")
            elif got < floor:
                problems.append(f"{path}: geomean {key} = {got:.4f} below "
                                f"committed floor {floor:.4f}")
                regressed.add(name)
    for name in sorted(set(baseline) - seen):
        if name.startswith("_"):
            continue  # metadata keys (e.g. _recorded_traces), not benches
        problems.append(f"baselined benchmark {name!r} produced no "
                        f"BENCH artifact")
    problems += diagnose_regressions(regressed, baseline, baseline_path,
                                     artifact_dir)
    return problems


def diagnose_regressions(regressed: set[str], baseline: dict,
                         baseline_path: str, artifact_dir: str) -> list[str]:
    """For each floor-failing bench with a committed known-good trace, run
    the differential doctor and leave DIAG_<name>.json next to the
    artifacts. Diagnosis failures are reported but never mask the floor
    failure itself."""
    notes: list[str] = []
    recorded = baseline.get("_recorded_traces", {})
    for name in sorted(regressed & set(recorded)):
        good = os.path.join(os.path.dirname(baseline_path), recorded[name])
        bad = os.path.join(artifact_dir, f"TRACE_{name}.json")
        out = os.path.join(artifact_dir, f"DIAG_{name}.json")
        try:
            d = _load_diff().diff(json.load(open(good)), json.load(open(bad)))
            with open(out, "w") as f:
                json.dump(d, f, indent=2, sort_keys=True)
            top = d["ranked"][0] if d["ranked"] else None
            culprit = (f"{top['lane']}:{top['component']} "
                       f"{top['delta']:+.1f}" if top else "no lane delta")
            notes.append(f"  wrote {out} (vs {recorded[name]}; makespan "
                         f"{d['makespan']['delta']:+.1f}, top {culprit})")
        except (OSError, ValueError, KeyError) as exc:
            notes.append(f"  diff of {name!r} vs {recorded[name]} "
                         f"failed: {exc}")
    return notes


def _load_diff():
    """Import repro.obs.diff by file path — the gate stays runnable without
    PYTHONPATH or the repo's deps (diff.py is standalone stdlib)."""
    import importlib.util

    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "src", "repro", "obs", "diff.py")
    spec = importlib.util.spec_from_file_location("_obs_diff", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding TRACE_*.json / BENCH_*.json")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "geomean_baseline.json"))
    args = ap.parse_args()

    traces = sorted(glob.glob(os.path.join(args.dir, "TRACE_*.json")))
    benches = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not traces:
        sys.exit(f"obs gate: no TRACE_*.json artifacts in {args.dir}")

    problems: list[str] = []
    for path in traces:
        problems += check_trace(path)
    problems += check_geomeans(benches, args.baseline, args.dir)

    if problems:
        print("\n".join(problems))
        sys.exit(f"obs gate: {len(problems)} problem(s)")
    print(f"obs gate ok: {len(traces)} trace(s) schema-valid, conservation "
          f"within {MAX_RESIDUAL:.0e}; geomean floors held across "
          f"{len(benches)} bench artifact(s)")


if __name__ == "__main__":
    main()
