"""Benchmarks reproducing the paper's evaluation figures.

* :func:`gemmini_sweep`    — Figure 10: WS tiled matmul on the sequential-
                             configuration target; geomean uplift ≈ 10.5%.
* :func:`opengemm_sweep`   — Figure 11: tiled matmul on the concurrent-
                             configuration target; geomean ≈ 2×.
* :func:`roofline_placement` — Figure 12: each measurement placed on the
                             configuration roofline (I_OC, ops/cycle, bound).
"""

from __future__ import annotations

from repro.core import accelerators, evaluate_levels, geomean, matmul_driver, speedup

GEMMINI = {"gemmini": accelerators.gemmini_like()}
OPENGEMM = {"opengemm": accelerators.opengemm_like()}


def gemmini_sweep(sizes=(16, 32, 64, 128, 256, 512)):
    rows = []
    for k in sizes:
        res = evaluate_levels(
            lambda k=k: matmul_driver.gemmini_tiled_matmul(k), GEMMINI,
            levels=("baseline", "dedup"),
        )
        b, d = res["baseline"], res["dedup"]
        rows.append({
            "size": k,
            "base_cycles": b.trace.total_cycles,
            "opt_cycles": d.trace.total_cycles,
            "speedup": speedup(res, "dedup"),
            "base_util": b.point.utilization,
            "opt_util": d.point.utilization,
        })
    g = geomean([r["speedup"] for r in rows])
    return rows, g


def opengemm_sweep(sizes=(16, 32, 64, 128, 256)):
    rows = []
    per_level = {lvl: [] for lvl in ("dedup", "overlap", "both")}
    for k in sizes:
        res = evaluate_levels(
            lambda k=k: matmul_driver.opengemm_tiled_matmul(k), OPENGEMM
        )
        row = {"size": k, "base_cycles": res["baseline"].trace.total_cycles}
        for lvl in ("dedup", "overlap", "both"):
            row[f"{lvl}_speedup"] = speedup(res, lvl)
            per_level[lvl].append(row[f"{lvl}_speedup"])
        rows.append(row)
    geo = {lvl: geomean(v) for lvl, v in per_level.items()}
    return rows, geo


def roofline_placement(sizes=(32, 64, 128, 256)):
    rows = []
    for k in sizes:
        res = evaluate_levels(
            lambda k=k: matmul_driver.opengemm_tiled_matmul(k), OPENGEMM
        )
        for lvl, r in res.items():
            p = r.point
            rows.append({
                "size": k, "level": lvl, "i_oc": p.i_oc,
                "perf_ops_per_cycle": p.performance,
                "bound": p.bound,
                "seq_roofline": p.attainable_sequential,
                "conc_roofline": p.attainable_concurrent,
            })
    return rows


def main() -> None:
    rows, g = gemmini_sweep()
    print("# Figure 10 — Gemmini (sequential configuration), dedup only")
    print("size,base_cycles,opt_cycles,speedup,base_util,opt_util")
    for r in rows:
        print(f"{r['size']},{r['base_cycles']:.0f},{r['opt_cycles']:.0f},"
              f"{r['speedup']:.3f},{r['base_util']:.3f},{r['opt_util']:.3f}")
    print(f"geomean_speedup,{g:.3f}  (paper: 1.105)")

    rows, geo = opengemm_sweep()
    print("\n# Figure 11 — OpenGeMM (concurrent configuration)")
    print("size,base_cycles,dedup_speedup,overlap_speedup,both_speedup")
    for r in rows:
        print(f"{r['size']},{r['base_cycles']:.0f},{r['dedup_speedup']:.3f},"
              f"{r['overlap_speedup']:.3f},{r['both_speedup']:.3f}")
    print(f"geomean_both,{geo['both']:.3f}  (paper: 1.99, max 2.71)")

    print("\n# Figure 12 — roofline placement (OpenGeMM)")
    print("size,level,i_oc,ops_per_cycle,bound")
    for r in roofline_placement():
        print(f"{r['size']},{r['level']},{r['i_oc']:.1f},"
              f"{r['perf_ops_per_cycle']:.1f},{r['bound']}")


if __name__ == "__main__":
    main()
