"""Shared ``--trace-out`` export helper for every benchmark.

Each benchmark that supports ``--trace-out`` re-runs one representative
scenario instrumented with a :class:`repro.obs.Tracer` and exports the
Perfetto-loadable trace with its conservation-checked cycle attribution
and metrics registry embedded (what ``obs_gate.py`` validates and
``python -m repro.obs.doctor`` diagnoses). The tracer wiring, attribution
check, and validated write used to be copy-pasted per benchmark; this is
the one copy.

Usage::

    from trace_util import export_trace          # script execution
    # (or `from benchmarks.trace_util import ...` under `-m`)

    def scenario(tracer):
        sched = Scheduler.from_registry({...}, tracer=tracer)
        return sched.run_open_loop(reqs)

    export_trace(path, scenario)
"""

from __future__ import annotations


def export_trace(path: str, scenario) -> dict:
    """Run ``scenario(tracer)`` (must return a run report — scheduler,
    cluster, or bridge) and write its validated trace document to
    ``path``. Returns the written document.

    Every exported trace also carries its conservation-checked *energy*
    attribution and per-lane ``power[...]`` counter tracks. Runs without
    an attached :class:`~repro.power.model.PowerSpec` price every lane to
    zero — the invariant still holds (and the CI gate still checks it),
    the viewer just gets no extra tracks."""
    from repro.obs import Tracer, attribute, write_trace
    from repro.obs.export import trace_power
    from repro.power.meter import attribute_energy

    tracer = Tracer()
    rep = scenario(tracer)
    energy = attribute_energy(rep).check()
    trace_power(tracer, rep)
    doc = write_trace(tracer, path, attribution=attribute(rep).check(),
                      metrics=rep.metrics, energy=energy)
    print(f"wrote {path}")
    return doc
