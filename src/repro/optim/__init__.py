from .adamw import AdamW, CosineSchedule

__all__ = ["AdamW", "CosineSchedule"]
