"""AdamW with global-norm clipping, cosine schedule, and fp32 master weights.

Built from scratch (no optax) so the optimizer-state pytree stays fully under
our control for ZeRO-style sharding: ``repro.distributed`` assigns each state
leaf a spec that additionally shards it along the *data* axis, which is what
makes the 398B/1T-parameter cells representable at all.

States per parameter: fp32 master copy, fp32 first moment, fp32 second
moment. Parameters themselves stay bf16 (compute precision); the master copy
carries the accumulation precision across steps.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CosineSchedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_ratio: float = 0.1

    def __call__(self, step):
        step = step.astype(jnp.float32)
        warm = step / max(self.warmup_steps, 1)
        prog = jnp.clip(
            (step - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = self.min_ratio + (1 - self.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.peak_lr * jnp.where(step < self.warmup_steps, warm, cos)


@dataclass(frozen=True)
class AdamW:
    schedule: CosineSchedule = CosineSchedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
        }

    def update(self, params, grads, state):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

        step = state["step"] + 1
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g), state["v"], grads
        )

        def upd(master, m, v):
            mh = m / b1c
            vh = v / b2c
            return master - lr * (mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * master)

        new_master = jax.tree.map(upd, state["master"], new_m, new_v)
        new_params = jax.tree.map(
            lambda p, mast: mast.astype(p.dtype), params, new_master
        )
        new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
