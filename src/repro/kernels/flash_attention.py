"""Flash attention (causal/full) as a Pallas TPU kernel.

Online-softmax blocked attention: the (B·H, S_q/bq, S_k/bk) grid streams K/V
tiles through VMEM while fp32 running max / normalizer / accumulator live in
VMEM scratch. S² scores never touch HBM — this is the memory-roofline fix
for the XLA-path attention, and the hillclimb candidate for the
memory-dominated train cells (EXPERIMENTS.md §Perf).

Block shapes default to MXU-aligned (128) tiles; causal masking prunes via
global row/col indices so the kernel also serves the decode path (S_q=1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, block_q: int, block_k: int, k_steps: int, causal: bool,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        i = pl.program_id(1)
        rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == k_steps - 1)
    def _flush():
        o_ref[0, ...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, H, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    k_steps = sk // block_k
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=1.0 / (d**0.5),
            block_q=block_q,
            block_k=block_k,
            k_steps=k_steps,
            causal=causal,
        ),
        grid=(b * h, sq // block_q, k_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
