"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def configured_matmul_ref(
    a: jax.Array, b: jax.Array, zp_a: jax.Array, zp_b: jax.Array
) -> jax.Array:
    """OpenGeMM-style GEMM with zero-point configuration registers:
    C = (A - zp_a)·(B - zp_b)."""
    a32 = a.astype(jnp.float32) - zp_a.astype(jnp.float32)
    b32 = b.astype(jnp.float32) - zp_b.astype(jnp.float32)
    return jnp.dot(a32, b32).astype(jnp.float32)


def greedy_sample_ref(logits: jax.Array) -> jax.Array:
    """Argmax over the last axis of (B, V) logits — lowest index wins ties
    (the tie-break contract the fused sampling kernel must reproduce)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def top_k_ref(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """``lax.top_k`` in fp32: descending values, ties by lowest index."""
    vals, idxs = jax.lax.top_k(logits.astype(jnp.float32), k)
    return vals, idxs.astype(jnp.int32)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """q,k,v: (B, H, S, D) — vanilla softmax attention in fp32."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
