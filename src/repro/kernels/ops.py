"""Jit'd public wrappers with backend selection.

``backend``:
* ``"xla"``            — pure-jnp reference path (the dry-run lowers this;
                          Pallas→TPU does not lower on a CPU backend),
* ``"pallas_interpret"`` — Pallas kernels executed in interpret mode
                          (CPU-validatable, used by the test suite),
* ``"pallas"``          — real Pallas lowering (the TPU target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .matmul import configured_matmul, matmul
from .sampling import greedy_sample, top_k

BACKENDS = ("xla", "pallas", "pallas_interpret")


def matmul_op(a, b, backend: str = "xla", **kw):
    if backend == "xla":
        return ref.matmul_ref(a, b)
    return matmul(a, b, interpret=(backend == "pallas_interpret"), **kw)


def configured_matmul_op(a, b, zero_points, backend: str = "xla", **kw):
    if backend == "xla":
        return ref.configured_matmul_ref(a, b, zero_points[0], zero_points[1])
    return configured_matmul(
        a, b, zero_points, interpret=(backend == "pallas_interpret"), **kw
    )


def attention_op(q, k, v, causal: bool = True, backend: str = "xla", **kw):
    """q,k,v: (B, H, S, D). GQA callers repeat K/V heads before the call."""
    if backend == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention(
        q, k, v, causal=causal, interpret=(backend == "pallas_interpret"), **kw
    )


def sample_op(logits, backend: str = "xla", **kw):
    """Greedy sampling over (B, V) logits → (B,) int32 ids, lowest index
    winning ties — the decode launch's fused epilogue."""
    if backend == "xla":
        return ref.greedy_sample_ref(logits)
    return greedy_sample(
        logits, interpret=(backend == "pallas_interpret"), **kw
    )


def top_k_op(logits, k: int, backend: str = "xla", **kw):
    """Top-k (values, indices) over (B, V) logits, lax.top_k ordering."""
    if backend == "xla":
        return ref.top_k_ref(logits, k)
    return top_k(logits, k, interpret=(backend == "pallas_interpret"), **kw)
