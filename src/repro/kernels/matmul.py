"""Blocked matmul Pallas kernels with explicit VMEM tiling.

Two kernels:

* :func:`matmul` — classic (M,N,K)-grid blocked GEMM: A/B tiles stream
  HBM→VMEM per grid step, an fp32 VMEM scratch accumulates across the K
  trips, and the MXU sees 128-aligned tiles. The grid pipeline double-buffers
  tile fetches — the hardware analogue of the paper's
  configuration–computation *overlap* (§5.5): block N+1's descriptors are
  staged while block N computes.

* :func:`configured_matmul` — the same GEMM with OpenGeMM-style zero-point
  *configuration registers* passed through scalar prefetch (SMEM). Scalar
  prefetch is exactly the paper's configuration port on TPU: scalars land in
  SMEM before the grid runs, so per-invocation reconfiguration costs no
  kernel-side HBM traffic — the *deduplicated* configuration path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        "MXU-aligned block shapes required (pad inputs to multiples of 128)"
    )
    k_steps = k // block_k
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def _configured_matmul_kernel(zp_ref, a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    zp_a = zp_ref[0].astype(jnp.float32)  # configuration registers in SMEM
    zp_b = zp_ref[1].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32) - zp_a
    b = b_ref[...].astype(jnp.float32) - zp_b
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def configured_matmul(
    a: jax.Array,
    b: jax.Array,
    zero_points: jax.Array,  # (2,) int32: zp_a, zp_b — the "config registers"
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    k_steps = k // block_k
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk, zp: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk, zp: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk, zp: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_configured_matmul_kernel, k_steps=k_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(zero_points, a, b)
