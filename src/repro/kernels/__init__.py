"""Pallas TPU kernels for the compute hot-spots (tiled GEMM — the paper's
workload — incl. the scalar-prefetch 'configured' variant, flash
attention, and the fused decode-sampling epilogue), each with a jit'd
wrapper (ops.py) and a pure-jnp oracle (ref.py). Validated in interpret
mode on CPU; ``backend="pallas"`` is the TPU target."""

from . import ops, ref
from .flash_attention import flash_attention
from .matmul import configured_matmul, matmul
from .sampling import greedy_sample, top_k

__all__ = ["configured_matmul", "flash_attention", "greedy_sample",
           "matmul", "ops", "ref", "top_k"]
