"""Fused sampling Pallas kernels — the decode epilogue on-device.

The serving engine's per-step synchronization point used to be the full
``(B, vocab)`` logits tensor, transferred host-side just to run ``argmax``.
That reduction is the decode launch's epilogue, and keeping it on the host
re-widens the very boundary the §5.4 deduplicated-configuration design
narrows: every decode step ships ``B·vocab`` floats back for a ``B``-word
answer. These kernels fuse the reduction into the launch so the host blocks
on a few bytes of token ids.

* :func:`greedy_sample` — blocked argmax over the vocab dimension: the grid
  walks vocab tiles in ascending order, a VMEM scratch carries the running
  (max, index) per batch row, and the *lowest index wins ties* — bit-
  identical to ``jnp.argmax`` (the tie-break contract the engine's
  fused-vs-host parity test pins). Cross-block ties resolve by a strict
  ``>`` (an earlier block's max is never displaced by an equal later one);
  within-block ties resolve by a masked index minimum.

* :func:`top_k` — k successive greedy passes with the winner masked to
  ``-inf`` between passes: descending values, ties by lowest index —
  the same ordering contract as ``jax.lax.top_k``.

Both run in interpret mode on CPU (the test suite's path) and lower for
TPU; ``kernels.ops`` exposes the usual ``backend=`` selection with the
pure-jnp oracle in ``kernels.ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _greedy_kernel(x_ref, o_ref, max_ref, idx_ref, *, block_v: int,
                   v_steps: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...].astype(jnp.float32)  # (b, block_v)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_v
    bmax = jnp.max(x, axis=1, keepdims=True)  # (b, 1)
    # lowest index among this block's maxima (tie-break within the block)
    bidx = jnp.min(jnp.where(x == bmax, col, jnp.int32(v_steps * block_v)),
                   axis=1, keepdims=True)
    # strict > across blocks: an earlier block's equal max keeps its index
    better = bmax > max_ref[...]
    idx_ref[...] = jnp.where(better, bidx, idx_ref[...])
    max_ref[...] = jnp.where(better, bmax, max_ref[...])

    @pl.when(j == v_steps - 1)
    def _flush():
        o_ref[...] = idx_ref[...]


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def greedy_sample(
    logits: jax.Array,
    *,
    block_v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Argmax over the last axis of ``(B, V)`` logits → ``(B,)`` int32 ids,
    lowest index winning ties (the ``jnp.argmax`` contract). The vocab is
    padded to a whole number of blocks with ``-inf``, which can never beat
    a real entry and never wins the cross-block strict-``>`` race."""
    b, v = logits.shape
    v_pad = -(-v // block_v) * block_v
    x = logits.astype(jnp.float32)
    if v_pad != v:
        x = jnp.pad(x, ((0, 0), (0, v_pad - v)), constant_values=NEG_INF)
    v_steps = v_pad // block_v
    out = pl.pallas_call(
        functools.partial(_greedy_kernel, block_v=block_v, v_steps=v_steps),
        grid=(v_steps,),
        in_specs=[pl.BlockSpec((b, block_v), lambda j: (0, j))],
        out_specs=pl.BlockSpec((b, 1), lambda j: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((b, 1), jnp.float32),  # running max per row
            pltpu.VMEM((b, 1), jnp.int32),  # its (lowest) index
        ],
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("k", "block_v", "interpret"))
def top_k(
    logits: jax.Array,
    k: int,
    *,
    block_v: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-k values and indices over the last axis of ``(B, V)`` logits,
    as k greedy passes with the winner masked between passes — descending
    values, ties by lowest index (the ``lax.top_k`` ordering). Rows must
    hold more than k entries above ``-inf`` for the k indices to be
    distinct (``-inf`` is the mask sentinel)."""
    b, v = logits.shape
    assert 0 < k <= v, (k, v)
    work = logits.astype(jnp.float32)
    rows = jnp.arange(b)
    vals, idxs = [], []
    for _ in range(k):
        idx = greedy_sample(work, block_v=block_v, interpret=interpret)
        vals.append(work[rows, idx])
        idxs.append(idx)
        work = work.at[rows, idx].set(NEG_INF)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)
