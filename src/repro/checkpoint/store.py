"""Checkpointing: atomic, async, integrity-checked save/restore of pytrees.

Design points for 1000+-node deployments:

* **Atomicity** — writes go to ``step_N.tmp/`` and are renamed into place;
  a crash mid-save never corrupts the restore set (restart picks the last
  complete step).
* **Async save** — serialization happens on a background thread against
  host-fetched copies, so the train loop only pays the device→host copy
  (the paper's overlap idea applied to state I/O).
* **Integrity** — every array file carries a CRC recorded in the manifest;
  restore verifies before handing the tree back.
* **Resharding restore** — arrays come back as host numpy and are placed
  onto whatever sharding the *current* mesh dictates (``jax.device_put``
  with the target sharding), so restarts may change topology (elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names incl. the ml_dtypes family (bfloat16, fp8, ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _to_bytes_view(arr: np.ndarray) -> np.ndarray:
    """A uint8 view for serialization — numpy's npy format cannot represent
    ml_dtypes (bfloat16 saves as void and fails to restore)."""
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        out[key] = leaf
    return out, treedef


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True
            )
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree) -> None:
        flat, _ = _flatten(host_tree)
        tmp = os.path.join(self.directory, f"step_{step}.tmp")
        final = os.path.join(self.directory, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": {}}
        for key, arr in flat.items():
            fn = key.replace("/", "__") + ".npy"
            path = os.path.join(tmp, fn)
            np.save(path, _to_bytes_view(arr))
            with open(path, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["arrays"][key] = {
                "file": fn,
                "crc32": crc,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def keys(self, step: int) -> list[str]:
        """Leaf keys recorded in one step's manifest — lets callers restore
        without already holding a template tree (``fabric.ContextStore``)."""
        with open(os.path.join(self.directory, f"step_{step}", "manifest.json")) as f:
            return sorted(json.load(f)["arrays"])

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; if ``shardings`` is
        given, place each leaf with its target sharding (reshard-on-restore)."""
        base = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = _flatten(like_tree)
        flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
        leaves = {}
        for key in flat_like:
            meta = manifest["arrays"][key]
            path = os.path.join(base, meta["file"])
            with open(path, "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption: CRC mismatch for {key}")
            raw = np.load(path)
            arr = raw.view(_np_dtype(meta["dtype"])).reshape(meta["shape"])
            if key in flat_sh:
                leaves[key] = jax.device_put(arr, flat_sh[key])
            else:
                leaves[key] = jax.numpy.asarray(arr)
        ordered = [leaves[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, ordered)
