"""Continuous-batching serving engine.

Production serving never decodes lock-step batches: requests arrive and
finish at different times, so the engine keeps a fixed pool of KV-cache
*slots* and every decode launch advances whichever slots are live, each at
its own position (`decode_step` accepts an (B,) position vector). A finished
request's slot is handed to the next queued request immediately — no
drain-the-batch bubbles.

Configuration-wall connection: the per-launch descriptor is exactly
{tokens, positions, live-mask} — a few hundred bytes against a device-resident
multi-GiB cache. The engine is the deduplicated-configuration serving design
the paper's §5.4 implies: everything invariant lives on-device; only the
changing fields cross the host→device boundary each step.

Every launch goes through a :class:`~repro.dispatch.ScheduledExecutor`
(``engine.executor``): descriptor elision drives the *real* launch path,
not just accounting. The executor's
:class:`~repro.sched.state_cache.ConfigStateCache` (aliased as
``engine.config_cache``) splits each descriptor into sent vs. device-resident
fields (sampling config always; the live-mask between admissions), and its
depth-bounded staging ring keeps prefill launches in flight while the host
prepares the next one — the serving twin of OpenGeMM's staged configuration.
``engine.config_traffic()`` reports the split for roofline placement.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.dispatch import ScheduledExecutor


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, max_slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, launch_depth: int = 2,
                 decode_fn=None, on_launch=None):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(max_slots, max_len)
        self.positions = np.zeros((max_slots,), np.int32)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * max_slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        # decode_fn lets N engines of one model share a single compiled
        # step (the bridge runs many tenant engines of the same
        # architecture; each call still passes its own donated cache)
        self._decode = decode_fn or jax.jit(model.decode_step,
                                            donate_argnums=(1,))
        # launch observer: called with every launch descriptor *after* it
        # goes through the executor — the seam ``repro.bridge`` taps to
        # mirror the real decode launch stream into cluster LaunchRequests
        # without perturbing the compute (observation only, no reply)
        self.on_launch = on_launch
        # scheduled launch path: the executor owns the staging ring (depth
        # launches in flight) and the config-state cache — one context, the
        # engine is one tenant of its device. Its descriptor elision is the
        # launch path itself, not a side accounting.
        # sync on the logits: the KV cache is donated launch-to-launch, so
        # only the per-step output is safe to block on
        self.executor = ScheduledExecutor(self._device_fn, depth=launch_depth,
                                          tenant="engine",
                                          sync_fn=lambda out: out[1])
        self.config_cache = self.executor.cache

    def _device_fn(self, state, desc):
        """One decode launch from a cached descriptor: only ``tokens`` and
        ``positions`` parameterize the kernel; everything else in the
        descriptor is device-resident configuration."""
        params, cache = state
        logits, cache = self._decode(
            params, cache, jnp.asarray(desc["tokens"]),
            jnp.asarray(desc["positions"]),
        )
        return (params, cache), logits

    def _launch(self, desc: dict):
        """Stage one launch through the executor; adopts the new KV cache
        and returns the (possibly still in-flight) logits."""
        (_, self.cache), logits = self.executor.launch(
            (self.params, self.cache), desc
        )
        if self.on_launch is not None:
            self.on_launch(desc)
        return logits

    @staticmethod
    def compile_decode(model):
        """One compiled decode step, shareable across every engine of the
        same architecture (`decode_fn=`): N bridged tenant engines then pay
        a single JIT compilation instead of N."""
        return jax.jit(model.decode_step, donate_argnums=(1,))

    # ---------------------------------------------------------------- admin

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def live_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.slot_req[slot] = req
            # prefill by stepping the prompt through the cache (simple
            # token-at-a-time prefill; a production engine would batch this)
            self.positions[slot] = 0
            for tok in req.prompt[:-1]:
                self._step_single_slot(slot, tok)
            self.tokens[slot, 0] = req.prompt[-1]

    def _step_single_slot(self, slot: int, token: int) -> None:
        toks = self.tokens.copy()
        toks[slot, 0] = token
        # prefill needs no logits: launches stay staged in the executor's
        # ring, overlapping host descriptor prep with device work
        self._launch(self._launch_descriptor(self.live_slots, tokens=toks))
        self.positions[slot] += 1

    # ----------------------------------------------------------------- step

    def step(self) -> int:
        """One decode launch over all live slots; returns #tokens produced."""
        self._admit()
        live = self.live_slots
        if not live:
            return 0
        logits = self._launch(self._launch_descriptor(live))
        # sampling is the synchronization point: argmax needs the logits
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        produced = 0
        for slot in live:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.positions[slot] += 1
            self.tokens[slot, 0] = tok
            produced += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if (
                len(req.generated) >= req.max_new_tokens
                or self.positions[slot] >= self.max_len - 1
                or hit_eos
            ):
                req.done = True
                self.finished.append(req)
                self.slot_req[slot] = None  # slot freed for the next request
                self.positions[slot] = 0
        return produced

    def _launch_descriptor(self, live: list[int],
                           tokens: np.ndarray | None = None) -> dict:
        """The fields that parameterize one decode launch. Copies snapshot
        the mutable host buffers so cached values stay bit-stable; a
        prefill override in ``tokens`` is already a fresh array."""
        mask = np.zeros((self.max_slots,), bool)
        mask[live] = True
        return {
            "tokens": self.tokens.copy() if tokens is None else tokens,
            "positions": self.positions.copy(),
            "live_mask": mask,
            # invariant sampling/shape config: elided after the first launch
            "max_len": np.int32(self.max_len),
            "eos_id": np.int32(-1 if self.eos_id is None else self.eos_id),
            "n_slots": np.int32(self.max_slots),
        }

    def config_traffic(self) -> dict[str, float]:
        """Config bytes sent vs. elided across all launches so far
        (prefill and batch decode alike)."""
        s = self.config_cache.stats
        return {
            "bytes_sent": float(s.bytes_sent),
            "bytes_elided": float(s.bytes_elided),
            "elision_ratio": s.elision_ratio,
        }

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.live_slots) and steps < max_steps:
            self.step()
            steps += 1
        self.executor.drain()  # retire any still-staged launches
        return self.finished
