"""Continuous-batching serving engine.

Production serving never decodes lock-step batches: requests arrive and
finish at different times, so the engine keeps a fixed pool of KV-cache
*slots* and every decode launch advances whichever slots are live, each at
its own position (`decode_step` accepts an (B,) position vector). A finished
request's slot is handed to the next queued request immediately — no
drain-the-batch bubbles.

Configuration-wall connection: the per-launch descriptor is a few dozen
bytes against a device-resident multi-GiB cache — the deduplicated-
configuration serving design the paper's §5.4 implies: everything invariant
lives on-device; only the changing fields cross the host→device boundary
each step. Two designs narrow that boundary further:

* **Fused sampling** (``sampling="fused"``, the default): the decode launch
  runs the greedy-sampling epilogue on-device
  (:meth:`~repro.models.model.Model.decode_and_sample`, backed by the
  ``kernels/sampling.py`` Pallas kernel) and returns ``(B, 1)`` token ids —
  the host blocks on a few bytes instead of the full ``(B, vocab)`` logits.
  Because the sampled ids stay device-resident and feed the next launch
  directly, the decode descriptor drops its ``tokens`` leaf entirely: the
  host injects tokens only through ``token_overrides``/``override_mask``
  (admissions and freed slots), which elide in steady-state decode. The
  steady-state descriptor is ``{positions}`` plus elided residents — the
  narrowest the boundary gets. ``sampling="host"`` keeps the classic
  logits-returning launch (the A/B baseline, bit-identical token streams).

* **Batched prefill**: admission runs the prompt through
  :meth:`~repro.models.model.Model.prefill_chunk` — ``ceil(p/chunk)``
  masked launches instead of p full-batch steps, each advancing *only* the
  admitted slot (other slots' cache rows stay bit-identical through an
  admission). The prefill descriptor (``prefill_tokens``/``prefill_len``/
  ``slot_mask``) is priced by the bridge like any other launch.

Every launch goes through a :class:`~repro.dispatch.ScheduledExecutor`
(``engine.executor``): descriptor elision drives the *real* launch path,
not just accounting. The executor's
:class:`~repro.sched.state_cache.ConfigStateCache` (aliased as
``engine.config_cache``) splits each descriptor into sent vs.
device-resident fields, and its depth-bounded staging ring keeps prefill
launches in flight while the host prepares the next one — the serving twin
of OpenGeMM's staged configuration. ``engine.config_traffic()`` reports the
split for roofline placement.
"""

from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.dispatch import ScheduledExecutor


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, max_slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, launch_depth: int = 2,
                 decode_fn=None, prefill_fn=None, on_launch=None,
                 sampling: str = "fused", sample_backend: str = "xla",
                 prefill_chunk: int = 8):
        assert sampling in ("fused", "host"), sampling
        assert prefill_chunk >= 1, prefill_chunk
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.sampling = sampling
        self.prefill_chunk = prefill_chunk
        self.cache = model.init_cache(max_slots, max_len)
        self.positions = np.zeros((max_slots,), np.int32)
        # host mirror of each slot's pending input token (the descriptor
        # field in host mode; bookkeeping only under fused sampling, where
        # the device-resident ids are the real input ring)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        # fused sampling: host→device token injections for the next decode
        # launch (admitted prompts' last token; zero for freed slots) —
        # all-False mask in steady state, so both leaves elide
        self._overrides = np.zeros((max_slots,), np.int32)
        self._override_mask = np.zeros((max_slots,), bool)
        if sampling == "fused":
            # the device-resident sampled ids (previous launch's output,
            # next launch's input — never crosses the boundary)
            self._dev_tokens = jnp.zeros((max_slots, 1), jnp.int32)
        self.slot_req: list[Request | None] = [None] * max_slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        # decode_fn/prefill_fn let N engines of one model share a single
        # compiled step (the bridge runs many tenant engines of the same
        # architecture; each call still passes its own donated cache). A
        # caller-supplied decode_fn must match the engine's sampling mode
        # (use compile_decode(model, sampling=...)).
        self._decode = decode_fn or ServingEngine.compile_decode(
            model, sampling=sampling, sample_backend=sample_backend)
        self._prefill = prefill_fn or ServingEngine.compile_prefill(model)
        # launch observer: called with every launch descriptor *after* it
        # goes through the executor — the seam ``repro.bridge`` taps to
        # mirror the real decode launch stream into cluster LaunchRequests
        # without perturbing the compute (observation only, no reply)
        self.on_launch = on_launch
        # scheduled launch path: the executor owns the staging ring (depth
        # launches in flight) and the config-state cache — one context, the
        # engine is one tenant of its device. Its descriptor elision is the
        # launch path itself, not a side accounting.
        # sync on the per-launch payload (sampled ids / logits / prefill
        # probe): the KV cache is donated launch-to-launch, so only the
        # per-step output is safe to block on
        self.executor = ScheduledExecutor(self._device_fn, depth=launch_depth,
                                          tenant="engine",
                                          sync_fn=lambda out: out[1])
        self.config_cache = self.executor.cache

    def _device_fn(self, state, desc):
        """One launch from a cached descriptor. Three launch kinds share the
        path: chunked prefill (keyed by ``prefill_tokens``), fused decode
        (device-resident token ring + host overrides → sampled ids), and
        host-sampling decode (``tokens`` field → full logits)."""
        params, cache = state
        if "prefill_tokens" in desc:
            probe, cache = self._prefill(
                params, cache,
                jnp.asarray(desc["prefill_tokens"]),
                jnp.asarray(desc["positions"]),
                jnp.asarray(desc["prefill_len"]),
                jnp.asarray(desc["slot_mask"]),
            )
            return (params, cache), probe
        if self.sampling == "fused":
            ids, cache = self._decode(
                params, cache, self._dev_tokens,
                jnp.asarray(desc["token_overrides"]),
                jnp.asarray(desc["override_mask"]),
                jnp.asarray(desc["positions"]),
                jnp.asarray(desc["live_mask"]),
            )
            self._dev_tokens = ids  # loopback: next launch's input tokens
            return (params, cache), ids
        logits, cache = self._decode(
            params, cache, jnp.asarray(desc["tokens"]),
            jnp.asarray(desc["positions"]),
            jnp.asarray(desc["live_mask"]),
        )
        return (params, cache), logits

    def _launch(self, desc: dict):
        """Stage one launch through the executor; adopts the new KV cache
        and returns the (possibly still in-flight) per-launch payload."""
        (_, self.cache), out = self.executor.launch(
            (self.params, self.cache), desc
        )
        if self.on_launch is not None:
            self.on_launch(desc)
        return out

    @staticmethod
    def compile_decode(model, sampling: str = "fused",
                       sample_backend: str = "xla"):
        """One compiled decode step, shareable across every engine of the
        same architecture (`decode_fn=`): N bridged tenant engines then pay
        a single JIT compilation instead of N. ``sampling="fused"`` returns
        the fused decode+sample step (ids out); ``"host"`` the classic
        logits-returning step. Must match the engines' ``sampling=``."""
        if sampling == "fused":
            return jax.jit(
                functools.partial(model.decode_and_sample,
                                  sample_backend=sample_backend),
                donate_argnums=(1,),
            )
        return jax.jit(model.decode_step, donate_argnums=(1,))

    @staticmethod
    def compile_prefill(model):
        """One compiled chunked-prefill launch (`prefill_fn=`), shareable
        like :meth:`compile_decode` (one shape per chunk size)."""
        return jax.jit(model.prefill_chunk, donate_argnums=(1,))

    # ---------------------------------------------------------------- admin

    def submit(self, req: Request) -> None:
        """Queue a request. Rejects prompts the slot layout cannot hold:
        an empty prompt has no token to start decode from, and a prompt of
        ``max_len`` or more would overrun the slot's KV rows before the
        first generated token."""
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt of {len(req.prompt)} tokens "
                f"needs max_len > {len(req.prompt)} (engine max_len="
                f"{self.max_len}) — it would overrun the KV cache")
        self.queue.append(req)

    @property
    def live_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.slot_req[slot] = req
            self.positions[slot] = 0
            # chunked prefill: all prompt tokens but the last stream through
            # masked launches that advance only this slot; launches stay
            # staged in the executor's ring (no sync), overlapping host
            # descriptor prep with device work
            ptoks = req.prompt[:-1]
            for start in range(0, len(ptoks), self.prefill_chunk):
                self._prefill_launch(slot, ptoks[start:start + self.prefill_chunk])
            # the prompt's last token seeds the first decode step
            self._set_token(slot, req.prompt[-1])

    def _prefill_launch(self, slot: int, chunk: list[int]) -> None:
        n = len(chunk)
        buf = np.zeros((self.prefill_chunk,), np.int32)
        buf[:n] = chunk
        mask = np.zeros((self.max_slots,), bool)
        mask[slot] = True
        self._launch({
            "prefill_tokens": buf,
            "prefill_len": np.int32(n),
            "positions": self.positions.copy(),
            "slot_mask": mask,
            **self._invariant_fields(),
        })
        self.positions[slot] += n

    def _set_token(self, slot: int, tok: int) -> None:
        """Point a slot's next decode input at ``tok`` — the host mirror
        always; plus a device override under fused sampling (the only way
        a host token enters the device-resident ring)."""
        self.tokens[slot, 0] = tok
        if self.sampling == "fused":
            self._overrides[slot] = tok
            self._override_mask[slot] = True

    # ----------------------------------------------------------------- step

    def step(self) -> int:
        """One decode launch over all live slots; returns #tokens produced."""
        self._admit()
        live = self.live_slots
        if not live:
            return 0
        out = self._launch(self._decode_descriptor(live))
        # sampling is the synchronization point. Fused: the launch already
        # sampled on-device — block on (B,) ids, a few bytes. Host: argmax
        # here needs the full (B, vocab) logits across the boundary first.
        if self.sampling == "fused":
            self._override_mask[:] = False  # consumed by the staged launch
            nxt = np.asarray(out[:, 0], np.int32)
        else:
            nxt = np.asarray(jnp.argmax(out[:, 0], axis=-1), np.int32)
        produced = 0
        for slot in live:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.positions[slot] += 1
            self.tokens[slot, 0] = tok
            produced += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if (
                len(req.generated) >= req.max_new_tokens
                or self.positions[slot] >= self.max_len - 1
                or hit_eos
            ):
                req.done = True
                self.finished.append(req)
                self.slot_req[slot] = None  # slot freed for the next request
                self.positions[slot] = 0
                # zero the freed slot's token state: later descriptors must
                # not carry (or dedup against) the dead request's last token
                self._set_token(slot, 0)
        return produced

    def _decode_descriptor(self, live: list[int]) -> dict:
        """The fields that parameterize one decode launch. Copies snapshot
        the mutable host buffers so cached values stay bit-stable. Fused
        sampling has no ``tokens`` leaf: input ids are device-resident, and
        the override pair is all-zero/all-False (elided) except on the step
        after an admission or a free."""
        mask = np.zeros((self.max_slots,), bool)
        mask[live] = True
        desc = {
            "positions": self.positions.copy(),
            "live_mask": mask,
            **self._invariant_fields(),
        }
        if self.sampling == "fused":
            desc["token_overrides"] = self._overrides.copy()
            desc["override_mask"] = self._override_mask.copy()
        else:
            desc["tokens"] = self.tokens.copy()
        return desc

    def _invariant_fields(self) -> dict:
        """Sampling/shape config common to every launch kind — sent once,
        device-resident (elided) afterwards."""
        return {
            "max_len": np.int32(self.max_len),
            "eos_id": np.int32(-1 if self.eos_id is None else self.eos_id),
            "n_slots": np.int32(self.max_slots),
        }

    @property
    def sync_bytes(self) -> int:
        """Device→host bytes the host blocks on per decode step — the
        sampling synchronization the closed-loop driver prices on the
        feedback edge. Fused sampling returns ``(B, 1)`` int32 ids; host
        sampling pulls the full ``(B, vocab)`` logits across the boundary
        just to argmax them."""
        if self.sampling == "fused":
            return self.max_slots * 4
        from repro.models.layers import COMPUTE_DTYPE
        vocab = self.model.cfg.vocab_size
        return self.max_slots * vocab * np.dtype(COMPUTE_DTYPE).itemsize

    def config_traffic(self) -> dict[str, float]:
        """Config bytes sent vs. elided across all launches so far
        (prefill and batch decode alike)."""
        s = self.config_cache.stats
        return {
            "bytes_sent": float(s.bytes_sent),
            "bytes_elided": float(s.bytes_elided),
            "elision_ratio": s.elision_ratio,
        }

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.live_slots) and steps < max_steps:
            self.step()
            steps += 1
        self.executor.drain()  # retire any still-staged launches
        return self.finished
