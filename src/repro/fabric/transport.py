"""Config-write transport: turn a cache write-plan into a transfer schedule.

``sched.ConfigStateCache`` decides *what* must cross the host→device
boundary (the delta of a launch's register file); this module decides
*how*. Three disciplines compete, priced against one :class:`~.link.LinkModel`:

* **MMIO** — the host issues one register write per config-write
  instruction, exactly the paper's §2 model: host cycles are
  ``(writes · instrs_per_write + launch_instrs) · host_cpi`` (parameter
  calculation and instruction issue, the T_calc of Eq. 4) and every write
  pays the link's full transaction latency.
* **Burst DMA** — the host packs the register values into a descriptor in
  local memory (~1 store per field, so host cycles shrink to
  ``(n_fields + launch_instrs) · host_cpi``) and a DMA engine streams the
  image in bursts, paying link latency once per burst instead of per write.
* **Write-combined MMIO** (``"wc"``) — on links with a posted-write buffer
  (``LinkModel.wc_depth ≥ 2``): the host issues the same per-register
  writes, but the buffer coalesces up to ``wc_depth`` of them per
  transaction, paying latency once per batch — between the other two, and
  ``None`` (never chosen) on every stock link.

:func:`plan_fields` picks whichever yields the smaller ``T_set``
(host + wire) and reports both, so benchmarks can show the crossover: on a
zero-latency core-local CSR port MMIO always wins (and reproduces the
pre-fabric cost bit-exactly); once writes cross a NoC or PCIe, burst DMA
wins as soon as the plan exceeds a few registers.

The launch command itself also crosses the link (one field-sized write,
matching the existing byte accounting in ``sched.scheduler``).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.accelerators import AcceleratorModel
from .link import LinkModel

MODES = ("mmio", "burst", "wc")

# pJ one host control-thread cycle costs while issuing config instructions
# — kept here (not on a PowerSpec) because the transport layer must price a
# schedule's joules at *plan* time, before any scheduler exists; the meter
# (repro.power) uses the same constant so the two can never disagree
HOST_ENERGY_PER_CYCLE = 1.0


@dataclass(frozen=True)
class TransferSchedule:
    """One launch's configuration transfer, fully priced."""

    mode: str  # "mmio" | "burst"
    link: str  # LinkModel.name
    n_fields: int  # register fields crossing the boundary (launch excluded)
    nbytes: int  # config payload on the wire, launch write included
    host_cycles: float  # host instruction time (T_calc + issue)
    link_cycles: float  # time on the wire
    host_energy: float = 0.0  # pJ of host instruction issue
    wire_energy: float = 0.0  # pJ on the wire (handshakes/descriptors+bytes)

    @property
    def t_set(self) -> float:
        """Eq. 4's configuration term for this launch: the host is captive
        for its instruction time and (conservatively) the wire time."""
        return self.host_cycles + self.link_cycles

    @property
    def energy(self) -> float:
        """Configuration energy of this launch, pJ — the joule analogue of
        :attr:`t_set`."""
        return self.host_energy + self.wire_energy

    @property
    def edp(self) -> float:
        """Energy-delay product (pJ·cycles) — the balanced objective."""
        return self.energy * self.t_set


def mmio_schedule(n_fields: int, model: AcceleratorModel,
                  link: LinkModel) -> TransferSchedule:
    """Per-register MMIO: the paper's write discipline over the link."""
    writes = -(-n_fields // model.fields_per_write) if n_fields else 0
    host = (writes * model.instrs_per_write + model.launch_instrs) * model.host_cpi
    payload = model.fields_per_write * model.bytes_per_field
    wire = (link.mmio_cycles(writes, payload)
            + link.write_cycles(model.bytes_per_field))  # the launch write
    nbytes = (n_fields + 1) * model.bytes_per_field
    return TransferSchedule(
        mode="mmio",
        link=link.name,
        n_fields=n_fields,
        nbytes=nbytes,
        host_cycles=host,
        link_cycles=wire,
        host_energy=host * HOST_ENERGY_PER_CYCLE,
        # writes + 1: the launch write is an ordered handshake too
        wire_energy=link.transfer_energy("mmio", nbytes, n_writes=writes + 1),
    )


def burst_schedule(n_fields: int, model: AcceleratorModel,
                   link: LinkModel) -> TransferSchedule | None:
    """Coalesced burst descriptor, or ``None`` when the link has no DMA
    engine. The host touches each field once (a local store into the
    descriptor), then the wire streams the whole image."""
    if not link.supports_dma:
        return None
    host = (n_fields + model.launch_instrs) * model.host_cpi
    nbytes = (n_fields + 1) * model.bytes_per_field
    return TransferSchedule(
        mode="burst",
        link=link.name,
        n_fields=n_fields,
        nbytes=nbytes,
        host_cycles=host,
        link_cycles=link.burst_cycles(nbytes),
        host_energy=host * HOST_ENERGY_PER_CYCLE,
        wire_energy=link.transfer_energy("burst", nbytes),
    )


def wc_schedule(n_fields: int, model: AcceleratorModel,
                link: LinkModel) -> TransferSchedule | None:
    """Write-combined MMIO, or ``None`` on links without a posted-write
    buffer (``wc_depth < 2`` — every stock link, so nothing changes unless
    a ``*_wc`` link is chosen). The host issues the same per-register write
    instructions as MMIO — combining happens in the link's write buffer,
    not in software — but the wire coalesces up to ``wc_depth`` posted
    writes per transaction, paying link latency once per batch: MMIO's
    ordering cost partially amortized without programming a descriptor.
    The launch write is posted too (it drains the final batch)."""
    if link.wc_depth < 2:
        return None
    writes = -(-n_fields // model.fields_per_write) if n_fields else 0
    host = (writes * model.instrs_per_write + model.launch_instrs) * model.host_cpi
    payload = model.fields_per_write * model.bytes_per_field
    nbytes = (n_fields + 1) * model.bytes_per_field
    return TransferSchedule(
        mode="wc",
        link=link.name,
        n_fields=n_fields,
        nbytes=nbytes,
        host_cycles=host,
        link_cycles=link.wc_cycles(writes + 1, payload),
        host_energy=host * HOST_ENERGY_PER_CYCLE,
        # one handshake per coalesced batch; writes + 1 counts the launch
        wire_energy=link.transfer_energy("wc", nbytes, n_writes=writes + 1),
    )


TRANSPORTS = ("auto", "mmio", "burst", "wc")

# what "cheaper" means when mode="auto" compares the two disciplines:
# cycles is the historical (and default) axis; joules and energy-delay
# product can disagree with it, because burst DMA amortizes *latency*
# aggressively while its descriptor setup *energy* is the expensive term
OBJECTIVES = ("cycles", "joules", "edp")

_OBJECTIVE_KEYS = {
    "cycles": lambda s: s.t_set,
    "joules": lambda s: s.energy,
    "edp": lambda s: s.edp,
}


def plan_fields(n_fields: int, model: AcceleratorModel, link: LinkModel,
                mode: str = "auto",
                objective: str = "cycles") -> TransferSchedule:
    """Price an ``n_fields``-register plan. ``mode="auto"`` (the default)
    picks the cheaper of MMIO and burst DMA under ``objective`` — cycles
    (``t_set``, the historical behaviour, default), joules (``energy``),
    or ``edp`` — ties break toward less machinery (MMIO over
    write-combining over burst: no write buffer to drain, no descriptor to
    build). ``"mmio"`` forces per-register writes (the paper's baseline
    discipline, and the doctor's counterfactual knob); ``"burst"`` forces
    the DMA path, falling back to MMIO on links without a DMA engine;
    ``"wc"`` forces write-combined MMIO, falling back likewise on links
    without a posted-write buffer."""
    assert mode in TRANSPORTS, mode
    assert objective in OBJECTIVES, objective
    mmio = mmio_schedule(n_fields, model, link)
    if mode == "mmio":
        return mmio
    if mode == "wc":
        return wc_schedule(n_fields, model, link) or mmio
    burst = burst_schedule(n_fields, model, link)
    if mode == "burst":
        return burst or mmio
    key = _OBJECTIVE_KEYS[objective]
    best = mmio
    for cand in (wc_schedule(n_fields, model, link), burst):
        if cand is not None and key(cand) < key(best):
            best = cand
    return best


def plan_transfer(plan, model: AcceleratorModel, link: LinkModel,
                  objective: str = "cycles") -> TransferSchedule:
    """Price a ``sched.state_cache.WritePlan``'s sent set (duck-typed so
    the fabric layer stays import-free of ``repro.sched``)."""
    return plan_fields(len(plan.sent), model, link, objective=objective)


def crossover_fields(model: AcceleratorModel, link: LinkModel,
                     limit: int = 1024,
                     objective: str = "cycles") -> int | None:
    """Smallest plan size at which burst DMA beats per-register MMIO on
    this (device, link) pair under ``objective`` — ``None`` if MMIO wins
    up to ``limit`` (always the case on a core-local CSR port under
    cycles). The joule crossover sits later than the cycle one wherever
    the descriptor setup energy outweighs a few MMIO handshakes."""
    if not link.supports_dma:
        return None
    key = _OBJECTIVE_KEYS[objective]
    for n in range(1, limit + 1):
        if key(burst_schedule(n, model, link)) < key(mmio_schedule(n, model, link)):
            return n
    return None


def crossover_table(model: AcceleratorModel, link: LinkModel,
                    limit: int = 256,
                    objective: str = "cycles") -> list[tuple[int, str]]:
    """Winning-discipline regimes of ``plan_fields(mode="auto")`` over plan
    sizes 1..``limit``: ``[(n_start, mode), ...]``, one entry per regime
    change. On a write-combining link the table typically reads
    ``[(1, "wc"), (k, "burst")]`` — a few posted writes amortize latency
    without a descriptor, deep register images still want DMA; on stock
    links (``wc_depth=0``) the "wc" regime can never appear, which is the
    bit-exactness guarantee in table form."""
    table: list[tuple[int, str]] = []
    for n in range(1, limit + 1):
        mode = plan_fields(n, model, link, objective=objective).mode
        if not table or table[-1][1] != mode:
            table.append((n, mode))
    return table
