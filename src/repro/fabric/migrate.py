"""Tenant migration: warm register-snapshot hand-off vs. cold resend.

When the router wants to move a tenant off a saturated host, there are two
ways to pay for it:

* **cold** — drop the tenant's source context; its first launch at the
  destination re-sends the full register file through the destination's
  config port (full T_calc + T_set of Eq. 4).
* **warm** — capture the tenant's :class:`~.snapshot.ContextSnapshot` at
  the source, ship it host-to-host over a fabric link (one DMA burst of
  raw register values, no per-field recalculation), install it into the
  destination cache; the first launch there pays only its delta.

:class:`MigrationPlanner` prices both against the migration link and the
destination's config fabric and executes the cheaper one (``policy="auto"``;
``"warm"``/``"cold"`` force a mode for A/B benchmarks). Warm wins when the
context is large relative to the link's per-transfer overhead — big
register files over a NoC win easily; over PCIe the double latency (ship +
delta) needs a much larger context to amortize. Concurrent migrations share
one :class:`~.link.LinkPort`, so hand-offs contend for wire bandwidth like
any other transfer.

:class:`ContextStore` persists snapshots through
``checkpoint.CheckpointStore`` (atomic, CRC-checked), so recurring tenants
restore warm across runs: capture at shutdown, install at boot, and the
returning tenant's first dispatch is already a context hit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

from .link import LinkModel, LinkPort, Transfer, resolve_link
from .snapshot import ContextSnapshot, capture, delta_fields, install, ship_cycles
from .transport import plan_fields

POLICIES = ("auto", "warm", "cold")


def _sched(host):
    """Accept either a ``cluster.Host`` or a bare ``sched.Scheduler``."""
    return getattr(host, "sched", host)


def _devices(host, accel: str | None):
    devs = [d for d in _sched(host).devices
            if accel is None or d.model.name == accel]
    assert devs, f"host carries no {accel!r} device"
    return devs


def context_device(host, tenant: str, accel: str | None = None):
    """The device whose cache holds the tenant's richest context, or
    ``None`` when the tenant is cold everywhere on this host."""
    best, best_n = None, 0
    for dev in _sched(host).devices:
        if accel is not None and dev.model.name != accel:
            continue
        ctx = dev.cache.context(tenant)
        if ctx is not None and len(ctx) >= best_n:
            best, best_n = dev, len(ctx)
    return best


@dataclass(frozen=True)
class MigrationEstimate:
    """Both prices for moving one tenant, and the chosen mode."""

    tenant: str
    src: str
    dst: str
    mode: str  # "warm" | "cold" — the cheaper (or forced) choice
    warm_cycles: float  # ship snapshot + delta T_set at the destination
    cold_cycles: float  # full-resend T_set at the destination
    context_fields: int
    context_bytes: int  # register payload the hand-off ships
    warm_port_bytes: int  # dst config-port bytes of the next launch, warm
    cold_port_bytes: int  # ... and cold (full register file)

    @property
    def savings_cycles(self) -> float:
        """Positive when the warm hand-off is the cheaper move."""
        return self.cold_cycles - self.warm_cycles


@dataclass(frozen=True)
class MigrationRecord:
    """One executed migration."""

    estimate: MigrationEstimate
    snapshot: ContextSnapshot | None  # shipped context (None for cold)
    transfer: Transfer | None  # link occupancy (None for cold)

    @property
    def done_at(self) -> float:
        return self.transfer.end if self.transfer else 0.0


class MigrationPlanner:
    """Prices and executes tenant moves over one shared migration link."""

    def __init__(self, link: LinkModel | str = "noc", *, policy: str = "auto",
                 kickoff_cycles: float = 8.0):
        assert policy in POLICIES, policy
        self.link = resolve_link(link)
        self.policy = policy
        self.kickoff_cycles = kickoff_cycles
        self.port = LinkPort(self.link, name=f"migrate[{self.link.name}]")
        self.migrations: list[MigrationRecord] = []

    # -- pricing -------------------------------------------------------------

    def estimate(self, tenant: str, src, dst, probe) -> MigrationEstimate:
        """Price both moves. ``probe`` is the tenant's next launch (a
        ``sched.LaunchRequest``) — its register file is what the first
        post-migration dispatch must convey."""
        src_id = getattr(src, "id", "src")
        dst_id = getattr(dst, "id", "dst")
        src_dev = context_device(src, tenant, getattr(probe, "accel", None))
        snap = (capture(src_dev.cache, tenant, src_dev.model)
                if src_dev is not None else None)
        # both prices must describe the same move: the destination device is
        # of the snapshot's kind when one exists (where migrate() installs),
        # else whatever the probe restricts to — least backlog breaks ties
        kind = snap.accel if snap is not None else getattr(probe, "accel", None)
        dst_sched = _sched(dst)
        dst_dev = min(_devices(dst, kind),
                      key=lambda d: (d.queue.backlog(dst_sched.host), d.id))
        regs = probe.regs_for(dst_dev.model)
        dst_link = dst_sched.link
        cold = plan_fields(len(regs), dst_dev.model, dst_link)
        delta = delta_fields(snap, regs)
        warm_delta = plan_fields(len(delta), dst_dev.model, dst_link)
        if snap is None:
            warm_cycles = float("inf")  # nothing to hand off
        else:
            warm_cycles = (ship_cycles(snap, self.link,
                                       kickoff_cycles=self.kickoff_cycles)
                           + warm_delta.t_set)
        mode = self.policy
        if mode == "auto":
            mode = "warm" if warm_cycles < cold.t_set else "cold"
        if snap is None:
            mode = "cold"
        return MigrationEstimate(
            tenant=tenant,
            src=src_id,
            dst=dst_id,
            mode=mode,
            warm_cycles=warm_cycles,
            cold_cycles=cold.t_set,
            context_fields=snap.n_fields if snap else 0,
            context_bytes=snap.context_bytes if snap else 0,
            warm_port_bytes=warm_delta.nbytes,
            cold_port_bytes=cold.nbytes,
        )

    # -- execution -----------------------------------------------------------

    def migrate(self, tenant: str, src, dst, probe, *,
                now: float = 0.0) -> MigrationRecord:
        """Move the tenant: execute the estimate's cheaper mode. Warm moves
        occupy the shared migration link (concurrent hand-offs serialize);
        either way the source context is dropped — the tenant has left."""
        est = self.estimate(tenant, src, dst, probe)
        snap: ContextSnapshot | None = None
        xfer: Transfer | None = None
        if est.mode == "warm":
            src_dev = context_device(src, tenant, getattr(probe, "accel", None))
            snap = capture(src_dev.cache, tenant, src_dev.model)
            xfer = self.port.acquire(
                now,
                ship_cycles(snap, self.link, kickoff_cycles=self.kickoff_cycles),
                nbytes=snap.context_bytes,
                tag=tenant,
                mode="burst" if self.link.supports_dma else "mmio",
            )
            dst_sched = _sched(dst)
            dst_dev = min(_devices(dst, snap.accel),
                          key=lambda d: (d.queue.backlog(dst_sched.host), d.id))
            install(dst_dev.cache, snap)
        _sched(src).invalidate(tenant)
        rec = MigrationRecord(estimate=est, snapshot=snap, transfer=xfer)
        self.migrations.append(rec)
        return rec


# -- cross-run persistence ---------------------------------------------------


def capture_contexts(host, tenants: Iterable[str] | None = None
                     ) -> list[ContextSnapshot]:
    """Snapshot every resident tenant context on a host (one snapshot per
    tenant — the richest across its devices), e.g. at shutdown."""
    wanted = set(tenants) if tenants is not None else None
    best: dict[str, ContextSnapshot] = {}
    for dev in _sched(host).devices:
        for tenant in dev.cache.tenants():
            if wanted is not None and tenant not in wanted:
                continue
            snap = capture(dev.cache, tenant, dev.model)
            if snap and (tenant not in best
                         or snap.n_fields > best[tenant].n_fields):
                best[tenant] = snap
    return [best[t] for t in sorted(best)]


def install_contexts(host, snapshots: Iterable[ContextSnapshot]) -> int:
    """Adopt snapshots onto a host (each on the least-loaded device of its
    kind); returns how many were installed. Snapshots for device kinds the
    host does not carry are skipped."""
    sched = _sched(host)
    n = 0
    for snap in snapshots:
        devs = [d for d in sched.devices if d.model.name == snap.accel]
        if not devs:
            continue
        dev = min(devs, key=lambda d: (d.queue.backlog(sched.host), d.id))
        install(dev.cache, snap)
        n += 1
    return n


class ContextStore:
    """Persist tenant contexts across runs through the checkpoint layer:
    atomic step directories, per-array CRCs, async save — so a recurring
    tenant's warmth survives restarts. Snapshots go in as their CRC-guarded
    wire bytes (one ``uint8`` leaf per tenant)."""

    def __init__(self, directory: str, keep: int = 3):
        # lazy import: the fabric cost models stay usable without jax
        from ..checkpoint.store import CheckpointStore

        self._store = CheckpointStore(directory, keep=keep)

    def save(self, step: int, snapshots: Iterable[ContextSnapshot], *,
             blocking: bool = True) -> None:
        import numpy as np

        tree = {
            s.tenant: np.frombuffer(s.to_bytes(), dtype=np.uint8).copy()
            for s in snapshots
        }
        assert tree, "nothing to persist: no resident contexts captured"
        self._store.save(step, tree, blocking=blocking)

    def restore(self, step: int | None = None) -> dict[str, ContextSnapshot]:
        """Tenant → snapshot at ``step`` (default: latest; empty dict when
        nothing was ever saved). Corruption fails loudly twice over: the
        checkpoint layer checks file CRCs, the snapshot its payload CRC."""
        import numpy as np

        if step is None:
            step = self._store.latest_step()
            if step is None:
                return {}
        like = {k: np.zeros(0, np.uint8) for k in self._store.keys(step)}
        tree = self._store.restore(step, like)
        return {
            tenant: ContextSnapshot.from_bytes(bytes(np.asarray(arr)))
            for tenant, arr in tree.items()
        }
