"""Register-context snapshots — the tenant-migration hand-off primitive.

A tenant's warmth lives in one place: the register values its
``sched.ConfigStateCache`` context says the device still holds. Moving the
tenant to another host today means losing that context and paying a cold
full re-send. A :class:`ContextSnapshot` makes the context itself portable:

* :func:`capture` lifts a tenant's context out of a device cache,
* :meth:`ContextSnapshot.to_bytes` / :meth:`~ContextSnapshot.from_bytes`
  give it a CRC-guarded wire format (shippable over a fabric link, or
  persisted through ``checkpoint.CheckpointStore`` for cross-run warmth),
* :func:`install` adopts it into the destination cache, so the tenant's
  next dispatch there is a context *hit* and pays only its delta.

The cost asymmetry that makes hand-off worthwhile: a snapshot carries raw
register **values**, so shipping it is one DMA burst with no per-field
parameter recalculation — whereas a cold re-send pays the full T_calc +
T_set of Eq. 4 through the destination's config port. ``fabric.migrate``
prices both and picks the cheaper.
"""

from __future__ import annotations

import json
import struct
import zlib
from dataclasses import dataclass
from typing import Any, Mapping

from .link import LinkModel

_MAGIC = b"CTX1"


@dataclass(frozen=True)
class ContextSnapshot:
    """One tenant's cached register file, portable across hosts and runs."""

    tenant: str
    accel: str  # device kind the register file belongs to
    bytes_per_field: int
    fields: dict[str, Any]  # register name -> last-written value

    @property
    def n_fields(self) -> int:
        return len(self.fields)

    @property
    def context_bytes(self) -> int:
        """Register payload a hand-off must move (model-unit bytes — the
        same accounting the state cache and telemetry use)."""
        return self.n_fields * self.bytes_per_field

    # -- wire format ---------------------------------------------------------

    def to_bytes(self) -> bytes:
        """``CTX1 | crc32(payload) | payload`` — JSON payload with sorted
        keys so identical contexts serialize identically."""
        payload = json.dumps(
            {
                "tenant": self.tenant,
                "accel": self.accel,
                "bytes_per_field": self.bytes_per_field,
                "fields": {k: int(v) for k, v in self.fields.items()},
            },
            sort_keys=True,
        ).encode()
        return _MAGIC + struct.pack("<I", zlib.crc32(payload)) + payload

    @classmethod
    def from_bytes(cls, raw: bytes) -> "ContextSnapshot":
        if raw[:4] != _MAGIC:
            raise ValueError("not a context snapshot (bad magic)")
        (crc,) = struct.unpack("<I", raw[4:8])
        payload = raw[8:]
        if zlib.crc32(payload) != crc:
            raise ValueError("context snapshot corruption: CRC mismatch")
        d = json.loads(payload)
        return cls(tenant=d["tenant"], accel=d["accel"],
                   bytes_per_field=int(d["bytes_per_field"]),
                   fields=dict(d["fields"]))


def capture(cache, tenant: str, model) -> ContextSnapshot | None:
    """Lift ``tenant``'s resident context out of a device's
    ``ConfigStateCache`` (``None`` when the context is cold/evicted)."""
    ctx = cache.context(tenant)
    if ctx is None:
        return None
    return ContextSnapshot(tenant=tenant, accel=model.name,
                           bytes_per_field=model.bytes_per_field,
                           fields=dict(ctx))


def install(cache, snap: ContextSnapshot) -> None:
    """Adopt a snapshot into a destination cache: the tenant's next
    dispatch there is a context hit paying only its register delta."""
    cache.install_context(snap.tenant, dict(snap.fields))


def ship_cycles(snap: ContextSnapshot, link: LinkModel, *,
                kickoff_cycles: float = 8.0) -> float:
    """Cycles to move a snapshot over ``link``: raw register values go as
    one DMA burst (no per-field parameter recalculation — the hand-off's
    whole advantage); links without DMA fall back to per-field writes."""
    if link.supports_dma:
        return kickoff_cycles + link.burst_cycles(snap.context_bytes)
    return kickoff_cycles + link.mmio_cycles(snap.n_fields, snap.bytes_per_field)


def delta_fields(snap: ContextSnapshot | None,
                 regs: Mapping[str, Any]) -> dict[str, Any]:
    """The register fields of ``regs`` a snapshot does *not* already hold —
    what the tenant's next launch would still have to send after a warm
    hand-off (bit-exact comparison, mirroring the state cache)."""
    if snap is None:
        return dict(regs)
    return {name: value for name, value in regs.items()
            if name not in snap.fields or snap.fields[name] != value}
