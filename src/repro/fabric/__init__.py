"""repro.fabric — interconnect-aware configuration transport.

The layers below assume config writes land on a core-local CSR port; this
package puts the *interconnect* into the model — the transport path that
dominates offload cost in deployed MPSoCs (Colagrande & Benini) and that
"Know your rooflines!" argues must appear as an explicit roofline ceiling:

* :mod:`~repro.fabric.link` — typed links (core-local CSR, NoC hop, PCIe)
  with latency/bandwidth/per-write vs. burst-DMA cost models, and
  :class:`LinkPort` contention queues so concurrent tenants share wire
  bandwidth.
* :mod:`~repro.fabric.transport` — turns a cache write-plan into a
  transfer schedule: per-register MMIO vs. one coalesced burst descriptor,
  whichever yields the smaller T_set (Eq. 4).
* :mod:`~repro.fabric.snapshot` — CRC-guarded, serializable register-context
  snapshots: capture from a ``ConfigStateCache``, ship across a link,
  install at the destination — the migration hand-off primitive.
* :mod:`~repro.fabric.migrate` — the migration planner (warm hand-off vs.
  cold resend, executed over a shared contended link) and
  :class:`ContextStore`, which persists contexts through
  ``checkpoint.CheckpointStore`` so recurring tenants restore warm across
  runs.

``sched.Scheduler`` prices every config write through this layer (a
``link="csr"`` fabric reproduces the pre-fabric numbers bit-exactly), and
``cluster.Host`` exposes the link as its config port.
"""

from . import link, migrate, snapshot, transport
from .link import LINKS, LinkModel, LinkPort, Transfer, csr_local, noc, pcie, resolve_link
from .migrate import (
    ContextStore,
    MigrationEstimate,
    MigrationPlanner,
    MigrationRecord,
    capture_contexts,
    context_device,
    install_contexts,
)
from .snapshot import ContextSnapshot, capture, delta_fields, install, ship_cycles
from .transport import (
    TransferSchedule,
    burst_schedule,
    crossover_fields,
    mmio_schedule,
    plan_fields,
    plan_transfer,
)

__all__ = [
    "LINKS",
    "ContextSnapshot",
    "ContextStore",
    "LinkModel",
    "LinkPort",
    "MigrationEstimate",
    "MigrationPlanner",
    "MigrationRecord",
    "Transfer",
    "TransferSchedule",
    "burst_schedule",
    "capture",
    "capture_contexts",
    "context_device",
    "crossover_fields",
    "csr_local",
    "delta_fields",
    "install",
    "install_contexts",
    "link",
    "migrate",
    "mmio_schedule",
    "noc",
    "pcie",
    "plan_fields",
    "plan_transfer",
    "resolve_link",
    "ship_cycles",
    "snapshot",
    "transport",
]
