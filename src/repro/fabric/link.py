"""Typed interconnect links for configuration transport.

The paper's host model (and PR 1/2's scheduler above it) assumes config
writes land on a *core-local* CSR port: the only cost is host instruction
time. In deployed systems the write crosses an interconnect — a NoC hop to
a far cluster, or a PCIe transaction to a discrete card — whose latency and
bandwidth must show up in ``T_set`` (Eq. 4) and therefore as a ceiling on
the configuration roofline ("Know your rooflines!": transfer terms belong
on the plot, not in a footnote). Colagrande & Benini measure exactly this:
offload cost on a many-cluster MPSoC is dominated by the transport path,
not the accelerator.

Three link classes span the design space:

* :func:`csr_local` — the paper's baseline. Zero latency, infinite
  bandwidth: configuration cost is pure host instruction time, so every
  existing single-host result is reproduced bit-exactly.
* :func:`noc` — an on-chip network hop (or several): a handful of cycles
  of latency per transaction, wide links, a cheap DMA engine.
* :func:`pcie` — off-chip: hundreds of cycles per non-posted transaction,
  narrower effective bandwidth, expensive-but-amortizable DMA bursts.

Each link prices the two transport disciplines ``fabric.transport``
chooses between:

* **MMIO** (:meth:`LinkModel.mmio_cycles`) — one transaction per config
  write; every write pays the full link latency (writes to device registers
  are strongly ordered, so they do not pipeline).
* **Burst DMA** (:meth:`LinkModel.burst_cycles`) — the host programs a
  descriptor (``burst_setup``) and a DMA engine streams the whole register
  image at link bandwidth, paying the latency once per ``max_burst`` bytes.

:class:`LinkPort` adds the *contention* dimension: one link instance shared
by concurrent tenants serializes their transfers FIFO (a transfer occupies
the wire until it completes), and logs every transfer so
``sched.telemetry`` can export per-link busy/occupancy timelines.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from ..engine.resources import Resource


@dataclass(frozen=True)
class LinkModel:
    """One interconnect class on the config-transport path."""

    name: str
    kind: str  # "csr" | "noc" | "pcie"
    latency: float  # cycles one transaction spends crossing the link
    bandwidth: float  # payload bytes per cycle once streaming
    supports_dma: bool  # is there a DMA engine that can burst descriptors?
    burst_setup: float = 0.0  # cycles to program one DMA burst descriptor
    max_burst: int = 4096  # payload bytes one burst descriptor may carry
    hops: int = 0  # topological distance (0 = core-local)
    # -- energy rates (pJ) — the joule axis the cycle model is blind to.
    # MMIO pays a handshake per ordered write; burst DMA pays a descriptor
    # setup per burst plus a streaming cost per byte. The per-byte cost is
    # shared, so the cycle-cheaper and joule-cheaper mode can differ: burst
    # amortizes *latency* aggressively but its descriptor setup energy can
    # exceed a few MMIO handshakes (transport.plan_fields(objective=...))
    mmio_write_energy: float = 0.0  # pJ per ordered register-write handshake
    byte_energy: float = 0.0  # pJ per payload byte streamed, either mode
    burst_setup_energy: float = 0.0  # pJ to build + launch one DMA descriptor
    # posted-write combining depth: how many config writes the link's write
    # buffer may coalesce into one transaction before it must drain. 0 (the
    # default, every stock link) disables the "wc" transport discipline
    # entirely, so existing MMIO numbers are reproduced bit-exactly.
    wc_depth: int = 0

    def write_cycles(self, nbytes: float) -> float:
        """One ordered register write of ``nbytes`` crossing the link."""
        return self.latency + nbytes / self.bandwidth

    def mmio_cycles(self, n_writes: int, nbytes_per_write: float) -> float:
        """``n_writes`` strongly-ordered register writes — each pays the
        full latency (device MMIO does not pipeline)."""
        return n_writes * self.write_cycles(nbytes_per_write)

    def burst_cycles(self, nbytes: float) -> float:
        """One DMA transfer of ``nbytes``: per-burst descriptor setup and
        latency, then the payload streams at link bandwidth."""
        assert self.supports_dma, f"link {self.name!r} has no DMA engine"
        bursts = max(1, math.ceil(nbytes / self.max_burst))
        return bursts * (self.burst_setup + self.latency) + nbytes / self.bandwidth

    def wc_cycles(self, n_writes: int, nbytes_per_write: float) -> float:
        """``n_writes`` *posted* register writes through a write-combining
        buffer: up to ``wc_depth`` consecutive writes coalesce into one
        transaction, so the link latency is paid once per batch instead of
        once per write, and the payload streams at link bandwidth — MMIO's
        ordering cost partially amortized, the way burst DMA amortizes it
        fully (no descriptor to program, but no deep bursts either)."""
        assert self.wc_depth >= 2, \
            f"link {self.name!r} has no write-combining buffer"
        if n_writes <= 0:
            return 0.0
        batches = math.ceil(n_writes / self.wc_depth)
        return (batches * self.latency
                + n_writes * nbytes_per_write / self.bandwidth)

    def transfer_energy(self, mode: str, nbytes: float,
                        n_writes: int | None = None) -> float:
        """Wire energy (pJ) of moving ``nbytes`` in ``mode``. When the MMIO
        write count is not known (e.g. a migration snapshot priced outside
        ``fabric.transport``), each write is assumed to carry ``max_burst``
        — a lower bound on handshake count. ``transport.TransferSchedule``
        passes the exact count, so launch traffic never takes the guess."""
        if nbytes <= 0:
            return 0.0
        streamed = nbytes * self.byte_energy
        if mode == "burst":
            bursts = max(1, math.ceil(nbytes / self.max_burst))
            return bursts * self.burst_setup_energy + streamed
        if n_writes is None:
            n_writes = max(1, math.ceil(nbytes / self.max_burst))
        if mode == "wc" and self.wc_depth >= 2:
            # one handshake per coalesced batch, not per posted write
            batches = max(1, math.ceil(n_writes / self.wc_depth))
            return batches * self.mmio_write_energy + streamed
        return n_writes * self.mmio_write_energy + streamed


def csr_local() -> LinkModel:
    """Core-local CSR port — the paper's host model. Zero wire cost, so the
    pre-fabric scheduler numbers are reproduced exactly; no DMA engine (a
    core writes its own CSRs faster than it could program a descriptor)."""
    return LinkModel(name="csr", kind="csr", latency=0.0,
                     bandwidth=float("inf"), supports_dma=False, hops=0,
                     mmio_write_energy=0.5, byte_energy=0.05)


def noc(hops: int = 1) -> LinkModel:
    """On-chip network: ~12 cycles of router/wire latency per hop, 8 B/cycle
    links, a lightweight cluster DMA (cf. the Snitch/Occamy iDMA path)."""
    assert hops >= 1
    # energy scales with distance: every hop's router switches per flit
    # (per-byte) and per handshake; the DMA descriptor setup energy is
    # deliberately the expensive term — on-chip it buys little over a few
    # cheap MMIO handshakes, so the joule-optimal crossover sits *later*
    # than the cycle-optimal one (pinned in tests/test_power.py)
    return LinkModel(name=f"noc{hops}" if hops > 1 else "noc", kind="noc",
                     latency=12.0 * hops, bandwidth=8.0, supports_dma=True,
                     burst_setup=24.0, max_burst=1024, hops=hops,
                     mmio_write_energy=6.0 * hops, byte_energy=0.3 * hops,
                     burst_setup_energy=48.0 * hops)


def pcie() -> LinkModel:
    """Off-chip PCIe: non-posted writes cost hundreds of cycles round-trip;
    DMA descriptors are expensive to build but carry 4 KiB bursts."""
    return LinkModel(name="pcie", kind="pcie", latency=350.0, bandwidth=4.0,
                     supports_dma=True, burst_setup=96.0, max_burst=4096,
                     hops=1, mmio_write_energy=150.0, byte_energy=1.0,
                     burst_setup_energy=400.0)


def with_write_combining(link: LinkModel, depth: int = 8) -> LinkModel:
    """The same link with an ``depth``-entry posted-write-combining buffer
    (and a ``_wc`` name suffix). A separate constructor — not a default —
    so every stock link keeps ``wc_depth=0`` and its committed transport
    numbers stay bit-exact."""
    assert depth >= 2, "a write-combining buffer needs ≥ 2 entries"
    return dataclasses.replace(link, name=f"{link.name}_wc", wc_depth=depth)


LINKS: dict[str, LinkModel] = {
    "csr": csr_local(),
    "noc": noc(),
    "noc2": noc(2),
    "pcie": pcie(),
    # write-combining variants: same wire, an 8-deep posted-write buffer
    "noc_wc": with_write_combining(noc()),
    "pcie_wc": with_write_combining(pcie()),
}


def resolve_link(spec: "LinkModel | str | None") -> LinkModel:
    """``None`` → the paper's core-local baseline; a string → ``LINKS``."""
    if spec is None:
        return LINKS["csr"]
    if isinstance(spec, LinkModel):
        return spec
    assert spec in LINKS, f"unknown link {spec!r} (have {sorted(LINKS)})"
    return LINKS[spec]


# -- contention --------------------------------------------------------------


@dataclass(frozen=True)
class Transfer:
    """One completed occupancy of a link."""

    start: float
    end: float
    nbytes: int
    tag: str  # tenant / purpose
    mode: str  # "mmio" | "burst" | "wc"
    energy: float = 0.0  # pJ this transfer burned on the wire

    @property
    def cycles(self) -> float:
        return self.end - self.start


class LinkPort:
    """One shared link instance: concurrent tenants' transfers serialize
    FIFO on the wire, and every occupancy is logged for telemetry.

    The wire is a reservable engine resource (:class:`~repro.engine.resources.Resource`,
    exposed as :attr:`res`): ``sched.Scheduler`` folds it into its
    :class:`~repro.engine.resources.EngineResources` so host, wire, and
    compute occupancy live in one vocabulary. One ``LinkPort`` may be
    shared by *several* hosts (a cluster-level PCIe switch): every
    sharer's config transfers then contend on the same FIFO timeline —
    ``cluster.Cluster.uniform(shared_port=True)`` builds that topology."""

    def __init__(self, link: LinkModel, name: str = "link"):
        self.link = link
        self.name = name
        self.res = Resource(name, kind="wire")
        self.log: list[Transfer] = []
        # observation-only hook (repro.obs.trace): set by the first
        # scheduler that attaches a tracer — always the *unbound* root, so
        # a port shared by several hosts traces under one fabric lane
        self.tracer = None

    @property
    def busy_until(self) -> float:
        """The wire's committed time (the resource's clock)."""
        return self.res.free

    def backlog(self, now: float) -> float:
        """Cycles the wire is already committed beyond ``now``."""
        return self.res.backlog(now)

    def acquire(self, now: float, cycles: float, *, nbytes: int = 0,
                tag: str = "", mode: str = "mmio",
                energy: float | None = None) -> Transfer:
        """Occupy the link for ``cycles`` starting no earlier than ``now``
        (a busy wire pushes the transfer back — bandwidth sharing as FIFO
        serialization). Returns the resolved transfer.

        ``energy`` is the transfer's wire joules; callers that priced the
        transfer (``transport.TransferSchedule``) pass the exact figure so
        the meter reads plan-time numbers verbatim. ``None`` falls back to
        the link's own estimate — migration snapshots and other non-launch
        traffic, where the MMIO write count is not known here."""
        if energy is None:
            energy = self.link.transfer_energy(mode, nbytes)
        iv = self.res.reserve(now, cycles, tag=tag)
        xfer = Transfer(start=iv.start, end=iv.end, nbytes=int(nbytes),
                        tag=tag, mode=mode, energy=float(energy))
        self.log.append(xfer)
        if self.tracer is not None and cycles > 0.0:
            self.tracer.span(mode, "wire", iv.start, iv.end, lane=self.name,
                             tenant=tag, nbytes=int(nbytes))
        return xfer

    # -- observables ---------------------------------------------------------

    @property
    def busy_cycles(self) -> float:
        return sum(t.cycles for t in self.log)

    @property
    def bytes_moved(self) -> int:
        return sum(t.nbytes for t in self.log)

    @property
    def transfer_joules(self) -> float:
        """Total wire energy (pJ) of every logged transfer."""
        return sum(t.energy for t in self.log)

    def occupancy(self, makespan: float) -> float:
        """Fraction of the run the wire was busy."""
        return self.busy_cycles / makespan if makespan else 0.0
