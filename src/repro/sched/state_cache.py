"""Runtime configuration-state cache — the dispatch-time analogue of
``passes/dedup.py``.

The compile-time dedup pass (§5.4) removes a setup field when SSA analysis
*proves* the register already holds the value. At runtime no proof is needed:
the host simply remembers what it last wrote to each device and elides any
write whose value the device demonstrably still holds (configuration
registers retain their contents between launches, §3.2 — the same hardware
property both layers exploit).

Multi-tenancy complicates retention: two streams sharing one device would
clobber each other's register file, so the cache models *per-tenant
contexts* — independent snapshots of the register state each tenant believes
the device holds — bounded by ``max_contexts`` with LRU eviction, like
hardware context slots. A context miss (first dispatch, or re-admission
after eviction) forces a full re-send; a hit sends only the delta.

Values are compared bit-exactly (``numpy.array_equal`` semantics), so the
cache works both for the cycle-approximate accfg register model (ints) and
for real JAX launch descriptors (scalars / small arrays)."""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import numpy as np


def _same(a: Any, b: Any) -> bool:
    """Bit-exact value equality across ints, floats and small arrays."""
    if a is b:
        return True
    try:
        return bool(np.array_equal(np.asarray(a), np.asarray(b)))
    except Exception:
        return a == b


def nbytes_of(value: Any) -> int:
    """Default byte accounting: the numpy wire size of the value."""
    return int(np.asarray(value).nbytes)


def elision_ratio(bytes_sent: float, bytes_elided: float) -> float:
    """Fraction of configuration bytes kept off the wire — the one formula
    every traffic report in this package shares."""
    total = bytes_sent + bytes_elided
    return bytes_elided / total if total else 0.0


@dataclass(frozen=True)
class WritePlan:
    """The outcome of routing one launch descriptor through the cache."""

    sent: dict[str, Any]  # fields that must cross the host→device boundary
    elided: dict[str, Any]  # fields the device already holds
    bytes_sent: int
    bytes_elided: int
    context_hit: bool  # was the tenant's context resident?

    @property
    def total_bytes(self) -> int:
        return self.bytes_sent + self.bytes_elided


@dataclass
class CacheStats:
    hits: int = 0  # context-resident dispatches
    misses: int = 0  # cold / evicted contexts
    evictions: int = 0
    bytes_sent: int = 0
    bytes_elided: int = 0
    fields_sent: int = 0
    fields_elided: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    @property
    def elision_ratio(self) -> float:
        """Fraction of configuration bytes the cache kept off the wire."""
        return elision_ratio(self.bytes_sent, self.bytes_elided)


class ConfigStateCache:
    """Last-written register values for one device, per tenant context.

    ``bytes_of(name, value)`` prices one field; the default uses the value's
    numpy size, while the scheduler substitutes the accelerator model's
    ``bytes_per_field`` so accounting matches the paper's register model.
    """

    def __init__(
        self,
        max_contexts: int = 4,
        bytes_of: Callable[[str, Any], int] | None = None,
    ):
        assert max_contexts >= 1
        self.max_contexts = max_contexts
        self._bytes_of = bytes_of or (lambda name, value: nbytes_of(value))
        self._contexts: OrderedDict[Any, dict[str, Any]] = OrderedDict()
        self.stats = CacheStats()

    # -- queries (no mutation) ----------------------------------------------

    def context(self, tenant: Any) -> dict[str, Any] | None:
        return self._contexts.get(tenant)

    def tenants(self) -> list[Any]:
        """Resident tenants, LRU-oldest first."""
        return list(self._contexts)

    def plan(self, tenant: Any, fields: Mapping[str, Any]) -> WritePlan:
        """Split ``fields`` into sent/elided against the tenant's context
        without touching cache state (used for affinity scoring)."""
        ctx = self._contexts.get(tenant)
        sent: dict[str, Any] = {}
        elided: dict[str, Any] = {}
        for name, value in fields.items():
            if ctx is not None and name in ctx and _same(ctx[name], value):
                elided[name] = value
            else:
                sent[name] = value
        return WritePlan(
            sent=sent,
            elided=elided,
            bytes_sent=sum(self._bytes_of(n, v) for n, v in sent.items()),
            bytes_elided=sum(self._bytes_of(n, v) for n, v in elided.items()),
            context_hit=ctx is not None,
        )

    def elidable_bytes(self, tenant: Any, fields: Mapping[str, Any]) -> int:
        """Affinity metric: bytes this device would keep off the wire."""
        return self.plan(tenant, fields).bytes_elided

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, tenant: Any, fields: Mapping[str, Any]) -> WritePlan:
        """Route one launch's configuration through the cache: compute the
        write delta, commit it to the tenant's context, update LRU + stats."""
        plan = self.plan(tenant, fields)
        if plan.context_hit:
            self.stats.hits += 1
            self._contexts.move_to_end(tenant)
        else:
            self.stats.misses += 1
            while len(self._contexts) >= self.max_contexts:
                self._contexts.popitem(last=False)  # LRU out
                self.stats.evictions += 1
            self._contexts[tenant] = {}
        self._contexts[tenant].update(fields)
        self.stats.bytes_sent += plan.bytes_sent
        self.stats.bytes_elided += plan.bytes_elided
        self.stats.fields_sent += len(plan.sent)
        self.stats.fields_elided += len(plan.elided)
        return plan

    # -- migration / restore -------------------------------------------------

    def install_context(self, tenant: Any, fields: Mapping[str, Any]) -> None:
        """Adopt a register context captured elsewhere (a migration
        hand-off or a checkpoint restore, ``fabric.snapshot``): the
        tenant's next dispatch here is a context hit and pays only its
        delta. Counts neither hit nor miss — no dispatch happened — but
        evictions it forces are recorded, and LRU order treats the install
        as a use."""
        if tenant in self._contexts:
            self._contexts.move_to_end(tenant)
        else:
            while len(self._contexts) >= self.max_contexts:
                self._contexts.popitem(last=False)
                self.stats.evictions += 1
            self._contexts[tenant] = {}
        self._contexts[tenant].update(fields)

    # -- invalidation --------------------------------------------------------

    def invalidate(self, tenant: Any | None = None) -> None:
        """Drop cached state — one tenant's context, or everything (the
        runtime mirror of ``effects = "all"`` clobbering calls, §5.1)."""
        if tenant is None:
            self._contexts.clear()
        else:
            self._contexts.pop(tenant, None)
