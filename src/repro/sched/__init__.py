"""repro.sched — runtime scheduling against the configuration wall.

The compile-time story (``core.passes``) eliminates configuration overhead
*within one program*: dedup removes register writes whose values provably
persist, overlap hides the rest behind accelerator busy time. A serving
system hits the same wall again *across* programs: every dispatch re-sends
state the device may already hold, and a single host serializes
configuration across the whole accelerator pool.

``repro.sched`` is the runtime mirror of those passes:

* :mod:`~repro.sched.state_cache` — dedup at dispatch time: a per-device,
  per-tenant-context cache of last-written register values that elides
  redundant config writes (LRU-bounded contexts let tenants share a device).
* :mod:`~repro.sched.queue` — overlap at dispatch time: depth-k staged
  launch queues (OpenGeMM-style staging) with the sequential-stall fallback
  for ``concurrent=False`` devices.
* :mod:`~repro.sched.scheduler` — config-affinity placement: route each
  launch to the pool device whose cached state maximizes write elision,
  spilling on admission delay so affinity and load balance share one score.
* :mod:`~repro.sched.telemetry` — bytes sent vs. elided, hit rates and
  busy/idle cycles, exported as ``interp.Trace`` timelines and
  ``RooflinePoint`` placements so scheduled pools land on the same plots as
  compiled programs.
"""

from . import queue, scheduler, state_cache, telemetry
from .queue import (
    AdmissionQueue,
    LaunchQueue,
    LaunchTiming,
    Staged,
    arrival_order,
    edf_order,
)
from .scheduler import Device, LaunchRequest, Scheduler, requests_from_trace
from .state_cache import CacheStats, ConfigStateCache, WritePlan, nbytes_of
from .telemetry import (
    DeviceTelemetry,
    LaunchRecord,
    LinkTelemetry,
    ResourceTelemetry,
    SchedulerReport,
    geomean,
)

__all__ = [
    "AdmissionQueue",
    "CacheStats",
    "ConfigStateCache",
    "Device",
    "DeviceTelemetry",
    "LaunchQueue",
    "LaunchRecord",
    "LaunchRequest",
    "LaunchTiming",
    "LinkTelemetry",
    "ResourceTelemetry",
    "Scheduler",
    "SchedulerReport",
    "Staged",
    "WritePlan",
    "arrival_order",
    "edf_order",
    "geomean",
    "nbytes_of",
    "queue",
    "requests_from_trace",
    "scheduler",
    "state_cache",
    "telemetry",
]
