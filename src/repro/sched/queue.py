"""Depth-k staged launch queues — per-device dispatch timing.

Models the two configuration disciplines the paper characterizes, per
device, against a single host clock owned by the scheduler:

* **Sequential** (Gemmini, §2.2): the host stalls at launch until the
  macro-op retires. ``depth`` is irrelevant — there is never more than one
  invocation outstanding.
* **Concurrent** (OpenGeMM, §6.2): launches are *staged*; the host returns
  immediately and keeps configuring the next invocation while the device
  runs. Up to ``depth`` launches may be outstanding (the size of the staging
  register file / descriptor ring); when the ring is full the host blocks
  until the oldest invocation retires. ``depth=1`` degenerates to the
  interpreter's launch-blocks-until-free model; larger depths are the
  OpenGeMM-style ring that `dispatch.ConcurrentExecutor` realizes on the
  real JAX runtime.

Staged launches that have not yet *started* are preemptible: a
higher-priority request can cancel the newest staged entry
(:meth:`LaunchQueue.preempt_tail`) and take its ring slot — the scheduler
re-dispatches the victim afterwards. A macro-op that already began is never
aborted; only staging-register state is discarded.

The queue only does *timing*; byte accounting lives in the state cache and
placement lives in the scheduler.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any

from ..core.accelerators import AcceleratorModel


@dataclass(frozen=True)
class LaunchTiming:
    """One invocation's resolved timeline."""

    host_after: float  # host clock after the launch was issued
    start: float  # device begins the macro-op
    end: float  # macro-op retires
    stall: float  # host cycles spent blocked on this launch


@dataclass(frozen=True)
class Staged:
    """One entry in the staging ring."""

    start: float  # device time the macro-op begins
    end: float  # device time it retires
    priority: int = 0
    token: Any = None  # opaque scheduler handle (the LaunchRequest)


class LaunchQueue:
    """Launch staging for one device instance."""

    def __init__(self, model: AcceleratorModel, depth: int = 2):
        assert depth >= 1
        self.model = model
        self.depth = depth if model.concurrent else 1
        self.device_free = 0.0
        self._inflight: deque[Staged] = deque()  # unretired invocations

    @property
    def outstanding(self) -> int:
        return len(self._inflight)

    def backlog(self, host: float) -> float:
        """Cycles the device is already committed beyond ``host`` — the
        load-balance term of the placement score."""
        return max(0.0, self.device_free - host)

    def admission_delay(self, host: float) -> float:
        """Cycles the *host* would block if it launched now (queue-full wait
        for concurrent devices; full occupancy for sequential ones).

        Pure query: the scheduler probes candidate devices with hypothetical
        future timestamps while scoring placements, so nothing may retire
        here — only ``submit`` advances queue state."""
        if not self.model.concurrent:
            return self.backlog(host)
        live = [s.end for s in self._inflight if s.end > host]
        if len(live) < self.depth:
            return 0.0
        return live[len(live) - self.depth] - host

    def tail(self) -> Staged | None:
        """The newest staged entry (the only preemptible one)."""
        return self._inflight[-1] if self._inflight else None

    def preempt_tail(self, host: float, priority: int) -> Staged | None:
        """Cancel the newest staged launch iff it has not yet started at
        ``host`` and its priority is strictly below ``priority``. Returns
        the cancelled entry (its ``token`` lets the scheduler re-dispatch
        the victim) or ``None`` when nothing is preemptible."""
        if not self.model.concurrent or not self._inflight:
            return None
        victim = self._inflight[-1]
        if victim.start <= host or victim.priority >= priority:
            return None
        self._inflight.pop()
        # the device is committed only through the previous entry now; if the
        # ring emptied, it runs no later than the victim would have started
        self.device_free = (
            self._inflight[-1].end if self._inflight else victim.start
        )
        return victim

    def _retire(self, host: float) -> None:
        while self._inflight and self._inflight[0].end <= host:
            self._inflight.popleft()

    def submit(self, host: float, duration: float, *, priority: int = 0,
               token: Any = None) -> LaunchTiming:
        """Issue a launch at host time ``host`` (configuration already
        written); returns the resolved timing and the new host clock."""
        t0 = host
        if self.model.concurrent:
            self._retire(host)
            # staging ring full: block until the oldest staged op frees a slot
            while len(self._inflight) >= self.depth:
                host = max(host, self._inflight.popleft().end)
            start = max(host, self.device_free)
        else:
            # sequential configuration: the host is captive until retirement
            start = max(host, self.device_free)
        end = start + duration
        self.device_free = end
        if self.model.concurrent:
            self._inflight.append(Staged(start, end, priority, token))
        else:
            host = end
        return LaunchTiming(host_after=host, start=start, end=end, stall=host - t0)

    def drain(self, host: float) -> float:
        """Host time once every staged invocation has retired."""
        self._inflight.clear()
        return max(host, self.device_free)
