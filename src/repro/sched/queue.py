"""Launch queues: admission ordering and depth-k staged dispatch timing.

**Admission** (:class:`AdmissionQueue`): the order in which an open-loop
drain hands arrived requests to the scheduler — plain arrival order (ties
to higher priority), earliest-deadline-first over whatever has already
arrived, or cache-warmth-aware (``"warm"``: warm residents drain ahead of
cold tenants, bounded by each cold request's deadline slack). EDF only
reorders the *backlog*: with no backlog (or no deadlines set) it degrades
to the priority-class order, so best-effort traffic is unaffected.

**Staging** (:class:`LaunchQueue`): per-device dispatch timing.

Models the two configuration disciplines the paper characterizes, per
device, against a single host clock owned by the scheduler:

* **Sequential** (Gemmini, §2.2): the host stalls at launch until the
  macro-op retires. ``depth`` is irrelevant — there is never more than one
  invocation outstanding.
* **Concurrent** (OpenGeMM, §6.2): launches are *staged*; the host returns
  immediately and keeps configuring the next invocation while the device
  runs. Up to ``depth`` launches may be outstanding (the size of the staging
  register file / descriptor ring); when the ring is full the host blocks
  until the oldest invocation retires. ``depth=1`` degenerates to the
  interpreter's launch-blocks-until-free model; larger depths are the
  OpenGeMM-style ring that `dispatch.ConcurrentExecutor` realizes on the
  real JAX runtime.

Staged launches that have not yet *started* are preemptible: a
higher-priority request can cancel the newest staged entry
(:meth:`LaunchQueue.preempt_tail`) and take its ring slot — the scheduler
re-dispatches the victim afterwards. A macro-op that already began is never
aborted; only staging-register state is discarded.

The queue only does *timing*; byte accounting lives in the state cache and
placement lives in the scheduler.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Any, Iterable

from ..core.accelerators import AcceleratorModel
from ..engine.resources import Resource

ADMISSION_MODES = ("arrival", "edf", "warm")


def arrival_order(req) -> tuple[float, int, str]:
    """Admission sort key for open-loop drains — arrival time, ties to
    higher priority, then tenant for determinism. Shared by
    :meth:`Scheduler.run_open_loop` and ``cluster.Cluster.run`` so
    single-host and cluster runs admit identical traces identically."""
    return (req.arrival_time, -req.priority, req.tenant)


def edf_order(req) -> tuple[float, int, float, str]:
    """Earliest-deadline-first key over *arrived* requests. Requests
    without a deadline sort last (deadline = +inf), falling back to the
    priority-class order — a stream with no deadlines set behaves exactly
    like the priority scheduler."""
    deadline = getattr(req, "deadline", None)
    return (deadline if deadline is not None else float("inf"),
            -req.priority, req.arrival_time, req.tenant)


class AdmissionQueue:
    """Open-loop admission buffer: hands the scheduler its next request.

    ``mode="arrival"`` reproduces the classic drain (arrival order, ties to
    higher priority). ``mode="edf"`` admits everything that has arrived by
    the host clock and pops the earliest deadline among it — under a
    backlog (e.g. a burst episode), tight-deadline requests overtake loose
    ones they arrived behind.

    ``mode="warm"`` is cache-warmth-aware admission: among arrived
    requests, one whose tenant is *warm* (``warmth(req)`` — typically: a
    device cache still holds its context, so its config bytes elide) is
    admitted ahead of cold ones, letting a warm resident drain before a
    cold tenant forces a context turnover. The deferral is bounded by each
    cold request's deadline: once its slack (``deadline − now``) falls to
    ``warm_slack`` or below it jumps ahead of every non-urgent request —
    warmth batching must never buy config bytes with deadline misses.
    Within a class (urgent / warm / cold), EDF order applies."""

    def __init__(self, requests: Iterable, mode: str = "arrival", *,
                 warmth=None, warm_slack: float = 0.0):
        assert mode in ADMISSION_MODES, mode
        assert mode != "warm" or warmth is not None, \
            "mode='warm' needs a warmth(req) predicate"
        self.mode = mode
        self.warmth = warmth
        self.warm_slack = warm_slack
        self._future = deque(sorted(requests, key=arrival_order))
        self._ready: list[tuple] = []  # heap of (edf key, seq, request)
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)

    def _admit_until(self, t: float) -> None:
        while self._future and self._future[0].arrival_time <= t:
            req = self._future.popleft()
            heapq.heappush(self._ready, (edf_order(req), next(self._seq), req))

    def pop(self, now: float):
        """The next request to dispatch given host clock ``now``."""
        assert len(self), "pop from an empty admission queue"
        if self.mode == "arrival":
            return self._future.popleft()
        self._admit_until(now)
        if not self._ready:
            # the host is idle ahead of traffic: jump to the next arrival
            # instant and let everything landing there compete on deadline
            self._admit_until(self._future[0].arrival_time)
        if self.mode == "edf":
            return heapq.heappop(self._ready)[-1]
        return self._pop_warm(now)

    def _pop_warm(self, now: float):
        """Warmth-aware selection over the ready set: urgent (deadline
        slack ≤ ``warm_slack``) beats warm beats cold, EDF order within a
        class. A cold-only backlog drains in plain EDF order — warmth never
        idles the host waiting for a warm arrival that isn't here."""
        best_i = best_rank = None
        for i, (key, seq, req) in enumerate(self._ready):
            deadline = getattr(req, "deadline", None)
            urgent = deadline is not None and deadline - now <= self.warm_slack
            rank = (0 if urgent else 1,
                    0 if self.warmth(req) else 1,
                    key, seq)
            if best_rank is None or rank < best_rank:
                best_i, best_rank = i, rank
        chosen = self._ready.pop(best_i)[-1]
        heapq.heapify(self._ready)  # pop from the middle broke the heap
        return chosen


@dataclass(frozen=True)
class LaunchTiming:
    """One invocation's resolved timeline."""

    host_after: float  # host clock after the launch was issued
    start: float  # device begins the macro-op
    end: float  # macro-op retires
    stall: float  # host cycles spent blocked on this launch


@dataclass(frozen=True)
class Staged:
    """One entry in the staging ring."""

    start: float  # device time the macro-op begins
    end: float  # device time it retires
    priority: int = 0
    token: Any = None  # opaque scheduler handle (the LaunchRequest)


class LaunchQueue:
    """Launch staging for one device instance.

    The device's compute datapath is an engine resource
    (:class:`~repro.engine.resources.Resource`): every submitted macro-op
    reserves a busy interval on it, so ``device_free`` is the resource's
    clock and the scheduler's occupancy model (``EngineResources``) reads
    compute timelines straight from here."""

    def __init__(self, model: AcceleratorModel, depth: int = 2,
                 name: str = ""):
        assert depth >= 1
        self.model = model
        self.depth = depth if model.concurrent else 1
        self.compute = Resource(f"compute[{name or model.name}]",
                                kind="compute")
        self._inflight: deque[Staged] = deque()  # unretired invocations

    @property
    def device_free(self) -> float:
        """Device time the datapath is committed through (resource clock)."""
        return self.compute.free

    @property
    def outstanding(self) -> int:
        return len(self._inflight)

    def backlog(self, host: float) -> float:
        """Cycles the device is already committed beyond ``host`` — the
        load-balance term of the placement score."""
        return max(0.0, self.device_free - host)

    def admission_delay(self, host: float) -> float:
        """Cycles the *host* would block if it launched now (queue-full wait
        for concurrent devices; full occupancy for sequential ones).

        Pure query: the scheduler probes candidate devices with hypothetical
        future timestamps while scoring placements, so nothing may retire
        here — only ``submit`` advances queue state."""
        if not self.model.concurrent:
            return self.backlog(host)
        live = [s.end for s in self._inflight if s.end > host]
        if len(live) < self.depth:
            return 0.0
        return live[len(live) - self.depth] - host

    def tail(self) -> Staged | None:
        """The newest staged entry (the only preemptible one)."""
        return self._inflight[-1] if self._inflight else None

    def preempt_tail(self, host: float, priority: int) -> Staged | None:
        """Cancel the newest staged launch iff it has not yet started at
        ``host`` and its priority is strictly below ``priority``. Returns
        the cancelled entry (its ``token`` lets the scheduler re-dispatch
        the victim) or ``None`` when nothing is preemptible."""
        if not self.model.concurrent or not self._inflight:
            return None
        victim = self._inflight[-1]
        if victim.start <= host or victim.priority >= priority:
            return None
        self._inflight.pop()
        self.compute.pop_last()  # the victim's macro-op never ran
        # the device is committed only through the previous entry now; if the
        # ring emptied, it runs no later than the victim would have started
        self.compute.free = (
            self._inflight[-1].end if self._inflight else victim.start
        )
        return victim

    def _retire(self, host: float) -> None:
        while self._inflight and self._inflight[0].end <= host:
            self._inflight.popleft()

    def submit(self, host: float, duration: float, *, priority: int = 0,
               token: Any = None, ready: float = 0.0) -> LaunchTiming:
        """Issue a launch at host time ``host``; returns the resolved timing
        and the new host clock. ``ready`` is the config-complete edge: the
        macro-op may not start before its register image is fully on-device
        (an async overlapped transfer finishing after the host released —
        0.0 for serialized configuration, where the host clock already
        covers the transfer)."""
        t0 = host
        if self.model.concurrent:
            self._retire(host)
            # staging ring full: block until the oldest staged op frees a slot
            while len(self._inflight) >= self.depth:
                host = max(host, self._inflight.popleft().end)
        # sequential configuration keeps the host captive until retirement;
        # either way the datapath reservation is FIFO on the compute resource
        iv = self.compute.reserve(max(host, ready), duration,
                                  tag=getattr(token, "tenant", ""))
        start, end = iv.start, iv.end
        if self.model.concurrent:
            self._inflight.append(Staged(start, end, priority, token))
        else:
            host = end
        return LaunchTiming(host_after=host, start=start, end=end, stall=host - t0)

    def drain(self, host: float) -> float:
        """Host time once every staged invocation has retired."""
        self._inflight.clear()
        return max(host, self.device_free)
