"""Scheduler telemetry — config traffic, cache effectiveness, device busy/idle.

Counters accumulate per device while the scheduler runs and export into the
repo's existing observability vocabulary: each device yields an
``interp.Trace`` (so `timeline.compare` renders scheduler runs exactly like
compiled-program runs — Figure 2/7 gantts) and a ``RooflinePoint`` (so a
scheduled workload lands on the same configuration-roofline plots as §4's
worked examples, with I_OC computed from the bytes *actually sent* — cache
elision moves the point rightward, the runtime mirror of Figure 12)."""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.accelerators import AcceleratorModel
from ..core.interp import Invocation, Trace
from ..core.roofline import RooflinePoint
from ..core.stats import geomean  # the one shared definition, re-exported
from ..engine.resources import overlap_cycles
from ..obs.metrics import MetricsRegistry
from .state_cache import CacheStats, elision_ratio

__all__ = [
    "DeviceTelemetry",
    "LaunchRecord",
    "LinkTelemetry",
    "ResourceTelemetry",
    "SchedulerReport",
    "geomean",
]


@dataclass(frozen=True)
class LaunchRecord:
    """One launch's end-to-end life: arrival → issue → start → retire.

    The per-request substrate for open-loop telemetry: ``cluster.slo``
    computes queueing-delay/latency percentiles and SLO attainment from
    these records, merged across every device of every host."""

    tenant: str
    device: str
    arrival: float  # open-loop arrival time (0.0 for closed-loop streams)
    issue: float  # host clock when config writes for this launch began
    start: float  # device begins the macro-op
    end: float  # macro-op retires
    ops: int
    config_cycles: float
    bytes_sent: int
    priority: int = 0
    deadline: float | None = None  # absolute EDF deadline (None = best effort)
    bytes_elided: int = 0  # config bytes the device already held (resident)
    # engine overlap observables: when the register image was fully
    # on-device (compute may never start earlier — the conservation
    # invariant), and how much of T_set the host actually saw (serialized
    # configuration exposes everything; an async burst DMA exposes only
    # the host instruction time plus wire cycles compute failed to cover)
    config_done: float = 0.0
    exposed_config: float = 0.0
    # attribution substrate (repro.obs): how the launch's T_set split
    # across the engine lanes — host instruction cycles, where its wire
    # transfer began (== its LinkPort reservation, so obs.attribution can
    # match the two exactly), when the host was released (captive through
    # the wire when serialized, descriptor enqueue when async), and how
    # long the host then blocked on the device (ring-full / sequential)
    host_cycles: float = 0.0
    wire_start: float = 0.0
    host_release: float = 0.0
    stall: float = 0.0

    @property
    def queue_delay(self) -> float:
        """Arrival to device-start: the tail-latency term open-loop traffic
        adds on top of service time."""
        return self.start - self.arrival

    @property
    def latency(self) -> float:
        """Arrival to retirement — what a tenant's SLO is written against."""
        return self.end - self.arrival

    @property
    def missed_deadline(self) -> bool:
        """Deadline-carrying launches that retired late (best-effort
        launches never miss)."""
        return self.deadline is not None and self.end > self.deadline

    @property
    def hidden_config(self) -> float:
        """Config cycles runtime overlap kept off the host's critical path
        (wire time that streamed behind this device's compute)."""
        return self.config_cycles - self.exposed_config


class DeviceTelemetry:
    """Everything observed about one device instance during a run.

    The counters live in a :class:`~repro.obs.metrics.MetricsRegistry`
    (one per scheduler, shared across its devices, labelled ``device=``);
    the historical scalar fields (``config_cycles``, ``bytes_sent``, ...)
    are properties — thin views over the registry — so every existing
    report and benchmark reads identically while the registry is what
    exports, folds across hosts, and feeds the obs layer."""

    _COUNTERS = (
        ("config_cycles", "sched.config_cycles"),
        ("exposed_config_cycles", "sched.exposed_config_cycles"),
        ("stall_cycles", "sched.stall_cycles"),
        ("busy_cycles", "sched.busy_cycles"),
        ("total_ops", "sched.total_ops"),
        ("bytes_sent", "sched.bytes_sent"),
        ("bytes_elided", "sched.bytes_elided"),
        ("launches", "sched.launches"),
        ("preemptions", "sched.preemptions"),
        ("preempted_config_cycles", "sched.preempted_config_cycles"),
    )

    def __init__(self, device: str, model: AcceleratorModel,
                 metrics: MetricsRegistry | None = None):
        self.device = device
        self.model = model
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.invocations: list[Invocation] = []
        self.launch_log: list[LaunchRecord] = []
        # launches cancelled before starting: their device-side accounting
        # is rolled back, but their host/wire occupancy happened — the obs
        # attribution classifies those cycles as preempted, not idle
        self.preempted_log: list[LaunchRecord] = []
        self._c = {attr: self.metrics.counter(name, device=device)
                   for attr, name in self._COUNTERS}

    # registry views: the historical scalar fields, now reading the shared
    # registry (int-valued counters surface as ints, as before)
    @property
    def config_cycles(self) -> float:
        return self._c["config_cycles"].value

    @property
    def exposed_config_cycles(self) -> float:
        return self._c["exposed_config_cycles"].value

    @property
    def stall_cycles(self) -> float:
        return self._c["stall_cycles"].value

    @property
    def busy_cycles(self) -> float:
        return self._c["busy_cycles"].value

    @property
    def total_ops(self) -> int:
        return int(self._c["total_ops"].value)

    @property
    def bytes_sent(self) -> int:
        return int(self._c["bytes_sent"].value)

    @property
    def bytes_elided(self) -> int:
        return int(self._c["bytes_elided"].value)

    @property
    def launches(self) -> int:
        return int(self._c["launches"].value)

    @property
    def preemptions(self) -> int:
        return int(self._c["preemptions"].value)

    @property
    def preempted_config_cycles(self) -> float:
        return self._c["preempted_config_cycles"].value

    def record_launch(
        self,
        tenant: str,
        regs: dict,
        start: float,
        end: float,
        ops: int,
        config_cycles: float,
        stall: float,
        bytes_sent: int,
        bytes_elided: int,
        arrival: float = 0.0,
        issue: float | None = None,
        priority: int = 0,
        deadline: float | None = None,
        config_done: float | None = None,
        exposed_config: float | None = None,
        host_cycles: float | None = None,
        wire_start: float | None = None,
        host_release: float | None = None,
    ) -> None:
        if exposed_config is None:
            exposed_config = config_cycles  # serialized: nothing hides
        if config_done is None:
            config_done = (issue if issue is not None else start) + config_cycles
        self.invocations.append(Invocation(self.device, dict(regs), start, end))
        self.launch_log.append(LaunchRecord(
            tenant=tenant,
            device=self.device,
            arrival=arrival,
            issue=issue if issue is not None else start,
            start=start,
            end=end,
            ops=ops,
            config_cycles=config_cycles,
            bytes_sent=bytes_sent,
            priority=priority,
            deadline=deadline,
            bytes_elided=bytes_elided,
            config_done=config_done,
            exposed_config=exposed_config,
            # CSR semantics when the caller doesn't split T_set: all host
            # time, a zero-length wire interval at the config-done edge,
            # host captive through it — attribution still conserves
            host_cycles=(host_cycles if host_cycles is not None
                         else config_cycles),
            wire_start=wire_start if wire_start is not None else config_done,
            host_release=(host_release if host_release is not None
                          else config_done),
            stall=stall,
        ))
        c = self._c
        c["busy_cycles"].add(end - start)
        c["total_ops"].add(ops)
        c["config_cycles"].add(config_cycles)
        c["exposed_config_cycles"].add(exposed_config)
        c["stall_cycles"].add(stall)
        c["bytes_sent"].add(bytes_sent)
        c["bytes_elided"].add(bytes_elided)
        c["launches"].inc()

    def record_preemption(self) -> None:
        """Undo the newest launch's *device-side* accounting: the staged
        macro-op never ran. Its config writes stay counted — that host work
        happened and was wasted (``exposed_config_cycles`` keeps them for
        the same reason), which is exactly what the preemption counters
        should expose. The popped record moves to ``preempted_log`` so the
        obs attribution can still classify its host/wire occupancy."""
        assert self.invocations, "preemption with no recorded launch"
        inv = self.invocations.pop()
        rec = self.launch_log.pop()
        self.preempted_log.append(rec)
        c = self._c
        c["busy_cycles"].add(-(inv.end - inv.start))
        c["total_ops"].add(-rec.ops)
        c["launches"].add(-1)
        c["preemptions"].inc()
        c["preempted_config_cycles"].add(rec.config_cycles)

    # -- derived -------------------------------------------------------------

    def utilization(self, makespan: float) -> float:
        return self.busy_cycles / makespan if makespan else 0.0

    def idle_cycles(self, makespan: float) -> float:
        return max(0.0, makespan - self.busy_cycles)

    @property
    def elision_ratio(self) -> float:
        return elision_ratio(self.bytes_sent, self.bytes_elided)

    # -- exports into the core observability vocabulary ----------------------

    def trace(self, makespan: float) -> Trace:
        """An ``interp.Trace`` over this device's invocations, renderable via
        ``timeline.render`` / ``timeline.compare``. ``total_cycles`` is the
        run's makespan so gantts of pool members share one time axis."""
        t = Trace(
            invocations=list(self.invocations),
            host_cycles=makespan,
            total_cycles=makespan,
            config_cycles=self.config_cycles,
            stall_cycles=self.stall_cycles,
            total_ops=self.total_ops,
            accel_busy_cycles=self.busy_cycles,
        )
        t._config_bytes = self.bytes_sent  # I_OC reflects elision
        return t

    def roofline_point(self, makespan: float) -> RooflinePoint:
        bytes_sent = max(self.bytes_sent, 1)
        return RooflinePoint(
            name=self.device,
            i_oc=self.total_ops / bytes_sent,
            performance=self.total_ops / makespan if makespan else 0.0,
            p_peak=self.model.p_peak,
            bw_config=self.model.bw_config,
        )


@dataclass(frozen=True)
class ResourceTelemetry:
    """Everything observed about one engine resource during a run: the
    busy-interval timeline of the host control thread, the config wire, or
    one device's compute datapath (``repro.engine.resources``). The
    per-resource analogue of a device gantt — and the substrate for the
    overlap observables: wire∩compute is the config time runtime overlap
    kept off the critical path."""

    resource: str  # e.g. "host", "cfg[pcie]", "compute[opengemm:0]"
    kind: str  # "host" | "wire" | "compute"
    busy_cycles: float
    makespan: float
    intervals: tuple = ()  # (start, end, tag) per reservation
    # the resource's repro.power.EnergyModel when a PowerSpec was attached,
    # else None — carried so the energy meter works offline from a report
    energy: object = None

    @classmethod
    def from_resource(cls, res, makespan: float) -> "ResourceTelemetry":
        return cls(
            resource=res.name,
            kind=res.kind,
            busy_cycles=res.busy_cycles,
            makespan=makespan,
            intervals=tuple(res.intervals()),
            energy=getattr(res, "energy", None),
        )

    @property
    def utilization(self) -> float:
        """Fraction of the run this resource was busy (→1.0 names the
        configuration bottleneck: host pipeline, wire, or datapath)."""
        return self.busy_cycles / self.makespan if self.makespan else 0.0

    @property
    def idle_cycles(self) -> float:
        return max(0.0, self.makespan - self.busy_cycles)

    def overlap_with(self, other: "ResourceTelemetry") -> float:
        """Cycles both resources were busy at once (union semantics — no
        double counting within either side)."""
        return overlap_cycles(self.intervals, other.intervals)

    def timeline(self) -> list[tuple[float, float, str]]:
        """(start, end, tag) busy intervals — renderable beside device
        gantts and link timelines on one time axis."""
        return [(s, e, tag) for s, e, tag in self.intervals]


@dataclass(frozen=True)
class LinkTelemetry:
    """Everything observed about one fabric link during a run: busy cycles,
    bytes moved, occupancy, and the per-transfer timeline (the link-level
    analogue of a device gantt). Built from a ``fabric.link.LinkPort``'s
    transfer log (duck-typed, so this layer stays fabric-import-free)."""

    link: str  # port name, e.g. "cfg[noc]"
    kind: str  # link class: "csr" | "noc" | "pcie"
    transfers: int
    nbytes: int
    busy_cycles: float
    makespan: float
    log: tuple = ()  # (start, end, nbytes, tag, mode, energy) per transfer
    # the wire's idle/wake EnergyModel when a PowerSpec was attached
    energy: object = None

    @classmethod
    def from_port(cls, port, makespan: float) -> "LinkTelemetry":
        return cls(
            link=port.name,
            kind=port.link.kind,
            transfers=len(port.log),
            nbytes=port.bytes_moved,
            busy_cycles=port.busy_cycles,
            makespan=makespan,
            log=tuple((t.start, t.end, t.nbytes, t.tag, t.mode,
                       getattr(t, "energy", 0.0))
                      for t in port.log),
            energy=getattr(port.res, "energy", None),
        )

    @property
    def occupancy(self) -> float:
        """Fraction of the run the wire was busy — the link-saturation
        observable (→1.0 means the interconnect, not any host or device,
        is the configuration bottleneck)."""
        return self.busy_cycles / self.makespan if self.makespan else 0.0

    @property
    def effective_bandwidth(self) -> float:
        """Bytes per busy cycle actually sustained on the wire."""
        return self.nbytes / self.busy_cycles if self.busy_cycles else 0.0

    def timeline(self) -> list[tuple[float, float, str]]:
        """(start, end, tag) busy intervals, transfer order — renderable
        beside device gantts on the same time axis."""
        return [(entry[0], entry[1], entry[3]) for entry in self.log]

    @property
    def transfer_joules(self) -> float:
        """Total wire energy (pJ) of every logged transfer."""
        return sum(entry[5] for entry in self.log if len(entry) > 5)


@dataclass
class SchedulerReport:
    """Aggregate of one scheduler run."""

    makespan: float
    devices: dict[str, DeviceTelemetry]
    cache_stats: dict[str, CacheStats]
    placements: dict[str, dict[str, int]]  # tenant -> {device: launches}
    links: dict[str, LinkTelemetry] = field(default_factory=dict)
    # engine occupancy: host / wire / per-device compute busy timelines
    resources: dict[str, ResourceTelemetry] = field(default_factory=dict)
    overlap_mode: str = "serialized"
    # the run's knob settings, recorded so obs.whatif can replay the run
    # under its *actual* configuration before flipping one knob
    staging_buffers: int = 2
    transport: str = "auto"
    # how compute was priced: "flat" (the legacy per-launch constant) or
    # "calibrated" (engine.costmodel predictions per kernel shape)
    compute_model: str = "flat"
    # the run's repro.power.PowerSpec (None = cycle-only run) and the
    # transport objective, recorded so repro.power.meter can attribute a
    # report's joules offline and whatif can replay under the same spec
    power: object = None
    objective: str = "cycles"
    # the scheduler's label-set registry (repro.obs.metrics): the aggregate
    # properties below are views over it; None only for hand-built reports
    metrics: MetricsRegistry | None = None

    def _total(self, name: str, fallback) -> float:
        if self.metrics is not None and self.metrics.has(name):
            return self.metrics.total(name)
        return sum(fallback(d) for d in self.devices.values())

    @property
    def bytes_sent(self) -> int:
        return int(self._total("sched.bytes_sent", lambda d: d.bytes_sent))

    @property
    def bytes_elided(self) -> int:
        return int(self._total("sched.bytes_elided", lambda d: d.bytes_elided))

    @property
    def preemptions(self) -> int:
        return int(self._total("sched.preemptions", lambda d: d.preemptions))

    @property
    def config_cycles(self) -> float:
        """Host cycles this run spent writing configuration — on one host
        these serialize through a single control thread (the config port)."""
        return self._total("sched.config_cycles", lambda d: d.config_cycles)

    @property
    def exposed_config_cycles(self) -> float:
        """Config cycles the host actually saw: T_set minus whatever the
        overlapped engine streamed behind compute. Serialized runs expose
        everything (``exposed == config_cycles``)."""
        return self._total("sched.exposed_config_cycles",
                           lambda d: d.exposed_config_cycles)

    @property
    def hidden_config_cycles(self) -> float:
        """Config cycles runtime overlap kept off the critical path — the
        §5.5 win, measured at dispatch instead of compile time."""
        return self.config_cycles - self.exposed_config_cycles

    def overlap_summary(self) -> dict[str, float]:
        """The run's configuration-overlap scoreboard."""
        total = self.config_cycles
        hidden = self.hidden_config_cycles
        return {
            "config_cycles": total,
            "exposed_config_cycles": self.exposed_config_cycles,
            "hidden_config_cycles": hidden,
            "hidden_fraction": hidden / total if total else 0.0,
        }

    def resource_timelines(self) -> dict[str, list[tuple[float, float, str]]]:
        """Per-resource busy intervals on the shared time axis."""
        return {name: tel.timeline() for name, tel in self.resources.items()}

    def launch_log(self) -> list[LaunchRecord]:
        """Every launch of the run in issue order — the substrate for
        queueing-delay/latency percentiles (``cluster.slo``)."""
        records = [r for d in self.devices.values() for r in d.launch_log]
        records.sort(key=lambda r: (r.issue, r.start, r.tenant))
        return records

    def descriptor_timeline(
        self, tenant: str | None = None
    ) -> list[tuple[float, int, int]]:
        """Per-launch ``(issue, bytes_sent, bytes_elided)`` in issue order —
        the descriptor-byte timeline of one tenant's stream (or the whole
        run): how much of each launch's configuration actually crossed the
        boundary vs. rode on device-resident state. The serving bridge
        (``repro.bridge``) plots these per decode step."""
        return [(r.issue, r.bytes_sent, r.bytes_elided)
                for r in self.launch_log()
                if tenant is None or r.tenant == tenant]

    def queue_delays(self) -> dict[str, list[float]]:
        """Per-tenant queueing delays (arrival → device start)."""
        out: dict[str, list[float]] = {}
        for rec in self.launch_log():
            out.setdefault(rec.tenant, []).append(rec.queue_delay)
        return out

    def deadline_misses(self) -> int:
        """Launches that carried a deadline and retired after it (EDF's
        objective; best-effort launches never count)."""
        return sum(1 for r in self.launch_log() if r.missed_deadline)

    def deadline_launches(self) -> int:
        """Launches that carried a deadline at all."""
        return sum(1 for r in self.launch_log() if r.deadline is not None)

    @property
    def elision_ratio(self) -> float:
        return elision_ratio(self.bytes_sent, self.bytes_elided)

    def hit_rate(self) -> float:
        hits = sum(s.hits for s in self.cache_stats.values())
        misses = sum(s.misses for s in self.cache_stats.values())
        return hits / (hits + misses) if hits + misses else 0.0

    def traces(self) -> dict[str, Trace]:
        """Per-device timelines on a shared axis, for ``timeline.compare``."""
        return {name: d.trace(self.makespan) for name, d in self.devices.items()}

    def roofline_points(self) -> list[RooflinePoint]:
        return [d.roofline_point(self.makespan) for d in self.devices.values()]

    def utilizations(self) -> dict[str, float]:
        return {name: d.utilization(self.makespan) for name, d in self.devices.items()}

    def geomean_utilization(self) -> float:
        return geomean(self.utilizations().values())
