"""Multi-accelerator runtime scheduler with config-affinity placement.

A fourth runtime layer: ``compile → dispatch → schedule → execute``. The
compile-time passes shrink one program's configuration traffic; the
scheduler shrinks a *pool's* — it admits streams of launch requests from
many tenants onto heterogeneous devices drawn from
``core.accelerators.REGISTRY``, and places each launch where the device's
cached register state makes the most of it.

**Config-affinity placement.** For every candidate device the scheduler
prices the *host-visible* cost of launching there now:

    cost = T_set(delta)  +  admission delay          (concurrent devices)
    cost = T_set(delta)  +  wait + macro-op duration (sequential devices)

where ``T_set(delta)`` covers only the fields the device's
:class:`~repro.sched.state_cache.ConfigStateCache` does not already hold for
this tenant. A device holding the tenant's context is cheap, so streams
naturally pin to their devices — until the staging ring backs up and the
admission-delay term spills work to a colder device. Affinity and load
balance fall out of a single scalar.

Timing uses the same cost model as ``core.interp`` (config-write cycles per
field, launch cycles, sequential-stall vs. staged-concurrent launches), so
scheduler telemetry is directly comparable with compiled-program traces.

**Engine occupancy (repro.engine).** Since the engine refactor the
scheduler no longer bumps a private scalar clock: every launch *reserves*
the three contended resources — the host control thread, the config wire
(the fabric :class:`~repro.fabric.link.LinkPort`'s resource, possibly
shared by several hosts), and the device's compute datapath (owned by its
:class:`~repro.sched.queue.LaunchQueue`). ``overlap="serialized"``
reproduces the pre-engine cycle counts bit-exactly (the host stays captive
for its transfers' wire time); ``overlap="overlapped"`` stages async
burst-DMA transfers behind compute, releasing the host at descriptor
enqueue — the runtime twin of the §5.5 compiler pass.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from ..core.accelerators import REGISTRY, AcceleratorModel
from ..core.interp import Trace
from ..engine.costmodel import resolve_compute_model
from ..engine.overlap import OverlapPolicy
from ..engine.resources import EngineResources, Resource
from ..fabric.link import LinkModel, LinkPort, resolve_link
from ..fabric.transport import plan_fields
from ..obs.metrics import MetricsRegistry
from .queue import AdmissionQueue, LaunchQueue, arrival_order
from .state_cache import ConfigStateCache, WritePlan
from .telemetry import (
    DeviceTelemetry,
    LinkTelemetry,
    ResourceTelemetry,
    SchedulerReport,
)

POLICIES = ("affinity", "round_robin", "least_loaded")


@dataclass(frozen=True)
class LaunchRequest:
    """One tenant macro-op: logical GEMM dims plus extra register fields
    (addresses, strides, zero-points ...). ``accel`` restricts placement to
    one device kind (a ``REGISTRY`` model name); ``None`` means any.

    ``arrival_time`` makes the request open-loop: the scheduler may not
    issue it earlier, and queueing delay is measured from it
    (``cluster.traffic`` stamps arrivals from Poisson/bursty/diurnal
    processes). ``priority`` orders same-instant admissions and lets a
    request preempt lower-priority *staged* launches (``sched.queue``).
    ``deadline`` (absolute, host cycles) opts the request into EDF
    admission (``run_open_loop(order="edf")``); ``None`` means best
    effort.

    ``kernel`` names the macro-op's kernel class for the calibrated
    compute model (``engine.costmodel.KERNELS`` or an alias — the bridge
    tags decode vs prefill); under the default flat model it is ignored,
    so every pre-costmodel stream is priced unchanged."""

    tenant: str
    dims: tuple[int, int, int]  # logical (M, K, N); ops = 2·M·K·N
    extra: dict[str, int] = field(default_factory=dict)
    accel: str | None = None
    arrival_time: float = 0.0
    priority: int = 0
    deadline: float | None = None
    kernel: str = "matmul"

    def regs_for(self, model: AcceleratorModel) -> dict[str, int]:
        """Materialize the register file for a device kind — logical dims
        land in the model's ``dim_fields`` register names."""
        regs = dict(zip(model.dim_fields, self.dims))
        regs.update(self.extra)
        return regs


class Device:
    """One pool member: an accelerator model + its cache and launch queue."""

    def __init__(self, dev_id: str, model: AcceleratorModel, *,
                 depth: int = 2, max_contexts: int = 4,
                 metrics: MetricsRegistry | None = None):
        self.id = dev_id
        self.model = model
        self.cache = ConfigStateCache(
            max_contexts=max_contexts,
            bytes_of=lambda name, value: model.bytes_per_field,
        )
        self.queue = LaunchQueue(model, depth=depth, name=dev_id)
        self.telemetry = DeviceTelemetry(dev_id, model, metrics=metrics)

    def config_cycles(self, n_fields: int) -> float:
        """Host cycles to write ``n_fields`` registers + issue the launch
        (same accounting as ``interp._exec_setup`` / ``_exec_launch``) —
        the core-local CSR special case of ``fabric.transport.plan_fields``
        (zero wire cost, MMIO always wins)."""
        m = self.model
        writes = -(-n_fields // m.fields_per_write) if n_fields else 0
        return (writes * m.instrs_per_write + m.launch_instrs) * m.host_cpi


class Scheduler:
    """Admits multi-tenant launch streams onto a heterogeneous device pool."""

    def __init__(
        self,
        pool: dict[str, AcceleratorModel] | None = None,
        *,
        depth: int = 2,
        max_contexts: int = 4,
        policy: str = "affinity",
        cache_enabled: bool = True,
        link: LinkModel | str | None = None,
        overlap: str = "serialized",
        staging_buffers: int = 2,
        transport: str = "auto",
        objective: str = "cycles",
        compute_model=None,
        power=None,
        port: LinkPort | None = None,
        tracer=None,
    ):
        assert policy in POLICIES, policy
        # how macro-op compute time is priced: ``None`` (default) keeps the
        # flat per-launch constant — ``AcceleratorModel.macro_cycles``,
        # bit-exact with every committed number; "calibrated" (or a
        # ``ComputeModel`` instance) prices each launch's kernel class and
        # shape through the fitted analytical model (engine.costmodel)
        self.compute_model = resolve_compute_model(compute_model)
        # transport discipline for config writes: "auto" lets the fabric
        # pick the cheaper of MMIO and burst DMA per plan; "mmio"/"burst"
        # force one side — the counterfactual knob obs.whatif validates
        # its burst-DMA predictions against
        self.transport = transport
        # what "cheaper" means under "auto": cycles (default, historical
        # behaviour bit-exactly), joules, or edp — the one place energy
        # rates are allowed to change *timing* (fabric.transport.OBJECTIVES)
        self.objective = objective
        # optional repro.power.PowerSpec: attaches observation-only
        # EnergyModels to the host/wire/compute resources so the energy
        # meter (repro.power.meter) and windowed power monitor can price
        # this run's busy intervals in joules; never consulted by dispatch
        self.power = power
        if pool is None:
            pool = {name: model for name, model in REGISTRY.items()}
        # one label-set registry per scheduler (repro.obs.metrics): every
        # device's counters live here, and reports aggregate through it
        self.metrics = MetricsRegistry()
        self.devices = [
            Device(dev_id, model, depth=depth, max_contexts=max_contexts,
                   metrics=self.metrics)
            for dev_id, model in pool.items()
        ]
        self.policy = policy
        self.cache_enabled = cache_enabled
        # the interconnect config writes cross: ``None``/"csr" is the
        # paper's core-local port (zero wire cost — the pre-fabric numbers
        # reproduce bit-exactly); "noc"/"pcie" price every write's T_set
        # through fabric.transport (MMIO vs. burst DMA, whichever is
        # cheaper) and log occupancy on the config LinkPort. Passing an
        # existing ``port`` shares its wire with other schedulers (the
        # cluster-level PCIe-switch topology): transfers from every sharer
        # contend FIFO on one resource, and the port's link wins.
        if port is not None:
            self.port = port
            self.link = port.link
        else:
            self.link = resolve_link(link)
            self.port = LinkPort(self.link, name=f"cfg[{self.link.name}]")
        # the three-resource occupancy model this scheduler dispatches onto
        # (repro.engine): the host clock is the host resource's committed
        # time, the wire is the port's resource, compute lives in the queues
        self.res = EngineResources(
            host=Resource("host", kind="host"),
            wire=self.port.res,
            compute={d.id: d.queue.compute for d in self.devices},
        )
        if power is not None:
            self.res.host.energy = power.host
            # a shared port keeps the first sharer's wire model: one
            # physical link, one standing burn, metered once cluster-wide
            if self.res.wire.energy is None:
                self.res.wire.energy = power.wire_model(self.link.kind)
            for d in self.devices:
                d.queue.compute.energy = power.compute_model(d.model.name)
        # serialized = pre-engine captive-host behavior (bit-exact);
        # overlapped = double-buffered async burst-DMA staging (§5.5's
        # runtime twin) — the host is released at descriptor enqueue
        self.overlap = OverlapPolicy(mode=overlap, buffers=staging_buffers)
        # observation-only span hooks (repro.obs.trace): a Tracer or a
        # host-bound view of one; never touches a clock, so traced runs
        # are bit-identical to untraced ones. The (possibly shared) wire
        # port gets the unbound root — its transfers belong to the fabric,
        # not to whichever host happened to attach first
        self.tracer = tracer
        self.overlap.tracer = tracer
        if tracer is not None and getattr(self.port, "tracer", None) is None:
            self.port.tracer = getattr(tracer, "root", tracer)
        self._rr = itertools.count()
        self._placements: dict[str, dict[str, int]] = {}
        self._last_request: dict[str, LaunchRequest] = {}

    @property
    def host(self) -> float:
        """The host control thread's committed time (the resource clock)."""
        return self.res.host.free

    @host.setter
    def host(self, value: float) -> None:
        # direct assignment (open-loop idling forward, probe save/restore)
        # moves the clock without logging busy time — reservations in
        # ``_dispatch_on`` are the only source of host busy intervals
        self.res.host.free = value

    @classmethod
    def from_registry(cls, counts: dict[str, int], **kwargs) -> "Scheduler":
        """e.g. ``Scheduler.from_registry({"gemmini": 1, "opengemm": 2})``."""
        pool: dict[str, AcceleratorModel] = {}
        for kind, n in counts.items():
            for i in range(n):
                pool[f"{kind}:{i}"] = REGISTRY[kind]
        return cls(pool, **kwargs)

    def _macro_cycles(self, dev: Device, regs: dict[str, int],
                      kernel: str) -> float:
        """One macro-op's compute duration on ``dev`` — the single seam the
        compute model replaces: ``None`` is literally the legacy call."""
        if self.compute_model is None:
            return dev.model.macro_cycles(regs)
        return self.compute_model.macro_cycles(dev.model, regs, kernel)

    # -- placement -----------------------------------------------------------

    def _candidates(self, req: LaunchRequest) -> list[Device]:
        devs = [d for d in self.devices
                if req.accel is None or d.model.name == req.accel]
        if not devs:
            raise KeyError(f"no device of kind {req.accel!r} in pool")
        return devs

    def _probe_device(self, dev: Device, req: LaunchRequest) -> tuple[float, int]:
        """(host-visible cost of launching here now, config bytes a resident
        context would elide) — one cache write-plan evaluation feeds both.
        Under runtime overlap an async burst transfer exposes only the
        host's instruction time to this scalar (the wire streams behind
        compute), so warm overlapped devices probe even cheaper."""
        regs = req.regs_for(dev.model)
        if self.cache_enabled:
            plan = dev.cache.plan(req.tenant, regs)
            n_sent, elided = len(plan.sent), plan.bytes_elided
        else:
            n_sent, elided = len(regs), 0
        xfer = plan_fields(n_sent, dev.model, self.link, self.transport,
                           objective=self.objective)
        cfg_c = self.overlap.exposed_cost(dev.model.concurrent, xfer)
        issue = self.host + cfg_c
        if dev.model.concurrent:
            delay = dev.queue.admission_delay(issue)
            if self.overlap.is_async(dev.model.concurrent, xfer):
                # overlap-aware placement: an async transfer releases the
                # host early, but compute may not start before the register
                # image lands (StagePlan.config_done) — and the wire's busy
                # window can push that transfer back. Probe the wire the
                # same way stage() would reserve it, so a device behind a
                # backlogged link prices the gate it would actually impose
                # on compute-start instead of looking free.
                earliest = max(self.host + xfer.host_cycles,
                               self.overlap.bank_free(dev.id))
                done = self.port.res.when(earliest, xfer.link_cycles).end
                delay = max(delay, done - issue)
            return cfg_c + delay, elided
        start = max(issue, dev.queue.device_free)
        return start + self._macro_cycles(dev, regs, req.kernel) - self.host, \
            elided

    def _host_cost(self, dev: Device, req: LaunchRequest) -> float:
        return self._probe_device(dev, req)[0]

    def place(self, req: LaunchRequest) -> Device:
        devs = self._candidates(req)
        if len(devs) == 1:
            return devs[0]
        if self.policy == "round_robin":
            return devs[next(self._rr) % len(devs)]
        if self.policy == "least_loaded":
            return min(devs, key=lambda d: d.queue.backlog(self.host))
        # affinity: cheapest host-visible cost; cold-cache ties (e.g. a
        # tenant's first launch) break toward the least-loaded device so
        # tenants spread across the pool before pinning
        return min(devs, key=lambda d: (self._host_cost(d, req),
                                        d.queue.backlog(self.host)))

    def probe_cost(self, req: LaunchRequest, now: float | None = None,
                   stickiness: float = 0.0) -> float:
        """Host-visible cycles to place ``req`` on this scheduler's best
        device, relative to ``max(host clock, now)`` — the clock an actual
        dispatch at wall time ``now`` would see. ``stickiness`` credits each
        device's resident-context elision (priced at its config bandwidth)
        that many launches ahead, the affinity router's hysteresis term.
        Pure query — the cross-host router's per-host term
        (``cluster.router``); one cache write-plan per device feeds both
        the cost and the residency credit."""
        saved = self.host
        if now is not None:
            self.host = max(self.host, now)
        try:
            best = float("inf")
            for dev in self._candidates(req):
                cost, elided = self._probe_device(dev, req)
                if stickiness:
                    cost -= stickiness * elided / dev.model.bw_config
                best = min(best, cost)
            return best
        finally:
            self.host = saved

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, req: LaunchRequest,
                 not_before: float = 0.0) -> Device:
        # open-loop admission: the host idles until the request exists.
        # ``not_before`` is an externally-imposed release edge (a host-level
        # config-bandwidth quota deferring the launch past its arrival) —
        # the delay lands in this request's own queueing time, measured
        # from its unchanged arrival_time.
        self.host = max(self.host, req.arrival_time, not_before)
        dev = self.place(req)
        self._dispatch_on(dev, req)
        return dev

    def _dispatch_on(self, dev: Device, req: LaunchRequest) -> None:
        victim: LaunchRequest | None = None
        if req.priority and dev.model.concurrent:
            # a higher-priority arrival that would block on a full staging
            # ring cancels the newest staged-not-started launch instead
            if dev.queue.admission_delay(self.host) > 0.0:
                staged = dev.queue.preempt_tail(self.host, req.priority)
                if staged is not None and staged.token is not None:
                    victim = staged.token
                    dev.telemetry.record_preemption()
                    self.overlap.preempted(dev.id)
                    self._placements[victim.tenant][dev.id] -= 1
                    if self.tracer is not None:
                        self.tracer.instant(
                            "preempt", self.host, lane=f"compute[{dev.id}]",
                            tenant=victim.tenant, by=req.tenant)
        regs = req.regs_for(dev.model)
        if self.cache_enabled:
            plan = dev.cache.dispatch(req.tenant, regs)
        else:
            total = len(regs) * dev.model.bytes_per_field
            plan = WritePlan(sent=dict(regs), elided={}, bytes_sent=total,
                             bytes_elided=0, context_hit=False)
        issue = self.host
        xfer = plan_fields(len(plan.sent), dev.model, self.link,
                           self.transport, objective=self.objective)
        cfg_c = xfer.t_set
        # reserve host + wire through the overlap policy: serialized keeps
        # the host captive for the wire (bit-exact pre-engine behavior);
        # overlapped enqueues an async burst DMA and releases the host at
        # the descriptor — the wire then streams behind the device's compute
        stage = self.overlap.stage(
            dev_id=dev.id, concurrent=dev.model.concurrent, xfer=xfer,
            host=self.res.host, port=self.port, issue=issue, tag=req.tenant)
        # config cycles the host actually saw: its instruction time plus
        # whatever wire time did NOT hide behind this device's compute —
        # the "exposed T_set" the overlap-adjusted roofline is built from
        hidden = (dev.queue.compute.overlap_with(stage.wire_start,
                                                 stage.config_done)
                  if stage.asynchronous else 0.0)
        exposed = cfg_c - hidden
        self.res.host.advance(stage.host_release)
        timing = dev.queue.submit(self.host,
                                  self._macro_cycles(dev, regs, req.kernel),
                                  priority=req.priority, token=req,
                                  ready=stage.config_done)
        self.host = timing.host_after
        self.overlap.committed(dev.id, timing.end)
        dev.telemetry.record_launch(
            tenant=req.tenant,
            regs=regs,
            start=timing.start,
            end=timing.end,
            ops=dev.model.macro_ops(regs),
            config_cycles=cfg_c,
            stall=timing.stall,
            # the launch itself crosses the boundary too (cf. interp)
            bytes_sent=plan.bytes_sent + dev.model.bytes_per_field,
            bytes_elided=plan.bytes_elided,
            arrival=req.arrival_time,
            issue=issue,
            priority=req.priority,
            deadline=req.deadline,
            config_done=stage.config_done,
            exposed_config=exposed,
            host_cycles=stage.host_busy,
            wire_start=stage.wire_start,
            host_release=stage.host_release,
        )
        if self.tracer is not None:
            self._emit_spans(dev, req, stage, timing, issue,
                             n_fields=len(plan.sent), config_cycles=cfg_c,
                             exposed=exposed)
        self._last_request[req.tenant] = req
        self._placements.setdefault(req.tenant, {})
        self._placements[req.tenant][dev.id] = (
            self._placements[req.tenant].get(dev.id, 0) + 1
        )
        if victim is not None:
            # the victim re-enters placement behind its preemptor; each hop
            # strictly lowers the displaced priority, so this terminates
            self.dispatch(victim)

    def _emit_spans(self, dev: Device, req: LaunchRequest, stage, timing,
                    issue: float, *, n_fields: int, config_cycles: float,
                    exposed: float) -> None:
        """One launch's span taxonomy (repro.obs.trace): queued →
        config-issue → [wire-captive] → [launch-stall] on the host lane,
        config-done → compute on the device lane, launch on the tenant
        lane. The wire-transfer span itself is emitted by the LinkPort
        (the transfer belongs to the fabric, shared ports included)."""
        tr = self.tracer
        tenant_lane = f"tenant[{req.tenant}]"
        h_end = stage.host_start + stage.host_busy
        if issue > req.arrival_time:
            tr.span("queued", "queueing", req.arrival_time, issue,
                    lane=tenant_lane, device=dev.id)
        tr.span("config-issue", "config", stage.host_start, h_end,
                lane="host", tenant=req.tenant, device=dev.id,
                fields=n_fields)
        if stage.host_release > h_end:
            tr.span("wire-captive", "config", h_end, stage.host_release,
                    lane="host", tenant=req.tenant, device=dev.id)
        if timing.stall > 0.0:
            tr.span("launch-stall", "stall", stage.host_release,
                    stage.host_release + timing.stall, lane="host",
                    tenant=req.tenant, device=dev.id)
        tr.instant("config-done", stage.config_done,
                   lane=f"compute[{dev.id}]", tenant=req.tenant)
        tr.span("compute", "compute", timing.start, timing.end,
                lane=f"compute[{dev.id}]", tenant=req.tenant,
                ops=dev.model.macro_ops(req.regs_for(dev.model)))
        tr.span("launch", "launch", issue, timing.end, lane=tenant_lane,
                device=dev.id, config_cycles=config_cycles,
                exposed_config=exposed,
                asynchronous=stage.asynchronous)

    def invalidate(self, tenant: str | None = None) -> None:
        """Clobber cached device state (the runtime ``effects="all"``)."""
        for dev in self.devices:
            dev.cache.invalidate(tenant)

    def last_request(self, tenant: str) -> LaunchRequest | None:
        """The tenant's most recently dispatched request — the probe a
        migration trigger (``cluster.shed``) prices a move with."""
        return self._last_request.get(tenant)

    def tenant_launches(self) -> dict[str, int]:
        """tenant → launches dispatched here (the shed trigger's heat
        signal for choosing which stream to move)."""
        return {t: sum(devs.values()) for t, devs in self._placements.items()}

    # -- runs ----------------------------------------------------------------

    def run(self, requests: Iterable[LaunchRequest]) -> SchedulerReport:
        """Batch admission: dispatch in the given order (closed-loop)."""
        for req in requests:
            self.dispatch(req)
        return self.finish()

    def run_open_loop(self, requests: Iterable[LaunchRequest],
                      *, order: str = "arrival", warmth=None,
                      warm_slack: float = 0.0) -> SchedulerReport:
        """Event-driven drain: requests are admitted in arrival order (ties
        go to higher priority), and the host clock idles forward whenever
        the next arrival is still in the future — queueing delay percentiles
        out of ``report.launch_log()`` are meaningful only under this loop.

        ``order="edf"`` re-orders the *backlog* earliest-deadline-first
        (requests without deadlines fall back to priority order): under
        bursts, tight-deadline launches overtake loose ones they arrived
        behind, lowering deadline misses at equal work.

        ``order="warm"`` is cache-warmth-aware: a tenant whose context is
        still resident in some device cache drains ahead of cold arrivals
        (fewer context turnovers → fewer config bytes), bounded by each
        cold request's deadline slack (``warm_slack`` cycles of margin).
        ``warmth`` overrides the default predicate (any candidate device's
        cache would elide bytes for this request)."""
        if order == "warm" and warmth is None:
            warmth = self._default_warmth
        queue = AdmissionQueue(requests, mode=order, warmth=warmth,
                               warm_slack=warm_slack)
        while len(queue):
            self.dispatch(queue.pop(self.host))
        return self.finish()

    def _default_warmth(self, req: LaunchRequest) -> bool:
        """Is some candidate device still warm for this request's tenant?
        Pure: evaluates cache write-plans without dispatching them."""
        if not self.cache_enabled:
            return False
        for dev in self._candidates(req):
            plan = dev.cache.plan(req.tenant, req.regs_for(dev.model))
            if plan.context_hit and plan.bytes_elided > 0:
                return True
        return False

    def finish(self) -> SchedulerReport:
        makespan = max([self.host, *(d.queue.device_free for d in self.devices)])
        self.metrics.gauge("sched.makespan").set(makespan)
        return SchedulerReport(
            makespan=makespan,
            devices={d.id: d.telemetry for d in self.devices},
            cache_stats={d.id: d.cache.stats for d in self.devices},
            placements={t: dict(p) for t, p in self._placements.items()},
            links={self.port.name: LinkTelemetry.from_port(self.port, makespan)},
            resources={name: ResourceTelemetry.from_resource(res, makespan)
                       for name, res in self.res.all().items()},
            overlap_mode=self.overlap.mode,
            staging_buffers=self.overlap.buffers,
            transport=self.transport,
            compute_model=("flat" if self.compute_model is None
                           else self.compute_model.mode),
            power=self.power,
            objective=self.objective,
            metrics=self.metrics,
        )


def requests_from_trace(trace: Trace, tenant: str) -> list[LaunchRequest]:
    """Admit a *compiled accfg program* into the scheduler: replay its
    invocation log (the interpreter's observable, register snapshots at each
    launch) as a stream of launch requests. The compile-time passes have
    already deduplicated within the program; the scheduler's cache then
    dedups *across* programs and tenants."""
    reqs = []
    for inv in trace.invocations:
        model = REGISTRY[inv.accel]
        dims = tuple(int(inv.regs.get(f, 0)) for f in model.dim_fields)
        extra = {k: v for k, v in inv.regs.items() if k not in model.dim_fields}
        reqs.append(LaunchRequest(tenant=tenant, dims=dims, extra=extra,
                                  accel=inv.accel))
    return reqs
