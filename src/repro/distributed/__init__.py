from .sharding import (
    batch_axes,
    cache_shardings,
    input_shardings,
    opt_state_shardings,
    param_shardings,
)

__all__ = [
    "batch_axes",
    "cache_shardings",
    "input_shardings",
    "opt_state_shardings",
    "param_shardings",
]
