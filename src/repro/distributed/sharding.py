"""Sharding rules: DP / TP / EP / ZeRO over the production mesh.

Rules are *divisibility-aware*: a dim is only sharded along a mesh axis when
its size divides evenly (a NamedSharding requirement). This matters in the
pool — qwen2.5-32b has 40 heads and qwen2-0.5b has 14, neither divisible by
the 16-way model axis — so those archs shard the packed ``heads×head_dim``
projection dim (which *is* divisible) and let GSPMD insert the resharding
around the attention einsum; the collective term of the roofline makes that
cost visible instead of hiding it.

Scheme:
* batch dims            → ("pod","data") (DP across pods and the data axis)
* attn/MLP in-proj      → model axis on the output (TP, Megatron-style)
* attn/MLP out-proj     → model axis on the input
* MoE expert stacks     → model axis on the expert dim (EP)
* embeddings            → vocab on model axis (falls back to d_model)
* norms/scalars/biases  → replicated
* optimizer states      → param spec + the largest remaining dim sharded
                          along the data axis (ZeRO-style state partitioning)
* KV caches             → batch on data; kv-heads on model when divisible,
                          else head_dim on model (long_500k's batch=1 case)
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh, cfg: ModelConfig | None = None):
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if cfg is not None and cfg.pure_dp:
        axes = axes + ("model",)  # batch over every axis: 256/512-way DP
    return axes


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _div(mesh: Mesh, dim: int, axis: str) -> str | None:
    return axis if dim % _axis_size(mesh, axis) == 0 and dim > 0 else None


def _apply_fsdp(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Additionally shard the largest unsharded divisible dim along data."""
    data = _axis_size(mesh, "data")
    spec = list(spec)
    best, best_dim = -1, -1
    for i, (dim, ax) in enumerate(zip(shape, spec)):
        if ax is None and dim % data == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        spec[best] = "data"
    return P(*spec)


_ATTN_PARAMS = ("wq", "wk", "wv", "wo", "bq", "bk", "bv")
# rwkv time-mix: TP here puts a cross-shard reduce inside every step of the
# 4096-long sequence scan; tp_attention=0 replicates the mixer the same way
_MIXER_CTX = {"attn", "self_attn", "cross_attn", "tm"}
_RWKV_TM_PARAMS = ("wr", "wk", "wv", "wg", "wo", "w_decay")


def _spec_for_param(
    mesh: Mesh, path: tuple[str, ...], shape: tuple[int, ...],
    cfg: ModelConfig | None = None,
) -> P:
    name = path[-1]
    ctx = set(path)
    nd = len(shape)

    if cfg is not None and cfg.pure_dp:
        return P(*([None] * nd))  # fully replicated; batch takes every axis

    if (
        cfg is not None
        and not cfg.tp_attention
        and (name in _ATTN_PARAMS or name in _RWKV_TM_PARAMS)
        and ctx & _MIXER_CTX
    ):
        spec = P(*([None] * nd))  # replicate attention, TP only in the MLPs
        return _apply_fsdp(mesh, spec, shape) if cfg.fsdp else spec

    def trailing(*axes):
        """Pad leading stack dims (scan-stacked layers/groups) with None."""
        pad = [None] * (nd - len(axes))
        spec = P(*pad, *axes)
        if cfg is not None and cfg.fsdp:
            spec = _apply_fsdp(mesh, spec, shape)
        return spec

    if name in ("embed",):
        v, d = shape[-2], shape[-1]
        ax = _div(mesh, v, "model")
        if ax:
            return P(ax, None)
        return P(None, _div(mesh, d, "model"))
    if name == "head":
        d, v = shape[-2], shape[-1]
        return P(None, _div(mesh, v, "model"))

    # MoE expert stacks: (…, E, d, ff) / (…, E, ff, d) — EP on the expert dim
    if ctx & {"moe", "mamba_moe"} or (name == "router"):
        if name == "router":
            nd_r = nd
            return P(*([None] * nd_r))
        e = shape[-3]
        ax = _div(mesh, e, "model")
        if ax:
            spec = P(*([None] * (nd - 3)), ax, None, None)
            # the shard_map MoE consumes experts at exactly EP sharding; FSDP
            # must not add a data dim there (in_specs are explicit)
            if cfg is not None and cfg.fsdp and cfg.moe_impl != "shard_map":
                spec = _apply_fsdp(mesh, spec, shape)
            return spec
        return trailing(None, None, _div(mesh, shape[-1], "model"))

    if nd >= 2:
        din, dout = shape[-2], shape[-1]
        # column-parallel (shard output dim): qkv projections, MLP in/gate,
        # mamba in_proj & conv, rwkv r/k/v/g/decay
        if name in ("wq", "wk", "wv", "wi", "wg", "in_proj", "conv_w", "wr", "w_decay"):
            return trailing(None, _div(mesh, dout, "model"))
        # row-parallel (shard input dim): output projections
        if name in ("wo", "out_proj", "x_proj"):
            return trailing(_div(mesh, din, "model"), None)
        if name in ("a_log",):
            return trailing(_div(mesh, din, "model"), None)
    if nd >= 1 and name in ("bq", "bk", "bv"):
        return trailing(_div(mesh, shape[-1], "model"))
    if nd >= 1 and name in ("dt_bias", "d_skip"):
        return trailing(_div(mesh, shape[-1], "model"))
    # norms, scalar mixes, biases: replicated
    return P(*([None] * nd))


def _tree_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        names = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        yield names, leaf
    return


def _map_with_paths(tree, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        names = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        leaves.append(fn(names, leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_shardings(mesh: Mesh, abstract_params, cfg: ModelConfig | None = None):
    def fn(names, leaf):
        return NamedSharding(mesh, _spec_for_param(mesh, names, leaf.shape, cfg))

    return _map_with_paths(abstract_params, fn)


def opt_state_shardings(mesh: Mesh, abstract_opt_state, cfg: ModelConfig | None = None):
    """ZeRO-style: the master/m/v leaves take the param spec plus one extra
    dim sharded along the data axis (largest unsharded divisible dim)."""
    data = _axis_size(mesh, "data")

    def fn(names, leaf):
        if names[0] == "step":
            return NamedSharding(mesh, P())
        pnames = names[1:]  # strip master/m/v prefix
        spec = list(_spec_for_param(mesh, pnames, leaf.shape, cfg))
        if "data" not in spec:  # FSDP params already consume the data axis
            # pick the largest dim not already sharded and divisible by data
            best, best_dim = -1, -1
            for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
                if ax is None and dim % data == 0 and dim > best_dim:
                    best, best_dim = i, dim
            if best >= 0:
                spec[best] = "data"
        return NamedSharding(mesh, P(*spec))

    return _map_with_paths(abstract_opt_state, fn)


def input_shardings(mesh: Mesh, specs: dict, cfg: ModelConfig | None = None):
    b = batch_axes(mesh, cfg)
    total = 1
    for a in b:
        total *= _axis_size(mesh, a)

    def fn(names, leaf):
        nd = len(leaf.shape)
        batch = b if leaf.shape[0] % total == 0 else None
        return NamedSharding(mesh, P(batch, *([None] * (nd - 1))))

    return _map_with_paths(specs, fn)


def cache_shardings(mesh: Mesh, cfg: ModelConfig, abstract_cache):
    b_axes = batch_axes(mesh)
    data = 1
    for a in b_axes:
        data *= _axis_size(mesh, a)

    def fn(names, leaf):
        name = names[-1]
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv", "k_scale", "v_scale"):
            # (L, B, S, Hkv, D) — scales are D=1 companions of a quantized cache
            batch = b_axes if shape[1] % data == 0 else None
            if cfg.cache_shard_seq and _div(mesh, shape[2], "model"):
                # flash-decoding layout: shard the sequence dim; softmax
                # reduces with tiny cross-shard (B,H) statistics instead of
                # regathering the cache every step
                return NamedSharding(mesh, P(None, batch, "model", None, None))
            hkv = _div(mesh, shape[3], "model")
            hd = None if hkv else _div(mesh, shape[4], "model")
            return NamedSharding(mesh, P(None, batch, None, hkv, hd))
        if name == "h":  # (G, m, B, d_in, N)
            batch = b_axes if shape[2] % data == 0 else None
            return NamedSharding(
                mesh, P(None, None, batch, _div(mesh, shape[3], "model"), None)
            )
        if name == "conv":  # (G, m, B, K, d_in)
            batch = b_axes if shape[2] % data == 0 else None
            return NamedSharding(
                mesh, P(None, None, batch, None, _div(mesh, shape[4], "model"))
            )
        if name == "s":  # (L, B, H, hd, hd)
            batch = b_axes if shape[1] % data == 0 else None
            return NamedSharding(
                mesh, P(None, batch, _div(mesh, shape[2], "model"), None, None)
            )
        if name in ("shift_tm", "shift_cm"):  # (L, B, d)
            batch = b_axes if shape[1] % data == 0 else None
            return NamedSharding(mesh, P(None, batch, _div(mesh, shape[2], "model")))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return _map_with_paths(abstract_cache, fn)
