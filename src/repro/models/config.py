"""Model configuration schema for the architecture pool.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro.configs.<id>``; reduced variants for CPU smoke tests come from
:meth:`ModelConfig.reduced`.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_period: int = 1  # MoE MLP every k-th layer (Jamba: 2), dense otherwise

    # hybrid (Jamba): one attention layer per `attn_period` layers, rest Mamba
    attn_period: int = 0  # 0 = pure-attention (or pure-SSM for family=ssm)

    # MLP flavour: swiglu (3 matrices) or gelu (2 matrices, whisper-style)
    mlp_kind: str = "swiglu"

    # SSM / RWKV
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # 30 s of audio at 50 Hz after the conv stub

    # modality frontends are STUBS per the assignment: input_specs() provides
    # precomputed frame/patch embeddings of this many positions
    frontend: str = ""  # "" | "audio_stub" | "vision_stub"
    frontend_tokens: int = 0

    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # training-time knobs (hillclimbed in §Perf)
    remat: str = "full"  # none | full | dots
    scan_unroll: bool = False  # unroll layer scans (dry-run: exact HLO costs)
    notes: str = ""

    # ---- distribution policy (hillclimb levers, EXPERIMENTS.md §Perf) ----
    tp_attention: bool = True  # model-shard attention projections
    pure_dp: bool = False  # replicate params; batch over every mesh axis
    fsdp: bool = False  # additionally shard params along the data axis
    grad_compression: str = "none"  # none | bf16 (cross-data reduce dtype)
    cache_shard_seq: bool = False  # decode KV cache: shard the seq dim (TP)
    attn_chunk: int = 0  # 0 = vanilla attention; >0 = online-softmax chunks
    moe_impl: str = "gspmd"  # gspmd (sort+scatter) | shard_map (explicit a2a EP)
    cache_quant: str = "none"  # none | int8 (per-token-head scaled KV cache)
    ssm_chunk: int = 0  # 0 = one associative scan over S; >0 = chunked SSD-style

    # ---------------------------------------------------------------- props

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (SSM/hybrid/linear-attention) archs run long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decode path

    def n_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid" and self.attn_period:
            return self.n_layers // self.attn_period
        return self.n_layers

    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid" and self.attn_period:
            return self.n_layers - self.n_attn_layers()
        return 0

    # ------------------------------------------------------------- counting

    def n_moe_layers(self) -> int:
        if self.n_experts == 0:
            return 0
        return self.n_layers // self.moe_period

    def param_count(self) -> int:
        """Total parameters (embeddings + trunk), used for 6·N·D."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.head_dim_, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d  # q,k,v,o
        mats = 3 if self.mlp_kind == "swiglu" else 2
        dense_mlp = mats * d * ff
        moe_mlp = self.n_experts * mats * d * ff + d * self.n_experts
        ssm = self._ssm_params()
        norms = 2 * d

        total = emb
        n_attn, n_ssm = self.n_attn_layers(), self.n_ssm_layers()
        if self.family == "encdec":
            enc = self.n_encoder_layers * (attn + dense_mlp + norms)
            dec = self.n_layers * (2 * attn + dense_mlp + 3 * d)  # + cross-attn
            return total + enc + dec
        if self.family == "ssm":
            return total + self.n_layers * (ssm + dense_mlp + norms)
        # dense / vlm / moe / hybrid: per-layer mixer + per-layer MLP
        n_moe = self.n_moe_layers()
        n_dense_mlp = self.n_layers - n_moe
        total += n_attn * attn + n_ssm * ssm + self.n_layers * norms
        total += n_moe * moe_mlp + n_dense_mlp * dense_mlp
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mats = 3 if self.mlp_kind == "swiglu" else 2
        k = self.experts_per_token
        per_layer_active = k * mats * d * ff + d * self.n_experts
        per_layer_total = self.n_experts * mats * d * ff + d * self.n_experts
        return self.param_count() - self.n_moe_layers() * (
            per_layer_total - per_layer_active
        )

    def _ssm_params(self) -> int:
        d = self.d_model
        if self.family == "ssm":  # RWKV6 time-mix block
            return 4 * d * d + 6 * d  # r,k,v,o + decay/mix vectors
        d_in = self.ssm_expand * d  # Mamba block
        return (
            2 * d * d_in  # in_proj (x, z)
            + d_in * self.ssm_conv_dim
            + d_in * (2 * self.ssm_state_dim + 1)  # x -> B, C, dt
            + d_in  # dt bias + A diag + D
            + d_in * d  # out_proj
        )

    # ------------------------------------------------------------- variants

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, (self.attn_period or 2)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq_len=16 if self.n_encoder_layers else 0,
            frontend_tokens=8 if self.frontend else 0,
            rwkv_head_dim=16,
        )
