"""Unified model API over the architecture pool.

Every assigned architecture — dense GQA transformers, MoE transformers, the
Jamba attention/Mamba hybrid, RWKV-6, Whisper (enc-dec), and phi-3-vision —
is instantiated through one :class:`Model` facade:

* ``init(key)`` / ``abstract_params()`` — concrete or shape-only parameters.
* ``forward(params, batch)`` / ``loss(params, batch)`` — training path.
* ``init_cache(batch, len)`` / ``abstract_cache()`` / ``decode_step(...)``
  — serving path (single-token decode against a persistent cache).

Layer trunks are built with ``lax.scan`` over stacked per-layer parameters so
the lowered HLO stays small even for the 72-layer Jamba trunk; heterogeneous
trunks (Jamba's 1-attention-per-8 interleave with MoE every other layer) scan
over *groups* and unroll inside the group.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops as kernel_ops
from . import layers as L
from .config import ModelConfig

Array = jax.Array


def _norm_init(key, d: int, kind: str = "rms"):
    if kind == "ln":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def _norm(params, x, eps):
    if "b" in params:
        return L.layer_norm(x, params["w"], params["b"], eps)
    return L.rms_norm(x, params["w"], eps)


def _sinusoidal(positions: Array, d: int) -> Array:
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(L.COMPUTE_DTYPE)


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _scan(self, body, init, xs):
        """lax.scan with optional full unroll (dry-run exact HLO costs)."""
        return lax.scan(body, init, xs, unroll=True if self.cfg.scan_unroll else 1)

    # ================================================================ params

    def init(self, key) -> dict:
        cfg = self.cfg
        kemb, khead, ktrunk, kfinal = jax.random.split(key, 4)
        params: dict = {
            "embed": L._dense_init(kemb, (cfg.vocab_size, cfg.d_model)),
            "final_norm": _norm_init(kfinal, cfg.d_model, self._norm_kind()),
        }
        if not cfg.tie_embeddings:
            params["head"] = L._dense_init(khead, (cfg.d_model, cfg.vocab_size))
        if cfg.family in ("dense", "vlm", "moe"):
            params["layers"] = self._uniform_trunk_init(ktrunk)
        elif cfg.family == "hybrid":
            params["groups"] = self._hybrid_trunk_init(ktrunk)
        elif cfg.family == "ssm":
            params["layers"] = self._rwkv_trunk_init(ktrunk)
        elif cfg.family == "encdec":
            kenc, kdec = jax.random.split(ktrunk)
            params["enc_layers"] = self._encoder_trunk_init(kenc)
            params["enc_final_norm"] = _norm_init(kenc, cfg.d_model, "ln")
            params["dec_layers"] = self._decoder_trunk_init(kdec)
        else:
            raise ValueError(cfg.family)
        return params

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    def _norm_kind(self) -> str:
        return "ln" if self.cfg.family in ("ssm", "encdec") else "rms"

    def _uniform_trunk_init(self, key) -> dict:
        cfg = self.cfg
        n = cfg.n_layers
        k1, k2, k3, k4 = jax.random.split(key, 4)
        trunk = {
            "attn_norm": _stack_init(lambda k: _norm_init(k, cfg.d_model), k1, n),
            "attn": _stack_init(lambda k: L.attention_init(k, cfg), k2, n),
            "mlp_norm": _stack_init(lambda k: _norm_init(k, cfg.d_model), k3, n),
        }
        if cfg.family == "moe":
            trunk["moe"] = _stack_init(lambda k: L.moe_init(k, cfg), k4, n)
        else:
            trunk["mlp"] = _stack_init(lambda k: L.mlp_init(k, cfg), k4, n)
        return trunk

    def _hybrid_trunk_init(self, key) -> dict:
        cfg = self.cfg
        g = cfg.n_layers // cfg.attn_period
        m = cfg.attn_period - 1  # mamba layers per group
        n_moe = (m + 1) // 2  # mamba positions 0,2,4,... carry MoE
        n_dense_m = m - n_moe
        ks = jax.random.split(key, 8)
        d = cfg.d_model
        return {
            "attn_norm": _stack_init(lambda k: _norm_init(k, d), ks[0], g),
            "attn": _stack_init(lambda k: L.attention_init(k, cfg), ks[1], g),
            "attn_mlp_norm": _stack_init(lambda k: _norm_init(k, d), ks[2], g),
            "attn_mlp": _stack_init(lambda k: L.mlp_init(k, cfg), ks[3], g),
            "mamba_norm": _stack_init(
                lambda k: _stack_init(lambda k2: _norm_init(k2, d), k, m), ks[4], g
            ),
            "mamba": _stack_init(
                lambda k: _stack_init(lambda k2: L.mamba_init(k2, cfg), k, m), ks[5], g
            ),
            "mamba_mlp_norm": _stack_init(
                lambda k: _stack_init(lambda k2: _norm_init(k2, d), k, m), ks[4], g
            ),
            "mamba_moe": _stack_init(
                lambda k: _stack_init(lambda k2: L.moe_init(k2, cfg), k, n_moe),
                ks[6],
                g,
            ),
            "mamba_mlp": _stack_init(
                lambda k: _stack_init(lambda k2: L.mlp_init(k2, cfg), k, n_dense_m),
                ks[7],
                g,
            ),
        }

    def _rwkv_trunk_init(self, key) -> dict:
        cfg = self.cfg
        n = cfg.n_layers
        ks = jax.random.split(key, 4)
        d = cfg.d_model
        return {
            "tm_norm": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[0], n),
            "tm": _stack_init(lambda k: L.rwkv_init(k, cfg), ks[1], n),
            "cm_norm": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[2], n),
            "cm": _stack_init(lambda k: L.rwkv_channel_mix_init(k, cfg), ks[3], n),
        }

    def _encoder_trunk_init(self, key) -> dict:
        cfg = self.cfg
        n = cfg.n_encoder_layers
        ks = jax.random.split(key, 4)
        d = cfg.d_model
        return {
            "ln1": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[0], n),
            "attn": _stack_init(lambda k: L.attention_init(k, cfg), ks[1], n),
            "ln2": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[2], n),
            "mlp": _stack_init(lambda k: L.mlp_init(k, cfg), ks[3], n),
        }

    def _decoder_trunk_init(self, key) -> dict:
        cfg = self.cfg
        n = cfg.n_layers
        ks = jax.random.split(key, 6)
        d = cfg.d_model
        return {
            "ln1": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[0], n),
            "self_attn": _stack_init(lambda k: L.attention_init(k, cfg), ks[1], n),
            "ln2": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[2], n),
            "cross_attn": _stack_init(lambda k: L.attention_init(k, cfg), ks[3], n),
            "ln3": _stack_init(lambda k: _norm_init(k, d, "ln"), ks[4], n),
            "mlp": _stack_init(lambda k: L.mlp_init(k, cfg), ks[5], n),
        }

    # ================================================================= train

    def forward(self, params: dict, batch: dict) -> tuple[Array, Array]:
        """Returns (logits, moe_aux_loss)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return self._forward_encdec(params, batch)
        tokens = batch["tokens"]
        x = params["embed"][tokens]  # (B, S, d)
        prefix = 0
        if cfg.family == "vlm":
            fe = batch["frontend_embeds"].astype(x.dtype)  # (B, P, d)
            x = jnp.concatenate([fe, x], axis=1)
            prefix = fe.shape[1]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        if cfg.family in ("dense", "vlm", "moe"):
            x, aux = self._uniform_trunk(params["layers"], x, positions)
        elif cfg.family == "hybrid":
            x, aux = self._hybrid_trunk(params["groups"], x, positions)
        elif cfg.family == "ssm":
            x, aux = self._rwkv_trunk(params["layers"], x)
        else:
            raise ValueError(cfg.family)

        x = _norm(params["final_norm"], x, cfg.norm_eps)
        if prefix:
            x = x[:, prefix:]
        logits = x @ self._head(params)
        return logits, aux

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def _uniform_trunk(self, trunk, x, positions):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            h, _ = L.attention_apply(
                lp["attn"], cfg, _norm(lp["attn_norm"], x, cfg.norm_eps), positions
            )
            x = x + h
            y = _norm(lp["mlp_norm"], x, cfg.norm_eps)
            if "moe" in lp:
                y, a = L.moe_apply(lp["moe"], cfg, y)
                aux = aux + a
            else:
                y = L.mlp_apply(lp["mlp"], y)
            return (x + y, aux), None

        (x, aux), _ = self._scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), trunk)
        return x, aux

    def _hybrid_trunk(self, trunk, x, positions):
        cfg = self.cfg
        m = cfg.attn_period - 1

        def body(carry, gp):
            x, aux = carry
            # attention layer (dense MLP)
            h, _ = L.attention_apply(
                gp["attn"], cfg, _norm(gp["attn_norm"], x, cfg.norm_eps), positions
            )
            x = x + h
            x = x + L.mlp_apply(
                gp["attn_mlp"], _norm(gp["attn_mlp_norm"], x, cfg.norm_eps)
            )
            # mamba layers; even in-group index carries MoE
            i_moe = i_mlp = 0
            for i in range(m):
                lpn = jax.tree.map(lambda a: a[i], gp["mamba_norm"])
                lp = jax.tree.map(lambda a: a[i], gp["mamba"])
                x = x + L.mamba_apply(lp, cfg, _norm(lpn, x, cfg.norm_eps))
                mn = jax.tree.map(lambda a: a[i], gp["mamba_mlp_norm"])
                y = _norm(mn, x, cfg.norm_eps)
                if i % 2 == 0:
                    mp = jax.tree.map(lambda a, i_moe=i_moe: a[i_moe], gp["mamba_moe"])
                    y, a = L.moe_apply(mp, cfg, y)
                    aux = aux + a
                    i_moe += 1
                else:
                    mp = jax.tree.map(lambda a, i_mlp=i_mlp: a[i_mlp], gp["mamba_mlp"])
                    y = L.mlp_apply(mp, y)
                    i_mlp += 1
                x = x + y
            return (x, aux), None

        (x, aux), _ = self._scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), trunk)
        return x, aux

    def _rwkv_trunk(self, trunk, x):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            x = x + L.rwkv_apply(lp["tm"], cfg, _norm(lp["tm_norm"], x, cfg.norm_eps))
            h = _norm(lp["cm_norm"], x, cfg.norm_eps)
            shifted = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            x = x + L.rwkv_channel_mix(lp["cm"], h, shifted)
            return (x, aux), None

        (x, aux), _ = self._scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), trunk)
        return x, aux

    def _forward_encdec(self, params, batch):
        cfg = self.cfg
        frames = batch["frontend_embeds"].astype(L.COMPUTE_DTYPE)  # (B, T, d)
        tokens = batch["tokens"]
        b, t = frames.shape[:2]
        frames = frames + _sinusoidal(jnp.arange(t), cfg.d_model)[None]
        enc_pos = jnp.broadcast_to(jnp.arange(t), (b, t))

        def enc_body(x, lp):
            h, _ = L.attention_apply(
                lp["attn"], cfg, _norm(lp["ln1"], x, cfg.norm_eps), enc_pos,
                causal=False, use_rope=False,
            )
            x = x + h
            x = x + L.mlp_apply(lp["mlp"], _norm(lp["ln2"], x, cfg.norm_eps))
            return x, None

        enc, _ = self._scan(_remat(enc_body, cfg), frames, params["enc_layers"])
        enc = _norm(params["enc_final_norm"], enc, cfg.norm_eps)

        x = params["embed"][tokens]
        s = x.shape[1]
        x = x + _sinusoidal(jnp.arange(s), cfg.d_model)[None]
        dec_pos = jnp.broadcast_to(jnp.arange(s), (b, s))

        def dec_body(x, lp):
            h, _ = L.attention_apply(
                lp["self_attn"], cfg, _norm(lp["ln1"], x, cfg.norm_eps), dec_pos,
                causal=True, use_rope=False,
            )
            x = x + h
            h, _ = L.attention_apply(
                lp["cross_attn"], cfg, _norm(lp["ln2"], x, cfg.norm_eps), dec_pos,
                causal=False, use_rope=False, kv=enc,
            )
            x = x + h
            x = x + L.mlp_apply(lp["mlp"], _norm(lp["ln3"], x, cfg.norm_eps))
            return x, None

        x, _ = self._scan(_remat(dec_body, cfg), x, params["dec_layers"])
        x = _norm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head(params)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params: dict, batch: dict) -> tuple[Array, dict]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # vocab-parallel gold-logit extraction: a masked sum keeps the vocab
        # dim sharded under GSPMD (take_along_axis would force an all-gather
        # of the full logits — ~40 GB/device on the 200k-vocab archs)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        mask = (vocab_iota[None, None, :] == labels[..., None]).astype(jnp.float32)
        gold = jnp.sum(logits * mask, axis=-1)
        ce = jnp.mean(logz - gold)
        zloss = 1e-4 * jnp.mean(jnp.square(logz))
        total = ce + zloss + 0.01 * aux
        return total, {"ce": ce, "zloss": zloss, "moe_aux": aux}

    # ================================================================= serve

    def init_cache(self, batch_size: int, max_len: int, concrete: bool = True):
        cfg = self.cfg
        mk = jnp.zeros if concrete else jax.ShapeDtypeStruct
        hd, hkv = cfg.head_dim_, cfg.n_kv_heads
        d_in = cfg.ssm_expand * cfg.d_model

        def arr(shape, dtype=L.COMPUTE_DTYPE):
            return jnp.zeros(shape, dtype) if concrete else jax.ShapeDtypeStruct(shape, dtype)

        if cfg.family in ("dense", "vlm", "moe"):
            n = cfg.n_layers
            cache = {
                "k": arr((n, batch_size, max_len, hkv, hd),
                         jnp.int8 if cfg.cache_quant == "int8" else L.COMPUTE_DTYPE),
                "v": arr((n, batch_size, max_len, hkv, hd),
                         jnp.int8 if cfg.cache_quant == "int8" else L.COMPUTE_DTYPE),
            }
            if cfg.cache_quant == "int8":
                cache["k_scale"] = arr((n, batch_size, max_len, hkv, 1))
                cache["v_scale"] = arr((n, batch_size, max_len, hkv, 1))
            return cache
        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_period
            m = cfg.attn_period - 1
            return {
                "k": arr((g, batch_size, max_len, hkv, hd)),
                "v": arr((g, batch_size, max_len, hkv, hd)),
                "h": arr((g, m, batch_size, d_in, cfg.ssm_state_dim), jnp.float32),
                "conv": arr((g, m, batch_size, cfg.ssm_conv_dim, d_in)),
            }
        if cfg.family == "ssm":
            n = cfg.n_layers
            nh = cfg.d_model // cfg.rwkv_head_dim
            return {
                "s": arr((n, batch_size, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
                "shift_tm": arr((n, batch_size, cfg.d_model)),
                "shift_cm": arr((n, batch_size, cfg.d_model)),
            }
        if cfg.family == "encdec":
            n = cfg.n_layers
            return {
                "k": arr((n, batch_size, max_len, hkv, hd)),
                "v": arr((n, batch_size, max_len, hkv, hd)),
                "xk": arr((n, batch_size, cfg.encoder_seq_len, hkv, hd)),
                "xv": arr((n, batch_size, cfg.encoder_seq_len, hkv, hd)),
            }
        raise ValueError(cfg.family)

    def decode_step(
        self, params: dict, cache: dict, tokens: Array, pos: Array,
        update_mask: Array | None = None,
    ) -> tuple[Array, dict]:
        """One new token per sequence. tokens: (B, 1); pos: scalar int32, or
        an (B,) int32 vector for continuous batching (per-slot positions).

        ``update_mask`` (optional, (B,) bool) freezes the cache rows of
        unselected batch entries: masked-out slots still compute (their
        logits are garbage to be discarded) but their cache state comes out
        bit-identical to what went in. This is what lets one launch advance
        only the slots it means to — a prefill chunk touching one admitted
        slot, or a decode step skipping dead slots."""
        cfg = self.cfg
        old_cache = cache
        x = params["embed"][tokens]  # (B, 1, d)
        b = x.shape[0]
        if getattr(pos, "ndim", 0) == 1:
            positions = pos[:, None]  # (B, 1): each slot at its own position
        else:
            positions = jnp.full((b, 1), pos)

        if cfg.family in ("dense", "vlm", "moe"):
            x, cache = self._uniform_decode(params["layers"], cache, x, positions, pos)
        elif cfg.family == "hybrid":
            x, cache = self._hybrid_decode(params["groups"], cache, x, positions, pos)
        elif cfg.family == "ssm":
            x, cache = self._rwkv_decode(params["layers"], cache, x)
        elif cfg.family == "encdec":
            x, cache = self._encdec_decode(params["dec_layers"], cache, x, positions, pos)
        else:
            raise ValueError(cfg.family)

        if update_mask is not None:
            cache = self._masked_cache(old_cache, cache, update_mask)
        x = _norm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head(params)
        return logits, cache

    @staticmethod
    def _masked_cache(old: dict, new: dict, update_mask: Array) -> dict:
        """Per-leaf batch-row select: rows where ``update_mask`` is False
        keep their old cache state bit-exactly. The batch axis is 1 on
        every cache layout (layer/group axis leads) except the hybrid
        trunk's per-group-stacked ``h``/``conv`` leaves, where it is 2."""
        b = update_mask.shape[0]

        def merge(key: str, o: Array, n: Array) -> Array:
            if n is o:  # passthrough leaves (encdec xk/xv): nothing to mask
                return n
            ax = 2 if key in ("h", "conv") else 1
            shape = [1] * n.ndim
            shape[ax] = b
            return jnp.where(update_mask.reshape(shape), n, o)

        return {k: merge(k, old[k], n) for k, n in new.items()}

    def decode_and_sample(
        self, params: dict, cache: dict, prev_tokens: Array,
        token_overrides: Array, override_mask: Array, pos: Array,
        update_mask: Array | None = None, *, sample_backend: str = "xla",
    ) -> tuple[Array, dict]:
        """Fused decode step + greedy sampling: the launch returns ``(B, 1)``
        int32 token ids instead of ``(B, vocab)`` logits, so the host's
        per-step sync point shrinks from the full logits tensor to a few
        bytes — and, because the sampled ids never leave the device, the
        next launch's input tokens are device-resident state rather than a
        descriptor field. The host injects tokens only through
        ``token_overrides``/``override_mask`` (admissions, freed slots),
        which elide in steady-state decode.

        ``prev_tokens``: (B, 1) device-resident ids from the previous step;
        ``token_overrides``: (B,) int32 host injections where
        ``override_mask`` (B, bool) is set."""
        tokens = jnp.where(override_mask[:, None],
                           token_overrides[:, None].astype(jnp.int32),
                           prev_tokens)
        logits, cache = self.decode_step(params, cache, tokens, pos,
                                         update_mask)
        ids = kernel_ops.sample_op(logits[:, 0], backend=sample_backend)
        return ids[:, None].astype(jnp.int32), cache

    def prefill_chunk(
        self, params: dict, cache: dict, chunk_tokens: Array, pos0: Array,
        n_valid: Array, slot_mask: Array,
    ) -> tuple[Array, dict]:
        """Batched prefill: advance only the slots in ``slot_mask`` through
        up to ``len(chunk_tokens)`` prompt tokens in **one launch** — a
        ``lax.scan`` of masked decode steps, so a p-token prompt costs
        ``ceil(p/chunk)`` launches instead of p full-batch launches.

        ``chunk_tokens``: (T,) int32, valid through ``n_valid`` (padded
        steps are fully masked — no slot advances); ``pos0``: (B,) int32
        per-slot start positions (step i writes at ``pos0 + i``);
        ``slot_mask``: (B,) bool selecting the admitted slot(s). Returns
        ``(probe, cache)`` where probe is the (B, 1) int32 argmax of the
        last valid step for the masked slots (a few-byte sync handle for
        the staging ring; zeros for unmasked slots)."""
        b = slot_mask.shape[0]

        def body(carry, xs):
            cache, probe = carry
            i, tok = xs
            step_mask = slot_mask & (i < n_valid)
            toks = jnp.full((b, 1), tok, jnp.int32)
            logits, cache = self.decode_step(params, cache, toks, pos0 + i,
                                             step_mask)
            ids = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            probe = jnp.where(step_mask[:, None], ids[:, None], probe)
            return (cache, probe), None

        t = chunk_tokens.shape[0]
        (cache, probe), _ = lax.scan(
            body, (cache, jnp.zeros((b, 1), jnp.int32)),
            (jnp.arange(t, dtype=jnp.int32), chunk_tokens.astype(jnp.int32)),
        )
        return probe, cache

    def _uniform_decode(self, trunk, cache, x, positions, pos):
        cfg = self.cfg
        quant = "k_scale" in cache

        def body(x, inputs):
            if quant:
                lp, lk, lv, lks, lvs = inputs
                layer_cache = {"k": lk, "v": lv, "k_scale": lks, "v_scale": lvs}
            else:
                lp, lk, lv = inputs
                layer_cache = {"k": lk, "v": lv}
            h, nc = L.attention_apply(
                lp["attn"], cfg, _norm(lp["attn_norm"], x, cfg.norm_eps), positions,
                cache=layer_cache, cache_pos=pos,
            )
            x = x + h
            y = _norm(lp["mlp_norm"], x, cfg.norm_eps)
            if "moe" in lp:
                y, _ = L.moe_apply(lp["moe"], cfg, y)
            else:
                y = L.mlp_apply(lp["mlp"], y)
            if quant:
                return x + y, (nc["k"], nc["v"], nc["k_scale"], nc["v_scale"])
            return x + y, (nc["k"], nc["v"])

        if quant:
            x, (ck, cv, cks, cvs) = self._scan(
                body, x,
                (trunk, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]),
            )
            return x, {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        x, (ck, cv) = self._scan(body, x, (trunk, cache["k"], cache["v"]))
        return x, {"k": ck, "v": cv}

    def _hybrid_decode(self, trunk, cache, x, positions, pos):
        cfg = self.cfg
        m = cfg.attn_period - 1

        def body(x, inputs):
            gp, lk, lv, gh, gconv = inputs
            h, nc = L.attention_apply(
                gp["attn"], cfg, _norm(gp["attn_norm"], x, cfg.norm_eps), positions,
                cache={"k": lk, "v": lv}, cache_pos=pos,
            )
            x = x + h
            x = x + L.mlp_apply(
                gp["attn_mlp"], _norm(gp["attn_mlp_norm"], x, cfg.norm_eps)
            )
            new_h, new_conv = [], []
            i_moe = i_mlp = 0
            for i in range(m):
                lpn = jax.tree.map(lambda a: a[i], gp["mamba_norm"])
                lp = jax.tree.map(lambda a: a[i], gp["mamba"])
                y, st = L.mamba_step(
                    lp, cfg, _norm(lpn, x, cfg.norm_eps),
                    {"h": gh[i], "conv": gconv[i]},
                )
                x = x + y
                new_h.append(st["h"])
                new_conv.append(st["conv"])
                mn = jax.tree.map(lambda a: a[i], gp["mamba_mlp_norm"])
                y = _norm(mn, x, cfg.norm_eps)
                if i % 2 == 0:
                    mp = jax.tree.map(lambda a, j=i_moe: a[j], gp["mamba_moe"])
                    y, _ = L.moe_apply(mp, cfg, y)
                    i_moe += 1
                else:
                    mp = jax.tree.map(lambda a, j=i_mlp: a[j], gp["mamba_mlp"])
                    y = L.mlp_apply(mp, y)
                    i_mlp += 1
                x = x + y
            return x, (nc["k"], nc["v"], jnp.stack(new_h), jnp.stack(new_conv))

        x, (ck, cv, ch, cconv) = self._scan(
            body, x, (trunk, cache["k"], cache["v"], cache["h"], cache["conv"])
        )
        return x, {"k": ck, "v": cv, "h": ch, "conv": cconv}

    def _rwkv_decode(self, trunk, cache, x):
        cfg = self.cfg

        def body(x, inputs):
            lp, s, sh_tm, sh_cm = inputs
            h = _norm(lp["tm_norm"], x, cfg.norm_eps)
            y, st = L.rwkv_step(lp["tm"], cfg, h, {"s": s, "shift": sh_tm})
            x = x + y
            h = _norm(lp["cm_norm"], x, cfg.norm_eps)
            x = x + L.rwkv_channel_mix(lp["cm"], h[:, 0], sh_cm)[:, None]
            return x, (st["s"], st["shift"], h[:, 0])

        x, (s, sh_tm, sh_cm) = self._scan(
            body, x, (trunk, cache["s"], cache["shift_tm"], cache["shift_cm"])
        )
        return x, {"s": s, "shift_tm": sh_tm, "shift_cm": sh_cm}

    def _encdec_decode(self, trunk, cache, x, positions, pos):
        cfg = self.cfg
        x = x + _sinusoidal(positions, cfg.d_model)

        def body(x, inputs):
            lp, lk, lv, xk, xv = inputs
            h, nc = L.attention_apply(
                lp["self_attn"], cfg, _norm(lp["ln1"], x, cfg.norm_eps), positions,
                use_rope=False, cache={"k": lk, "v": lv}, cache_pos=pos,
            )
            x = x + h
            # cross attention against precomputed encoder K/V
            h = _norm(lp["ln2"], x, cfg.norm_eps)
            q = L._split_heads(h @ lp["cross_attn"]["wq"], cfg.n_heads)
            scores = L.gqa_scores(q, xk, cfg.n_kv_heads).astype(jnp.float32)
            probs = jax.nn.softmax(scores, axis=-1).astype(L.COMPUTE_DTYPE)
            o = L.gqa_combine(probs, xv)
            bsz = x.shape[0]
            x = x + o.reshape(bsz, 1, -1) @ lp["cross_attn"]["wo"]
            x = x + L.mlp_apply(lp["mlp"], _norm(lp["ln3"], x, cfg.norm_eps))
            return x, (nc["k"], nc["v"])

        x, (ck, cv) = self._scan(
            body, x, (trunk, cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        return x, {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}

    # ================================================================ inputs

    def input_specs(self, batch_size: int, seq_len: int) -> dict:
        """ShapeDtypeStruct stand-ins for one training batch."""
        cfg = self.cfg
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        }
        if cfg.family == "vlm":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.frontend_tokens, cfg.d_model), L.COMPUTE_DTYPE
            )
        if cfg.family == "encdec":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.encoder_seq_len, cfg.d_model), L.COMPUTE_DTYPE
            )
        return specs
