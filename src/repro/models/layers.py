"""Neural building blocks shared by every architecture in the pool.

Pure-function style: each block is ``init(key, cfg) -> params`` plus
``apply(params, x, ...) -> y`` over plain dict pytrees, so layers stack via
``jax.lax.scan`` (small HLO even for 72-layer trunks) and shard via
``NamedSharding`` trees computed from param paths (``repro.distributed``).

Compute dtype is bf16 with fp32 normalization/softmax/logits; this matches
TPU MXU-native mixed precision.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale_axis: int = 0):
    scale = 1.0 / jnp.sqrt(jnp.asarray(shape[scale_axis], jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(COMPUTE_DTYPE)


# --------------------------------------------------------------------------
# Norms & positional encodings
# --------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * weight).astype(x.dtype)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * lax.rsqrt(var + eps) * weight + bias).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, d, 2, dtype=jnp.float32) / d
    )  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (B, S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (MHA / GQA, optional QKV bias, optional KV cache)
# --------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d, hq * hd)),
        "wk": _dense_init(ks[1], (d, hkv * hd)),
        "wv": _dense_init(ks[2], (d, hkv * hd)),
        "wo": _dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((hq * hd,), COMPUTE_DTYPE)
        params["bk"] = jnp.zeros((hkv * hd,), COMPUTE_DTYPE)
        params["bv"] = jnp.zeros((hkv * hd,), COMPUTE_DTYPE)
    return params


def _split_heads(x: Array, n_heads: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def gqa_scores(q: Array, k: Array, n_kv: int) -> Array:
    """q: (B,S,Hq,D), k: (B,T,Hkv,D) -> scores (B,Hkv,G,S,T)."""
    b, s, hq, d = q.shape
    g = hq // n_kv
    qg = q.reshape(b, s, n_kv, g, d)
    return jnp.einsum("bsngd,btnd->bngst", qg, k) / jnp.sqrt(float(d))


def gqa_combine(probs: Array, v: Array) -> Array:
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,D) -> (B,S,Hq,D)."""
    b, n, g, s, _t = probs.shape
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(b, s, n * g, -1)


def chunked_attention(
    q: Array, k: Array, v: Array, n_kv: int, *, causal: bool, chunk: int
) -> Array:
    """Online-softmax attention over KV chunks (flash-style, XLA path).

    Never materializes the S×T score matrix — the jnp twin of the Pallas
    flash kernel, used when a cell is memory-bound on the naive einsum path.
    q: (B,S,Hq,D); k,v: (B,T,Hkv,D) -> (B,S,Hq,D).
    """
    b, s, hq, d = q.shape
    t = k.shape[1]
    assert t % chunk == 0, (t, chunk)
    g = hq // n_kv
    qg = q.reshape(b, s, n_kv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    nchunks = t // chunk
    kc = jnp.moveaxis(k.reshape(b, nchunks, chunk, n_kv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, chunk, n_kv, d), 1, 0)
    rows = jnp.arange(s)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        sc = jnp.einsum(
            "bsngd,btnd->bngst", qg, kj.astype(jnp.float32)
        ) * scale  # (B,n,g,S,chunk)
        if causal:
            cols = j * chunk + jnp.arange(chunk)
            mask = rows[:, None] >= cols[None, :]
            sc = jnp.where(mask[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bngst,btnd->bngsd", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, n_kv, g, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, s, 1), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, s, d), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nchunks))
    )
    out = (acc / l).astype(q.dtype)  # (B,n,g,S,D)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, hq, d)


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) absmax int8 quantization. x: (B,S,H,D)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: Array, scale: Array) -> Array:
    return q.astype(COMPUTE_DTYPE) * scale.astype(COMPUTE_DTYPE)


def attention_apply(
    params: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv: Array | None = None,  # cross-attention source (B, T, d)
    cache: dict | None = None,  # {"k","v": (B, S_max, Hkv, D)} decode cache
    cache_pos: Array | None = None,  # scalar (or (B,) vector) decode position
) -> tuple[Array, dict | None]:
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    q = x @ params["wq"]
    src = x if kv is None else kv
    k = src @ params["wk"]
    v = src @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = _split_heads(q, hq)
    k = _split_heads(k, hkv)
    v = _split_heads(v, hkv)

    per_slot = cache_pos is not None and getattr(cache_pos, "ndim", 0) == 1

    if use_rope and kv is None:
        q = rope(q, positions, cfg.rope_theta)
        if cache_pos is None:
            k = rope(k, positions, cfg.rope_theta)
        elif per_slot:  # continuous batching: each row at its own position
            k = rope(k, jnp.broadcast_to(cache_pos[:, None], k.shape[:2]), cfg.rope_theta)
        else:
            k = rope(k, jnp.full(k.shape[:2], cache_pos), cfg.rope_theta)

    new_cache = None
    if cache is not None:
        if cache_pos is not None:  # single-token decode: append to the cache
            quant = "k_scale" in cache
            if quant:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
            else:
                kq, ks, vq, vs = k, None, v, None

            def upd(buf, val):
                if per_slot:  # scatter one row per sequence at its position
                    b = val.shape[0]
                    return buf.at[jnp.arange(b), cache_pos].set(
                        val[:, 0].astype(buf.dtype)
                    )
                return lax.dynamic_update_slice_in_dim(
                    buf, val.astype(buf.dtype), cache_pos, axis=1
                )

            new_cache = {"k": upd(cache["k"], kq), "v": upd(cache["v"], vq)}
            if quant:
                new_cache["k_scale"] = upd(cache["k_scale"], ks)
                new_cache["v_scale"] = upd(cache["v_scale"], vs)
                k = dequantize_kv(new_cache["k"], new_cache["k_scale"])
                v = dequantize_kv(new_cache["v"], new_cache["v_scale"])
            else:
                k, v = new_cache["k"], new_cache["v"]
        else:  # prefill: cache is returned filled with this call's K/V
            new_cache = {"k": k, "v": v}

    b, s = x.shape[:2]
    if cfg.attn_chunk and cache is None and q.shape[1] > cfg.attn_chunk:
        # adjust to the largest divisor of T not exceeding the request
        # (e.g. S=4672 with chunk 512 -> 292; S=1500 -> 500)
        t_len = k.shape[1]
        chunk = next(c for c in range(min(cfg.attn_chunk, t_len), 0, -1) if t_len % c == 0)
        if chunk > 1:
            # flash-style online softmax: no S×T score materialization
            out = chunked_attention(
                q, k, v, hkv, causal=causal and kv is None, chunk=chunk
            )
            return out.reshape(b, s, -1) @ params["wo"], new_cache

    scores = gqa_scores(q, k, hkv).astype(jnp.float32)
    t = k.shape[1]
    if cache is not None and cache_pos is not None:
        # mask out cache slots past the current position
        if per_slot:
            valid = jnp.arange(t)[None, :] <= cache_pos[:, None]  # (B, T)
            scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        else:
            valid = jnp.arange(t) <= cache_pos
            scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    elif causal and kv is None:
        s_q = q.shape[1]
        mask = jnp.tril(jnp.ones((s_q, t), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = gqa_combine(probs, v)
    return out.reshape(b, s, -1) @ params["wo"], new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "gelu":
        return {"wi": _dense_init(ks[0], (d, ff)), "wo": _dense_init(ks[2], (ff, d))}
    return {
        "wi": _dense_init(ks[0], (d, ff)),
        "wg": _dense_init(ks[1], (d, ff)),
        "wo": _dense_init(ks[2], (ff, d)),
    }


def mlp_apply(params: dict, x: Array) -> Array:
    if "wg" not in params:  # GELU (whisper-style 2-matrix MLP)
        return jax.nn.gelu(x @ params["wi"]) @ params["wo"]
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]


# --------------------------------------------------------------------------
# Mixture-of-Experts MLP (top-k token-choice with capacity, sort-based
# dispatch — the memory-lean TPU formulation)
# --------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e)).astype(jnp.float32),
        "wi": _dense_init(ks[1], (e, d, ff), scale_axis=1),
        "wg": _dense_init(ks[2], (e, d, ff), scale_axis=1),
        "wo": _dense_init(ks[3], (e, ff, d), scale_axis=1),
    }


def _moe_route(params: dict, cfg: ModelConfig, xt: Array):
    """Shared router: returns (top_p, top_e, aux_loss). xt: (T, d)."""
    k, e = cfg.experts_per_token, cfg.n_experts
    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return top_p, top_e, aux


def _moe_dispatch(cfg: ModelConfig, xt: Array, top_p, top_e, capacity: int):
    """Sort-based dispatch; returns (buf (E,C,d), se, sp, st, slot, keep)."""
    t, d = xt.shape
    k, e = cfg.experts_per_token, cfg.n_experts
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    token_idx = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)  # group by expert
    se, sp, st = flat_e[order], flat_p[order], token_idx[order]
    starts = jnp.searchsorted(se, jnp.arange(e))  # first slot of each expert
    pos = jnp.arange(t * k) - starts[se]  # position within expert
    keep = pos < capacity
    slot = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e, capacity, d), xt.dtype)
    buf = buf.at[se, slot].add(xt[st] * keep[:, None].astype(xt.dtype))
    return buf, se, sp, st, slot, keep


def _moe_ffn(params: dict, buf: Array) -> Array:
    h = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, params["wo"])


def _moe_combine(cfg, yb, se, sp, st, slot, keep, t: int, d: int) -> Array:
    out_tok = yb[se, slot] * (sp * keep)[:, None].astype(yb.dtype)
    return jnp.zeros((t, d), yb.dtype).at[st].add(out_tok)


def moe_apply(params: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """Returns (output, aux_loss). x: (B, S, d).

    Two implementations:

    * ``gspmd`` (default) — single-program sort/scatter dispatch; GSPMD
      shards it, but scatters into an expert-sharded buffer replicate (the
      dominant collective on the 1T-param MoE cells — §Perf-B).
    * ``shard_map`` — explicit expert parallelism: local dispatch per data
      shard, ``lax.all_to_all`` over the model axis to the expert owners,
      local expert FFN, reverse all-to-all, local combine. The production
      MoE data path.
    """
    if cfg.moe_impl == "shard_map":
        out, aux = _moe_shard_map(params, cfg, x)
        if out is not None:
            return out, aux
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_token, cfg.n_experts
    xt = x.reshape(t, d)
    top_p, top_e, aux = _moe_route(params, cfg, xt)
    capacity = max(int(cfg.capacity_factor * t * k / e), 1)
    buf, se, sp, st, slot, keep = _moe_dispatch(cfg, xt, top_p, top_e, capacity)
    yb = _moe_ffn(params, buf)
    out = _moe_combine(cfg, yb, se, sp, st, slot, keep, t, d)
    return out.reshape(b, s, d), aux


def _ambient_mesh():
    """Version-compatible ambient-mesh lookup: ``jax.sharding
    .get_abstract_mesh`` (newer JAX) or the thread-resources physical mesh
    (older releases). Returns None when no mesh is in scope."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    try:
        from jax.interpreters import pxla

        return pxla.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None


def _shard_map(*args, **kwargs):
    """``jax.shard_map`` where present; the experimental entry point
    otherwise. Some releases spell ``check_vma`` as ``check_rep`` (including
    a window where ``jax.shard_map`` itself still takes ``check_rep``), so
    pick the spelling off the actual signature."""
    import inspect

    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    if "check_vma" in kwargs:
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            params = {}
        if "check_vma" not in params:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return fn(*args, **kwargs)


def _moe_shard_map(params: dict, cfg: ModelConfig, x: Array):
    """Expert-parallel MoE via shard_map + all_to_all. Returns (None, 0) when
    no suitable mesh is ambient (single-device smoke paths)."""
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None, jnp.zeros((), jnp.float32)
    ep = mesh.shape["model"]
    if cfg.n_experts % ep != 0:
        return None, jnp.zeros((), jnp.float32)
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    e, k = cfg.n_experts, cfg.experts_per_token

    def local_moe(lp, xl):
        # xl: (B_local, S, d) — this data shard's tokens, replicated over model
        bl, s, d = xl.shape
        t = bl * s
        xt = xl.reshape(t, d)
        top_p, top_e, aux = _moe_route(lp, cfg, xt)
        aux = lax.pmean(aux, batch_axes)
        capacity = max(int(cfg.capacity_factor * t * k / e), 1)
        # pad capacity so E*C splits evenly across the expert axis
        capacity = -(-capacity // ep) * ep
        buf, se, sp, st, slot, keep = _moe_dispatch(cfg, xt, top_p, top_e, capacity)
        # to expert owners: (E, C, d) -> (E/ep, C*ep, d)
        buf = lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)
        yb = _moe_ffn(lp, buf)  # local experts only: lp weights are (E/ep, ...)
        # back to the token owners: (E/ep, C*ep, d) -> (E, C, d)
        yb = lax.all_to_all(yb, "model", split_axis=1, concat_axis=0, tiled=True)
        out = _moe_combine(cfg, yb, se, sp, st, slot, keep, t, d)
        return out.reshape(bl, s, d), aux

    param_specs = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wg": P("model", None, None),
        "wo": P("model", None, None),
    }
    out, aux = _shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(param_specs, P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(params, x)
    return out, aux


# --------------------------------------------------------------------------
# Mamba (selective SSM) block — Jamba's mixer
# --------------------------------------------------------------------------


def mamba_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv_dim, d_in)),
        "x_proj": _dense_init(ks[2], (d_in, 2 * n + 1)),  # -> B, C, dt
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, 1))
        ),  # (d_in, N)
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": _dense_init(ks[3], (d_in, d)),
    }


def _mamba_scan_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a2 * a1, a2 * b1 + b2


def mamba_apply(params: dict, cfg: ModelConfig, x: Array) -> Array:
    """Training/prefill form: associative scan over the sequence.

    With ``cfg.ssm_chunk > 0``, the recurrence runs SSD-style: a sequential
    ``lax.scan`` over sequence chunks carrying the (B, d_in, N) state, with
    the parallel associative scan only *inside* each chunk. Peak activation
    memory drops from O(S·d_in·N) to O(chunk·d_in·N) per layer — the memory
    lever for the Jamba train cells.
    """
    b, s, _ = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state_dim

    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_in) each

    # depthwise causal conv over time
    w = params["conv_w"]  # (K, d_in)
    pad = jnp.pad(xi, ((0, 0), (cfg.ssm_conv_dim - 1, 0), (0, 0)))
    xi = sum(
        pad[:, i : i + s, :] * w[i][None, None, :] for i in range(cfg.ssm_conv_dim)
    )
    xi = jax.nn.silu(xi)

    bc_dt = xi @ params["x_proj"]  # (B, S, 2N+1)
    bmat, cmat, dt = (
        bc_dt[..., :n],
        bc_dt[..., n : 2 * n],
        bc_dt[..., 2 * n :],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,d_in)
    dt = jnp.broadcast_to(dt, (b, s, d_in))

    a = -jnp.exp(params["a_log"])  # (d_in, N)

    def ssm_prefix(xi_c, dt_c, b_c, h0):
        """Scan one chunk: returns (h_t for each t, final h)."""
        a_bar = jnp.exp(dt_c[..., None] * a[None, None])  # (B, C, d_in, N)
        bx = (dt_c * xi_c.astype(jnp.float32))[..., None] * b_c[:, :, None, :].astype(
            jnp.float32
        )
        a_acc, h = lax.associative_scan(_mamba_scan_combine, (a_bar, bx), axis=1)
        # fold in the carried-in state: h_t += a_acc_t · h0
        h = h + a_acc * h0[:, None]
        return h, h[:, -1]

    chunk = cfg.ssm_chunk
    if chunk and s > chunk and s % chunk == 0:
        nchunks = s // chunk

        def body(h0, inputs):
            xi_c, dt_c, b_c, c_c = inputs
            h, h_last = ssm_prefix(xi_c, dt_c, b_c, h0)
            y_c = jnp.einsum("bsdn,bsn->bsd", h, c_c.astype(jnp.float32))
            return h_last, y_c

        def to_chunks(t):
            return jnp.moveaxis(
                t.reshape(b, nchunks, chunk, *t.shape[2:]), 1, 0
            )

        h0 = jnp.zeros((b, d_in, n), jnp.float32)
        _, y = lax.scan(
            body, h0, (to_chunks(xi), to_chunks(dt), to_chunks(bmat), to_chunks(cmat))
        )
        y = jnp.moveaxis(y, 0, 1).reshape(b, s, d_in)
    else:
        h, _ = ssm_prefix(xi, dt, bmat, jnp.zeros((b, d_in, n), jnp.float32))
        y = jnp.einsum("bsdn,bsn->bsd", h, cmat.astype(jnp.float32))

    y = y + xi.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"]


def mamba_step(
    params: dict, cfg: ModelConfig, x: Array, state: dict
) -> tuple[Array, dict]:
    """Single-token decode. x: (B, 1, d); state: {"h": (B,d_in,N), "conv": (B,K,d_in)}."""
    b = x.shape[0]
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state_dim

    xz = x[:, 0] @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, d_in)

    conv = jnp.concatenate([state["conv"][:, 1:], xi[:, None]], axis=1)  # (B,K,d_in)
    xi = jax.nn.silu(jnp.einsum("bkd,kd->bd", conv, params["conv_w"]))

    bc_dt = xi @ params["x_proj"]
    bvec, cvec, dt = bc_dt[..., :n], bc_dt[..., n : 2 * n], bc_dt[..., 2 * n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, d_in)

    a = -jnp.exp(params["a_log"])
    a_bar = jnp.exp(dt[..., None] * a[None])  # (B, d_in, N)
    bx = (dt * xi.astype(jnp.float32))[..., None] * bvec[:, None, :].astype(jnp.float32)
    h = a_bar * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, cvec.astype(jnp.float32))
    y = y + xi.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (y @ params["out_proj"])[:, None], {"h": h, "conv": conv}


# --------------------------------------------------------------------------
# RWKV-6 (Finch) time-mix + channel-mix — data-dependent decay, attention-free
# --------------------------------------------------------------------------


def rwkv_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "wr": _dense_init(ks[0], (d, d)),
        "wk": _dense_init(ks[1], (d, d)),
        "wv": _dense_init(ks[2], (d, d)),
        "wg": _dense_init(ks[3], (d, d)),
        "wo": _dense_init(ks[4], (d, d)),
        "w_decay": _dense_init(ks[5], (d, d)),  # data-dependent decay proj
        "decay_bias": jnp.full((d,), -6.0, jnp.float32),
        "mix": jnp.full((5, d), 0.5, jnp.float32),  # token-shift mixes r,k,v,g,w
        "bonus": jnp.zeros((d,), jnp.float32),  # per-channel "u" bonus
    }


def _rwkv_heads(x: Array, head_dim: int) -> Array:
    b, s, d = x.shape
    return x.reshape(b, s, d // head_dim, head_dim)


def rwkv_apply(params: dict, cfg: ModelConfig, x: Array) -> Array:
    """Training/prefill: scan over time with matrix-valued state (B,H,K,V)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd

    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # token shift
    mix = params["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x * mix[i] + shifted * (1 - mix[i]) for i in range(5))

    r = _rwkv_heads(xr @ params["wr"], hd)  # (B,S,H,K)
    k = _rwkv_heads(xk @ params["wk"], hd)
    v = _rwkv_heads(xv @ params["wv"], hd)
    g = jax.nn.silu(xg @ params["wg"])  # (B,S,D)
    w = jnp.exp(
        -jnp.exp((xw @ params["w_decay"]).astype(jnp.float32) + params["decay_bias"])
    )  # (B,S,D) data-dependent decay in (0,1)
    w = _rwkv_heads(w, hd)  # (B,S,H,K)
    u = _rwkv_heads(jnp.broadcast_to(params["bonus"], (b, 1, d)), hd)[:, 0]  # (B,H,K)

    def step(state, inputs):
        rt, kt, vt, wt = inputs  # (B,H,K) except vt: (B,H,V)
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32), state + u[..., None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    state0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    xs = (
        jnp.moveaxis(r, 1, 0),
        jnp.moveaxis(k, 1, 0),
        jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(w, 1, 0),
    )
    _, outs = lax.scan(step, state0, xs)  # (S, B, H, V)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d).astype(x.dtype)
    return (out * g) @ params["wo"]


def rwkv_step(
    params: dict, cfg: ModelConfig, x: Array, state: dict
) -> tuple[Array, dict]:
    """Single-token decode. state: {"s": (B,H,K,V) fp32, "shift": (B,d)}."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    xt = x[:, 0]
    mix = params["mix"].astype(x.dtype)
    prev = state["shift"]
    xr, xk, xv, xg, xw = (xt * mix[i] + prev * (1 - mix[i]) for i in range(5))

    r = (xr @ params["wr"]).reshape(b, -1, hd)
    k = (xk @ params["wk"]).reshape(b, -1, hd)
    v = (xv @ params["wv"]).reshape(b, -1, hd)
    g = jax.nn.silu(xg @ params["wg"])
    w = jnp.exp(
        -jnp.exp((xw @ params["w_decay"]).astype(jnp.float32) + params["decay_bias"])
    ).reshape(b, -1, hd)
    u = jnp.broadcast_to(params["bonus"], (b, d)).reshape(b, -1, hd)

    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    out = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), state["s"] + u[..., None] * kv)
    new_s = w[..., None] * state["s"] + kv
    out = out.reshape(b, d).astype(x.dtype)
    y = (out * g) @ params["wo"]
    return y[:, None], {"s": new_s, "shift": xt}


def rwkv_channel_mix_init(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "wk": _dense_init(ks[0], (d, ff)),
        "wv": _dense_init(ks[1], (ff, d)),
        "mix": jnp.full((1, d), 0.5, jnp.float32),
    }


def rwkv_channel_mix(params: dict, x: Array, shifted: Array) -> Array:
    mix = params["mix"][0].astype(x.dtype)
    xk = x * mix + shifted * (1 - mix)
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return h @ params["wv"]
