"""Cluster power cap: hold the pool under a watt budget in every window.

The serving stack so far optimizes cycles under an SLO; deployments are
provisioned in *Watts* — a rack budget, a thermal envelope — and the
paper's per-Watt motivation cuts both ways: configuration overhead burns
joules (MMIO handshakes, un-gated idle links) that a power-capped pool
cannot spend. This module enforces a hard cap with two cooperating
mechanisms, both built from existing machinery:

* **Admission delay** (:func:`run_power_capped`) — before a request is
  dispatched, the pool's worst-case committed energy in *any* window the
  request could touch is measured from the live engine logs
  (:func:`~repro.power.meter.max_window_energy` — dispatch commits future
  busy intervals into the resource logs, so "committed" includes work
  that has not nominally happened yet), and admission is pushed back
  until that worst case plus a per-request upper bound fits under
  ``budget × window``. The guarantee is inductive: every admitted request
  kept every window under the budget at its own admission, and later
  admissions only ever *add* energy after re-checking — so the capped run
  never exceeds the watt budget in any window (the CI gate asserts this
  on the bench artifact). The request's ``arrival_time`` is **not**
  rewritten: delay shows up as queueing latency, so the SLO report
  prices exactly what the cap cost.
* **Load shedding** (:class:`PowerCapTrigger`) — a
  :class:`~repro.obs.monitor.SustainedThreshold` on windowed pool power:
  when the pool draws sustained near-budget power while imbalanced, the
  hottest host sheds its heaviest tenant to the coldest host through the
  same :class:`~repro.cluster.shed.ShedTrigger` machinery (victim choice,
  migration planner, slot-context hand-off) — rebalancing heat instead
  of port backlog.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from ..fabric.transport import plan_fields
from ..obs.monitor import StreamMonitor
from ..power.meter import (
    PoolEnergySnapshot,
    host_window_energy,
    max_window_energy,
    pool_window_energy,
)
from ..power.model import ZERO_ENERGY
from ..sched.scheduler import LaunchRequest, arrival_order
from .host import Host
from .shed import ShedDecision, ShedTrigger
from .slo import ClusterReport, build_report, percentile


def request_energy_bound(host: Host, req: LaunchRequest) -> float:
    """Upper bound (pJ) on the energy dispatching ``req`` on ``host`` can
    add to any single window: a full cold-cache config transfer (host
    issue + wire) on the worst eligible device, the macro-op at active
    power, and one wake-up on each resource. Elision, overlap, and window
    clipping only shrink the real figure — never grow it."""
    sched = host.sched
    host_model = sched.res.host.energy or ZERO_ENERGY
    wire_model = sched.res.wire.energy or ZERO_ENERGY
    worst = 0.0
    for dev in sched.devices:
        if req.accel is not None and dev.model.name != req.accel:
            continue
        regs = req.regs_for(dev.model)
        xfer = plan_fields(len(regs), dev.model, sched.link, sched.transport,
                           objective=sched.objective)
        compute_model = dev.queue.compute.energy or ZERO_ENERGY
        energy = (xfer.energy
                  + compute_model.active_energy(dev.model.macro_cycles(regs))
                  + host_model.wake_energy + wire_model.wake_energy
                  + compute_model.wake_energy)
        worst = max(worst, energy)
    return worst


@dataclass
class CapReport:
    """What the cap did to one run."""

    budget_power: float  # pJ/cycle the pool must stay under per window
    window: float  # cycles per enforcement window
    delayed: int = 0  # requests admission pushed back
    total_delay: float = 0.0  # cycles of added admission delay
    delays: list = field(default_factory=list)
    sheds: list = field(default_factory=list)  # PowerCapTrigger decisions
    max_window_power: float = 0.0  # worst measured window, post-run
    max_window_at: float = 0.0

    @property
    def held(self) -> bool:
        """Did the pool stay under budget in every window? (The CI gate's
        assertion; 1e-9 absorbs float summation order.)"""
        return self.max_window_power <= self.budget_power + 1e-9

    @property
    def p50_delay(self) -> float:
        return percentile(self.delays, 50) if self.delays else 0.0

    def to_dict(self) -> dict:
        return {
            "budget_power": self.budget_power,
            "window": self.window,
            "delayed": self.delayed,
            "total_delay": self.total_delay,
            "p50_delay": self.p50_delay,
            "sheds": len(self.sheds),
            "max_window_power": self.max_window_power,
            "max_window_at": self.max_window_at,
            "held": self.held,
        }


class PowerCapTrigger(ShedTrigger):
    """Shed tenants off the hottest host when pool power runs sustained
    above ``headroom ×`` budget. Reuses :class:`ShedTrigger`'s victim
    choice, migration execution, and slot-context hand-off; only the
    pressure signal changes — windowed joules instead of port backlog.
    ``monitor`` receives every per-host observation under the canonical
    ``power.energy`` name, so :meth:`StreamMonitor.power_draw` windows
    the exact signal the trigger acts on."""

    def __init__(self, planner, *, budget_power: float, window: float,
                 headroom: float = 0.9, sustain: int = 2,
                 monitor: StreamMonitor | None = None):
        assert budget_power > 0.0 and window > 0.0
        assert 0.0 < headroom <= 1.0
        super().__init__(planner, k=1.5, sustain=sustain, monitor=monitor)
        self.budget_power = budget_power
        self.window = window
        self.headroom = headroom

    def observe(self, hosts: Sequence[Host], now: float) -> list[ShedDecision]:
        t0 = now - self.window
        # per-host burn for ranking; a shared port belongs to no single
        # host, so it is excluded here and counted once in the pool figure
        shared = len({id(h.sched.port) for h in hosts}) < len(hosts)
        energies = {
            h.id: host_window_energy(h, t0, now, include_port=not shared)
            for h in hosts
        }
        if self.monitor is not None:
            for host_id, joules in energies.items():
                self.monitor.observe("power.energy", now, joules,
                                     host=host_id)
        pool_power = pool_window_energy(hosts, t0, now) / self.window
        hot = pool_power > self.headroom * self.budget_power
        if not self.pressure.update("pool", hot):
            return []
        # rebalance heat: hottest host sheds toward the coldest
        src = max(hosts, key=lambda h: (energies[h.id], h.id))
        decision = self._shed(src, hosts, energies, now,
                              percentile(list(energies.values()), 50))
        if decision is None:
            return []
        self.decisions.append(decision)
        self.pressure.reset("pool")
        return [decision]


def run_power_capped(
    cluster,
    requests,
    *,
    budget_power: float,
    window: float,
    slo=None,
    trigger: PowerCapTrigger | None = None,
) -> tuple[ClusterReport, CapReport]:
    """Drain ``requests`` through ``cluster`` while holding pool power
    under ``budget_power`` (pJ/cycle) in every ``window``-cycle span.

    Requests are routed normally, then admission-delayed until the
    worst committed window that the dispatch could touch has headroom for
    the request's energy upper bound (see module docstring for why this
    is a hard guarantee, not a best effort). Infeasible budgets — the
    pool's standing idle burn alone exceeding the budget — fail fast
    rather than delaying forever."""
    assert budget_power > 0.0 and window > 0.0
    hosts = cluster.hosts
    budget_energy = budget_power * window
    idle_floor = pool_window_energy(hosts, -window, 0.0)
    assert idle_floor < budget_energy, (
        f"infeasible cap: pool idle burn {idle_floor / window} pJ/cycle "
        f"already exceeds budget {budget_power}")
    cap = CapReport(budget_power=budget_power, window=window)
    last_observe = 0.0
    snap = PoolEnergySnapshot(hosts)
    for req in sorted(requests, key=arrival_order):
        host = cluster.router.route(req, now=req.arrival_time)
        bound = request_energy_bound(host, req)
        assert idle_floor + bound <= budget_energy, (
            f"infeasible cap: a single {req.accel} launch ({bound} pJ) "
            f"can never fit under {budget_energy} pJ per window")
        # find the earliest admission time at which every window the
        # dispatch could add energy to keeps the budget: every committed
        # window starting at or after admission − window must leave
        # ``bound`` of headroom. One snapshot serves the whole run — logs
        # only change at dispatch, and they grow at the frontier, so each
        # dispatch folds in incrementally
        snap.extend()
        admit = snap.earliest_admission(req.arrival_time, window,
                                        budget_energy - bound)
        if admit > req.arrival_time:
            # push the host's control thread; arrival_time stays put, so
            # the added wait is visible as queueing latency in the SLO
            host.sched.host = max(host.sched.host, admit)
            cap.delayed += 1
            cap.total_delay += admit - req.arrival_time
            cap.delays.append(admit - req.arrival_time)
        host.dispatch(req)
        if trigger is not None:
            # the pool-wide clock: per-host clocks are not monotone across
            # dispatches, and the monitor's window series require ordered
            # samples. Observing is throttled to quarter-windows — the
            # trigger thresholds windowed power, so denser sampling only
            # costs time
            now = max(h.clock for h in hosts)
            if now - last_observe >= window / 4.0:
                last_observe = now
                cap.sheds.extend(trigger.observe(hosts, now=now))
    makespan = max(h.clock for h in hosts)
    worst, at = max_window_energy(hosts, window)
    cap.max_window_power = worst / window
    cap.max_window_at = at
    # the inductive argument, re-checked empirically on the final logs
    assert cap.held, (
        f"power cap violated: {cap.max_window_power} pJ/cycle at "
        f"{at} exceeds budget {budget_power} (makespan {makespan})")
    return build_report(hosts, slo=slo), cap
