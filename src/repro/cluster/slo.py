"""SLO-percentile telemetry for open-loop cluster runs.

Closed-loop reports end at makespan and utilization; an open-loop serving
system is judged on its *tails*: the p99 of the queueing delay (arrival →
device start) and the fraction of launches finishing inside each tenant's
latency target. This module folds every host's
:class:`~repro.sched.telemetry.SchedulerReport` — specifically the
per-launch :class:`~repro.sched.telemetry.LaunchRecord` logs — into one
:class:`ClusterReport`:

* per-tenant p50/p95/p99 queueing delay and latency,
* SLO attainment (fraction of launches with ``latency ≤ slo_cycles``) and
  **goodput** (ops of SLO-meeting launches per cycle — work that was worth
  doing),
* config-byte traffic and preemption counts summed across hosts,
* per-host ``interp.Trace`` timelines on one shared time axis and per-host
  configuration-roofline points (serialized-port effective bandwidth), so a
  cluster run lands on the same plots as a single compiled program.

Percentiles use deterministic linear interpolation (no numpy dependency at
this layer, bit-stable across platforms) — the shared implementation lives
in :mod:`repro.obs.metrics` and is re-exported here unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

from ..core.interp import Trace
from ..core.roofline import RooflinePoint
from ..obs.metrics import MetricsRegistry, percentile
from ..sched.state_cache import elision_ratio
from ..sched.telemetry import (
    LaunchRecord,
    LinkTelemetry,
    ResourceTelemetry,
    SchedulerReport,
)

__all__ = [
    "ClusterReport",
    "TenantSLO",
    "TenantServing",
    "build_report",
    "percentile",
]


@dataclass(frozen=True)
class TenantSLO:
    """One tenant's open-loop service quality over a run."""

    tenant: str
    launches: int
    p50_queue: float
    p95_queue: float
    p99_queue: float
    p50_latency: float
    p95_latency: float
    p99_latency: float
    slo_cycles: float | None  # None = best effort, attainment vacuously 1
    attainment: float  # fraction of launches with latency <= slo_cycles
    total_ops: int
    good_ops: int  # ops of launches that met the SLO

    @classmethod
    def from_records(cls, tenant: str, records: Sequence[LaunchRecord],
                     slo_cycles: float | None) -> "TenantSLO":
        queues = [r.queue_delay for r in records]
        lats = [r.latency for r in records]
        if slo_cycles is None:
            met = list(records)
        else:
            met = [r for r in records if r.latency <= slo_cycles]
        return cls(
            tenant=tenant,
            launches=len(records),
            p50_queue=percentile(queues, 50),
            p95_queue=percentile(queues, 95),
            p99_queue=percentile(queues, 99),
            p50_latency=percentile(lats, 50),
            p95_latency=percentile(lats, 95),
            p99_latency=percentile(lats, 99),
            slo_cycles=slo_cycles,
            attainment=len(met) / len(records) if records else 1.0,
            total_ops=sum(r.ops for r in records),
            good_ops=sum(r.ops for r in met),
        )


@dataclass(frozen=True)
class TenantServing:
    """One bridged tenant's token-level service quality (``repro.bridge``):
    the closed-loop observables a serving SLO is written against — decode
    step latency percentiles (arrival of the step's first launch to
    retirement of its last) and token goodput over the run's makespan.
    Sits beside :class:`TenantSLO`, which speaks per-*launch*; this speaks
    per-*decode-step*, the unit a user-visible token corresponds to."""

    tenant: str
    tokens: int
    steps: int
    p50_decode: float
    p95_decode: float
    p99_decode: float
    tokens_per_kcycle: float  # token goodput normalized to the run makespan

    @classmethod
    def from_steps(cls, tenant: str, latencies: Sequence[float],
                   tokens: int, makespan: float) -> "TenantServing":
        return cls(
            tenant=tenant,
            tokens=tokens,
            steps=len(latencies),
            p50_decode=percentile(latencies, 50),
            p95_decode=percentile(latencies, 95),
            p99_decode=percentile(latencies, 99),
            tokens_per_kcycle=1000.0 * tokens / makespan if makespan else 0.0,
        )


@dataclass
class ClusterReport:
    """Aggregate of one open-loop cluster run."""

    makespan: float
    hosts: dict[str, SchedulerReport]
    tenants: dict[str, TenantSLO]
    records: list[LaunchRecord]
    port_utilization: dict[str, float]  # host -> config-port duty cycle
    roofline: list[RooflinePoint]  # one point per host (serialized port)
    # host -> residual port wait when the run's last request arrived — the
    # same Host.port_wait_estimate the router probes, so telemetry and
    # routing can never disagree about backlog
    port_wait: dict[str, float]
    fabric_roofline: list[RooflinePoint]  # one point per host (link-effective BW)
    # one point per host with runtime overlap priced in: BW_cfg over the
    # *exposed* config cycles only (== `roofline` on serialized hosts)
    overlap_roofline: list[RooflinePoint] = field(default_factory=list)
    # tenant -> token-level serving stats, attached by the closed-loop
    # bridge (empty for plain open-loop runs)
    serving: dict[str, TenantServing] = field(default_factory=dict)
    # every host's scheduler registry absorbed under a host=<id> label plus
    # the cluster-level series build_report adds; the traffic properties
    # below are views over it (repro.obs.metrics), falling back to summing
    # host reports when a report was assembled without a registry
    metrics: MetricsRegistry | None = None

    def attach_serving(self, stats: Mapping[str, TenantServing]) -> None:
        """Fold bridged token-level stats in (``repro.bridge.report``)."""
        self.serving = dict(stats)

    # -- traffic -------------------------------------------------------------

    def _total(self, name: str, fallback: float) -> float:
        if self.metrics is not None and self.metrics.has(name):
            return self.metrics.total(name)
        return fallback

    @property
    def bytes_sent(self) -> int:
        return int(self._total("sched.bytes_sent",
                               sum(rep.bytes_sent for rep in self.hosts.values())))

    @property
    def bytes_elided(self) -> int:
        return int(self._total("sched.bytes_elided",
                               sum(rep.bytes_elided for rep in self.hosts.values())))

    @property
    def elision_ratio(self) -> float:
        return elision_ratio(self.bytes_sent, self.bytes_elided)

    @property
    def preemptions(self) -> int:
        return int(self._total("sched.preemptions",
                               sum(rep.preemptions for rep in self.hosts.values())))

    @property
    def launches(self) -> int:
        return len(self.records)

    @property
    def deadline_misses(self) -> int:
        """Deadline-carrying launches that retired late, cluster-wide."""
        return sum(1 for r in self.records if r.missed_deadline)

    @property
    def tokens(self) -> int:
        """Tokens produced by bridged tenants (0 for open-loop GEMM runs)."""
        return sum(s.tokens for s in self.serving.values())

    @property
    def tokens_per_kcycle(self) -> float:
        """Cluster token goodput — the closed-loop analogue of ``goodput``:
        tokens the bridged engines actually produced per 1000 cycles of the
        run (queueing delay throttles this directly, unlike open-loop)."""
        if not self.makespan:
            return 0.0
        return 1000.0 * self.tokens / self.makespan

    def descriptor_timeline(
        self, tenant: str | None = None
    ) -> list[tuple[float, int, int]]:
        """Per-launch ``(issue, bytes_sent, bytes_elided)`` across every
        host, in arrival order — the cluster-wide descriptor-byte timeline
        (cf. ``SchedulerReport.descriptor_timeline``)."""
        return [(r.issue, r.bytes_sent, r.bytes_elided)
                for r in self.records
                if tenant is None or r.tenant == tenant]

    def links(self) -> dict[str, LinkTelemetry]:
        """Per-host fabric config-port telemetry (busy/occupancy timelines),
        keyed ``host/port`` so merged cluster views stay unambiguous. Hosts
        behind one shared cluster LinkPort each report the same underlying
        wire (the key's port name carries the ``:shared`` suffix)."""
        return {
            f"{host_id}/{name}": tel
            for host_id, rep in self.hosts.items()
            for name, tel in rep.links.items()
        }

    def resources(self) -> dict[str, ResourceTelemetry]:
        """Per-host engine-resource telemetry (host control thread, config
        wire, per-device compute busy timelines), keyed ``host/resource``."""
        return {
            f"{host_id}/{name}": tel
            for host_id, rep in self.hosts.items()
            for name, tel in rep.resources.items()
        }

    @property
    def config_cycles(self) -> float:
        return self._total("sched.config_cycles",
                           sum(rep.config_cycles for rep in self.hosts.values()))

    @property
    def exposed_config_cycles(self) -> float:
        """Config cycles the cluster's hosts actually saw (T_set minus
        what the overlapped engines streamed behind compute)."""
        return self._total(
            "sched.exposed_config_cycles",
            sum(rep.exposed_config_cycles for rep in self.hosts.values()))

    @property
    def hidden_config_cycles(self) -> float:
        return self.config_cycles - self.exposed_config_cycles

    # -- tails ---------------------------------------------------------------

    def queue_delay_percentile(self, q: float) -> float:
        """Cluster-wide queueing-delay percentile over every launch."""
        return percentile([r.queue_delay for r in self.records], q)

    def latency_percentile(self, q: float) -> float:
        return percentile([r.latency for r in self.records], q)

    @property
    def attainment(self) -> float:
        """Launch-weighted SLO attainment across tenants with targets."""
        bound = [t for t in self.tenants.values() if t.slo_cycles is not None]
        total = sum(t.launches for t in bound)
        if not total:
            return 1.0
        return sum(t.attainment * t.launches for t in bound) / total

    @property
    def goodput(self) -> float:
        """Ops per cycle delivered *within* SLO — throughput that counts."""
        if not self.makespan:
            return 0.0
        return sum(t.good_ops for t in self.tenants.values()) / self.makespan

    # -- plots ---------------------------------------------------------------

    def traces(self) -> dict[str, Trace]:
        """Per-device timelines across every host on one shared time axis
        (device ids are host-namespaced), for ``timeline.compare``."""
        return {
            dev_id: tel.trace(self.makespan)
            for rep in self.hosts.values()
            for dev_id, tel in rep.devices.items()
        }

    def placements(self) -> dict[str, dict[str, int]]:
        """tenant -> host -> launches (how hard each router shuffles)."""
        out: dict[str, dict[str, int]] = {}
        for host_id, rep in self.hosts.items():
            for tenant, devs in rep.placements.items():
                n = sum(devs.values())
                out.setdefault(tenant, {})
                out[tenant][host_id] = out[tenant].get(host_id, 0) + n
        return out


def build_report(hosts, *, slo: Mapping[str, float] | None = None) -> ClusterReport:
    """Fold a list of :class:`~repro.cluster.host.Host` into one report."""
    slo = dict(slo or {})
    reports = {h.id: h.report() for h in hosts}
    makespan = max([rep.makespan for rep in reports.values()] + [0.0])
    records: list[LaunchRecord] = []
    for rep in reports.values():
        records.extend(rep.launch_log())
    records.sort(key=lambda r: (r.arrival, r.issue, r.tenant))
    by_tenant: dict[str, list[LaunchRecord]] = {}
    for rec in records:
        by_tenant.setdefault(rec.tenant, []).append(rec)
    tenants = {
        t: TenantSLO.from_records(t, recs, slo.get(t))
        for t, recs in sorted(by_tenant.items())
    }
    last_arrival = max([r.arrival for r in records], default=0.0)
    # one cluster registry: every host's sched.* series folded in under a
    # host=<id> label, plus the cluster-level tail/backlog series — so the
    # traffic properties above and cluster dashboards read one store
    metrics = MetricsRegistry()
    for host_id, rep in reports.items():
        if rep.metrics is not None:
            metrics.absorb(rep.metrics, host=host_id)
    for rec in records:
        metrics.histogram("cluster.queue_delay",
                          tenant=rec.tenant).observe(rec.queue_delay)
        metrics.histogram("cluster.latency",
                          tenant=rec.tenant).observe(rec.latency)
    metrics.gauge("cluster.makespan").set(makespan)
    for h in hosts:
        metrics.gauge("cluster.port_wait",
                      host=h.id).set(h.port_wait_estimate(now=last_arrival))
    return ClusterReport(
        makespan=makespan,
        hosts=reports,
        tenants=tenants,
        records=records,
        port_utilization={h.id: h.port_utilization(makespan) for h in hosts},
        roofline=[h.roofline_point(makespan) for h in hosts],
        port_wait={h.id: h.port_wait_estimate(now=last_arrival) for h in hosts},
        fabric_roofline=[h.fabric_roofline_point(makespan) for h in hosts],
        overlap_roofline=[h.overlap_roofline_point(makespan) for h in hosts],
        metrics=metrics,
    )
