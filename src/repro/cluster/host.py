"""One cluster host: a shard of the device pool behind a serialized
config-write port.

The paper measures configuration overhead for one host–accelerator pair;
Colagrande & Benini show the overhead *amplifies* when several devices hang
off one control processor — every device's ``T_set`` competes for the same
host pipeline, so config writes that could proceed in parallel across
devices serialize in time. `repro.sched`'s single host clock already *is*
that control thread: a :class:`Host` wraps one :class:`~repro.sched.Scheduler`
(its shard of the pool) and exposes the clock as the **config port** — the
resource cross-host routing must keep un-congested.

With `repro.fabric` the port is no longer core-local: each host names the
interconnect its config writes cross (CSR / NoC / PCIe), the scheduler
prices every write's T_set through it, and the wire's occupancy is logged
on the host's :class:`~repro.fabric.link.LinkPort`. With `repro.engine`
the port splits into its real resources — control thread, wire, compute —
so ``overlap="overlapped"`` hosts release the control thread at descriptor
enqueue and stream config behind compute, and ``port=`` lets several hosts
share one cluster-level LinkPort (PCIe-switch contention).

What the router reads off a host:

* :meth:`port_wait_estimate` — how far the host's control thread (and
  fabric wire) has committed beyond the cluster wall clock: arriving work
  waits at least this long before its first config write (the
  offload-amplification term). :meth:`port_backlog` is its alias; probes
  and the SLO report share this one estimate.
* :meth:`probe_cost` — the scheduler's config-affinity scalar for the best
  device of the shard (T_set of the delta + admission delay), i.e. warm
  tenant contexts make a host cheap.
* :meth:`warm_bytes` — how many of the request's config bytes this host's
  caches could elide right now (tenant-context residency).
* :meth:`hosts_context` — whether this host holds a tenant's *slot
  context* (a hosted serving-engine shard's KV cache, ``repro.bridge``):
  unlike register-cache warmth, slot residency is binding — a decode
  launch must run where the KV cache lives, so the sticky router routes
  on it before any cost comparison.
"""

from __future__ import annotations

import heapq
import itertools

from ..core.accelerators import REGISTRY, AcceleratorModel
from ..core.roofline import (
    RooflinePoint,
    fabric_roofline_point,
    host_roofline_point,
    overlap_roofline_point,
)
from ..fabric.link import LinkModel, LinkPort
from ..sched.scheduler import Device, LaunchRequest, Scheduler
from ..sched.telemetry import SchedulerReport


class ConfigQuota:
    """Per-tenant configuration-bandwidth quota at one host port.

    Caps how many config bytes a tenant may put on the host's port per
    ``window`` host cycles. A launch that would overrun its tenant's budget
    is **deferred to the next window, never dropped** — the excess lands in
    that tenant's own queueing delay (its ``arrival_time`` is unchanged, so
    the deferral is visible in its latency percentiles) while neighbors
    keep the port's residual bandwidth. ``budgets`` overrides the default
    per tenant; a budget of ``None`` exempts the tenant.

    Accounting is by window index (``t // window``): a tenant may start a
    launch in any window whose spend is still under budget, so one launch
    may overshoot its window by its own size — the cap is a rate limit, not
    an admission-control byte gate (a single launch larger than the budget
    still runs, once per window)."""

    def __init__(self, bytes_per_window: int, window: float,
                 budgets: dict[str, int | None] | None = None):
        assert bytes_per_window > 0 and window > 0
        self.bytes_per_window = int(bytes_per_window)
        self.window = float(window)
        self.budgets = dict(budgets or {})
        self._spent: dict[tuple[str, int], int] = {}

    def budget_for(self, tenant: str) -> int | None:
        return self.budgets.get(tenant, self.bytes_per_window)

    def spent(self, tenant: str, now: float) -> int:
        """Bytes the tenant has already charged to ``now``'s window."""
        return self._spent.get((tenant, int(now // self.window)), 0)

    def release_time(self, tenant: str, now: float) -> float:
        """Earliest time ≥ ``now`` the tenant may start a launch without
        overrunning a window's budget (``now`` itself while the current
        window has headroom, else the next window boundary)."""
        budget = self.budget_for(tenant)
        if budget is None:
            return now
        if self.spent(tenant, now) < budget:
            return now
        return (int(now // self.window) + 1) * self.window

    def charge(self, tenant: str, now: float, nbytes: int) -> None:
        """Record ``nbytes`` of config traffic in ``now``'s window."""
        key = (tenant, int(now // self.window))
        self._spent[key] = self._spent.get(key, 0) + int(nbytes)


class Host:
    """One control processor owning a shard of the device pool.

    ``link`` names the interconnect this host's config writes cross
    (``repro.fabric``): the default ``"csr"`` is the paper's core-local
    port (zero wire cost), ``"noc"``/``"pcie"`` price every write's T_set
    through the fabric transport — so two otherwise-identical hosts at
    different link distances probe differently to the router."""

    def __init__(
        self,
        host_id: str,
        pool: dict[str, AcceleratorModel],
        *,
        depth: int = 2,
        max_contexts: int = 4,
        policy: str = "affinity",
        cache_enabled: bool = True,
        link: LinkModel | str | None = None,
        overlap: str = "serialized",
        staging_buffers: int = 2,
        transport: str = "auto",
        objective: str = "cycles",
        compute_model=None,
        power=None,
        quota: ConfigQuota | None = None,
        port: LinkPort | None = None,
        tracer=None,
    ):
        self.id = host_id
        # bind the host id into every span this shard emits (repro.obs):
        # one cluster-wide tracer still attributes each event to its host
        self.tracer = tracer
        bound = tracer.bind(host=host_id) if tracer is not None else None
        self.sched = Scheduler(pool, depth=depth, max_contexts=max_contexts,
                               policy=policy, cache_enabled=cache_enabled,
                               link=link, overlap=overlap,
                               staging_buffers=staging_buffers,
                               transport=transport, objective=objective,
                               compute_model=compute_model,
                               power=power, port=port,
                               tracer=bound)
        # per-tenant config-bandwidth quota at this port (None = uncapped).
        # Over-budget launches park in the deferred heap until their window
        # release edge; they are flushed lazily — at the next dispatch
        # whose arrival has passed the edge, or at report() — so the host
        # clock is never idled forward past a neighbor's earlier arrival.
        self.quota = quota
        self._deferred: list[tuple[float, float, int, LaunchRequest]] = []
        self._defer_seq = itertools.count()
        self.deferred_launches = 0
        # tenants whose *slot context* (a hosted engine shard's KV cache)
        # lives on this host — the binding residency the sticky router
        # consults; distinct from register-cache warmth, which is advisory
        self._slot_contexts: set[str] = set()

    @classmethod
    def from_registry(cls, host_id: str, counts: dict[str, int],
                      **kwargs) -> "Host":
        """e.g. ``Host.from_registry("h0", {"gemmini": 1, "opengemm": 1})`` —
        device ids are namespaced ``h0/gemmini:0`` so merged cluster
        telemetry stays unambiguous."""
        pool = {
            f"{host_id}/{kind}:{i}": REGISTRY[kind]
            for kind, n in counts.items()
            for i in range(n)
        }
        return cls(host_id, pool, **kwargs)

    # -- state the router reads ---------------------------------------------

    @property
    def clock(self) -> float:
        """The host control thread's committed time (the config port)."""
        return self.sched.host

    @property
    def link(self) -> LinkModel:
        """The interconnect this host's config writes cross."""
        return self.sched.link

    @property
    def port(self):
        """The host's fabric config port (``fabric.link.LinkPort``)."""
        return self.sched.port

    @property
    def devices(self) -> list[Device]:
        return self.sched.devices

    def kinds(self) -> set[str]:
        return {d.model.name for d in self.sched.devices}

    def can_serve(self, req: LaunchRequest) -> bool:
        return req.accel is None or req.accel in self.kinds()

    @property
    def launches(self) -> int:
        """Cumulative launches dispatched here (the router's long-run
        load signal for cold-tie spreading)."""
        return sum(d.telemetry.launches for d in self.sched.devices)

    # -- slot residency (hosted engine shards, ``repro.bridge``) -------------

    def adopt_context(self, tenant: str) -> None:
        """Record that ``tenant``'s slot context (its serving-engine shard's
        KV cache) lives on this host: its decode launches are sticky here
        until the context is dropped (a finished or migrated tenant)."""
        self._slot_contexts.add(tenant)

    def drop_context(self, tenant: str) -> None:
        self._slot_contexts.discard(tenant)

    def hosts_context(self, tenant: str) -> bool:
        """Does this host hold ``tenant``'s slot context? The binding
        residency signal: a decode launch reads and writes the KV cache,
        so it cannot run anywhere else without a migration."""
        return tenant in self._slot_contexts

    @property
    def resident_tenants(self) -> set[str]:
        """Tenants whose slot contexts (engine shards) this host hosts."""
        return set(self._slot_contexts)

    def port_wait_estimate(self, req: LaunchRequest | None = None,
                           now: float = 0.0) -> float:
        """Cycles a request arriving at ``now`` waits before its first
        config write can start here — the later of the control thread's
        committed time and the fabric wire's in-flight transfer. The
        **single** backlog estimate shared by router probes
        (:meth:`probe_cost`) and the SLO report (``cluster.slo``), so the
        two can never drift apart.

        Since the engine refactor this is a *query against the resource
        intervals* (:meth:`~repro.engine.resources.EngineResources.port_wait`),
        not a bespoke formula: the max-combine (never ``+`` — a captive
        host already contains its own transfer, summing would double-count
        it) and the half-open ``[start, end)`` boundary (a transfer
        completing at exactly ``now`` holds the port for zero further
        cycles) both live in ``Resource.backlog``. Under DMA/host overlap
        the wire can outrun the control thread, and with a shared cluster
        LinkPort it carries other hosts' transfers too — both show up here
        automatically because the wire resource is the port's. With a
        per-tenant config-bandwidth ``quota``, ``req`` matters: a tenant
        that has exhausted its window waits at least until the window
        rolls over, so the router steers its launches toward hosts with
        budget left while neighbors still see only the resource wait."""
        wait = self.sched.res.port_wait(now)
        if self.quota is not None and req is not None:
            wait = max(wait,
                       self.quota.release_time(req.tenant, now) - now)
        return wait

    def port_backlog(self, now: float) -> float:
        """Cycles of config work already committed past the wall clock —
        a request routed here waits at least this long for the port."""
        return self.port_wait_estimate(now=now)

    def probe_cost(self, req: LaunchRequest, now: float,
                   stickiness: float = 0.0) -> float:
        """Host-visible cycles from ``now`` until this host would have the
        request's launch issued: port congestion first, then the scheduler's
        config-affinity cost on the shard's best device — minus the
        residency credit when the router passes its ``stickiness``. Link
        distance is priced in: the scheduler's cost term carries the
        fabric T_set (MMIO/burst over this host's link), so a host behind
        a PCIe fabric probes expensive even when idle."""
        return self.port_wait_estimate(req, now) + self.sched.probe_cost(
            req, now, stickiness)

    def _elidable_per_device(self, req: LaunchRequest):
        """(device, elidable config bytes) over the shard's eligible devices."""
        for dev in self.sched.devices:
            if req.accel is not None and dev.model.name != req.accel:
                continue
            yield dev, dev.cache.elidable_bytes(req.tenant, req.regs_for(dev.model))

    def warm_bytes(self, req: LaunchRequest) -> int:
        """Config bytes the host's caches would elide for this request —
        the tenant-context residency signal (0 on a cold host)."""
        return max((b for _, b in self._elidable_per_device(req)), default=0)

    def residency_cycles(self, req: LaunchRequest) -> float:
        """Config-write cycles a resident context saves on one launch of
        this request (elidable bytes priced at the device's configuration
        bandwidth) — the router weighs this beyond the single launch,
        since residency keeps paying on the tenant's future stream."""
        return max((b / dev.model.bw_config
                    for dev, b in self._elidable_per_device(req)), default=0.0)

    def last_request(self, tenant: str) -> LaunchRequest | None:
        """The tenant's most recent launch here — what a migration trigger
        prices a shed with (``cluster.shed``)."""
        return self.sched.last_request(tenant)

    def tenant_launches(self) -> dict[str, int]:
        """tenant → launches dispatched on this host (shed heat signal)."""
        return self.sched.tenant_launches()

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, req: LaunchRequest) -> Device | None:
        """Dispatch (or, under a config-bandwidth quota, defer) one launch.

        Returns the device the launch ran on, or ``None`` when the
        tenant's quota window is exhausted and the launch was parked — it
        will run at its window's release edge (flushed lazily, or force it
        with :meth:`flush_deferred`), with queueing delay measured from
        its unchanged ``arrival_time``."""
        if self.quota is None:
            return self.sched.dispatch(req)
        # launches whose release edge has passed go first — they have been
        # waiting since before this arrival (the host clock only ever moves
        # to release points ≤ this arrival, so no neighbor is delayed)
        self._release_deferred(upto=req.arrival_time)
        start = max(req.arrival_time, self.clock)
        release = self.quota.release_time(req.tenant, start)
        if release > start:
            self._defer(req, release)
            return None
        return self._dispatch_charged(req)

    def _defer(self, req: LaunchRequest, release: float) -> None:
        heapq.heappush(self._deferred,
                       (release, req.arrival_time, next(self._defer_seq), req))
        self.deferred_launches += 1

    def _dispatch_charged(self, req: LaunchRequest,
                          not_before: float = 0.0) -> Device:
        dev = self.sched.dispatch(req, not_before=not_before)
        rec = self._record_for(dev, req)
        self.quota.charge(req.tenant, rec.issue, rec.bytes_sent)
        return dev

    @staticmethod
    def _record_for(dev: Device, req: LaunchRequest):
        """The launch record ``req`` just produced on ``dev`` — matched by
        (tenant, arrival), not ``launch_log[-1]``: a priority dispatch can
        preempt a staged launch whose victim re-dispatches afterwards."""
        for rec in reversed(dev.telemetry.launch_log):
            if rec.tenant == req.tenant and rec.arrival == req.arrival_time:
                return rec
        raise AssertionError(
            f"dispatched launch for {req.tenant!r} left no record on {dev.id}")

    def _release_deferred(self, upto: float | None = None) -> None:
        """Dispatch every parked launch whose release edge is ≤ ``upto``
        (``None`` = all of them, the report()-time flush). A window that
        filled up in the meantime (deferred siblings landed first) pushes
        the launch to the next edge instead of overrunning the budget; each
        window admits at least one launch, so the drain terminates."""
        limit = float("inf") if upto is None else upto
        while self._deferred and self._deferred[0][0] <= limit:
            release, _, _, req = heapq.heappop(self._deferred)
            actual = self.quota.release_time(req.tenant, release)
            if actual > release:
                self._defer(req, actual)
                self.deferred_launches -= 1  # same launch, not a new deferral
                continue
            self._dispatch_charged(req, not_before=release)

    def flush_deferred(self) -> None:
        """Force every quota-deferred launch through at its release edge —
        the closed-loop driver calls this when it must observe a deferred
        launch's completion before the tenant's next step."""
        if self.quota is not None:
            self._release_deferred()

    # -- reporting -----------------------------------------------------------

    def report(self) -> SchedulerReport:
        self.flush_deferred()
        return self.sched.finish()

    def port_utilization(self, makespan: float) -> float:
        """Fraction of the run the control thread spent writing config —
        the offload-amplification observable (→1.0 means the host pipeline,
        not any accelerator, is the bottleneck)."""
        if not makespan:
            return 0.0
        return sum(d.telemetry.config_cycles for d in self.sched.devices) / makespan

    def roofline_point(self, makespan: float) -> RooflinePoint:
        """This host on the configuration roofline: P_peak sums the shard,
        BW_cfg is the serialized port's effective bandwidth (Eq. 4)."""
        devs = self.sched.devices
        total_ops = sum(d.telemetry.total_ops for d in devs)
        config_bytes = sum(d.telemetry.bytes_sent for d in devs)
        config_cycles = sum(d.telemetry.config_cycles for d in devs)
        return host_roofline_point(
            self.id,
            total_ops=total_ops,
            config_bytes=max(config_bytes, 1),
            config_cycles=config_cycles,
            makespan=makespan,
            p_peak=sum(d.model.p_peak for d in devs),
        )

    def overlap_roofline_point(self, makespan: float) -> RooflinePoint:
        """This host with *runtime overlap* priced in: the effective T_set
        of Eq. 4 counts only the **exposed** config cycles (host
        instruction time + wire time compute failed to hide), so BW_cfg
        rises and the ridge shifts left. On a serialized host exposed ==
        total and the point coincides with :meth:`roofline_point`."""
        devs = self.sched.devices
        return overlap_roofline_point(
            f"{self.id}[{self.sched.overlap.mode}]",
            total_ops=sum(d.telemetry.total_ops for d in devs),
            config_bytes=max(sum(d.telemetry.bytes_sent for d in devs), 1),
            exposed_cycles=sum(d.telemetry.exposed_config_cycles for d in devs),
            makespan=makespan,
            p_peak=sum(d.model.p_peak for d in devs),
        )

    def fabric_roofline_point(self, makespan: float) -> RooflinePoint:
        """This host with the interconnect split out: BW_cfg is the
        *link-effective* config bandwidth — T_calc the host's instruction
        cycles, T_set the cycles its config bytes spent on the wire
        (``core.roofline.fabric_roofline_point``). On a core-local CSR
        port the wire term is ~0 and the point degenerates to the host's
        instruction-limited bandwidth."""
        devs = self.sched.devices
        config_cycles = sum(d.telemetry.config_cycles for d in devs)
        link_cycles = self.sched.port.busy_cycles
        return fabric_roofline_point(
            f"{self.id}[{self.link.name}]",
            total_ops=sum(d.telemetry.total_ops for d in devs),
            config_bytes=max(sum(d.telemetry.bytes_sent for d in devs), 1),
            host_cycles=max(config_cycles - link_cycles, 0.0),
            link_cycles=link_cycles,
            makespan=makespan,
            p_peak=sum(d.model.p_peak for d in devs),
        )
