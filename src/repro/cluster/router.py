"""Cross-host placement and the cluster drain loop.

PR 1's scheduler collapses device choice *within one host* to a single
scalar (T_set of the config delta + admission delay). The router lifts the
same idea one level: choose the **host**, pricing

    route cost = port congestion          (serialized config writes queued
                                           ahead on the host control thread
                                           and its fabric wire —
                                           ``Host.port_wait_estimate``)
               + config-affinity cost     (the shard's best device: T_set of
                                           the delta *over the host's fabric
                                           link* given resident tenant
                                           contexts + admission delay)

Link distance is part of the affinity scalar: a host behind a NoC hop or a
PCIe fabric carries the wire latency/bandwidth inside its T_set
(``fabric.transport``), so the router spills to a far host only once the
near one's congestion outweighs the distance — no separate tuning knob.
Tenants therefore pin to the hosts that hold their warm
:class:`~repro.sched.state_cache.ConfigStateCache` contexts until port
congestion spills them — affinity and load balance again fall out of one
number.

**Slot residency** (``sticky=True``, the serving bridge) is stronger than
either term: a hosted serving-engine shard's KV cache lives on exactly one
host (``Host.adopt_context``), and a decode launch reads *and writes* that
cache — it cannot run anywhere else without a migration. A sticky router
therefore returns the resident host before any cost comparison; the cost
model only picks the *first* home (and re-picks after an explicit
``drop_context``). Classical routers ride along for comparison,
``POLICIES``-style:

* ``round_robin`` — the naive baseline; migrating tenants across hosts
  thrashes every context cache.
* ``jsq`` — join-shortest-queue on port backlog (load-aware, cache-blind).
* ``p2c`` — power-of-two-choices: two deterministic random candidates, pick
  the lesser backlog (the classic low-coordination router).
* ``affinity`` — the cost above.

:class:`Cluster` owns the hosts and the event loop: requests are drained in
arrival order (ties to higher priority), routed, dispatched, and the merged
per-host reports become a :class:`~repro.cluster.slo.ClusterReport`.
"""

from __future__ import annotations

import itertools
import random
import zlib
from collections import Counter
from typing import Iterable, Mapping, Sequence

from ..fabric.link import LinkPort, resolve_link
from ..sched.queue import ADMISSION_MODES, AdmissionQueue
from ..sched.scheduler import LaunchRequest, arrival_order
from .host import ConfigQuota, Host
from .slo import ClusterReport, build_report

ROUTERS = ("affinity", "round_robin", "jsq", "p2c")


def _rendezvous(tenant: str, host_id: str) -> int:
    """Highest-random-weight score: a deterministic, hash-seed-independent
    per-(tenant, host) weight for breaking otherwise-symmetric ties."""
    return zlib.crc32(f"{tenant}@{host_id}".encode())


class Router:
    """Pluggable cross-host placement policy."""

    def __init__(self, hosts: Sequence[Host], policy: str = "affinity",
                 seed: int = 0, stickiness: float = 4.0,
                 sticky: bool = False):
        assert policy in ROUTERS, policy
        assert hosts, "need at least one host"
        self.hosts = list(hosts)
        self.policy = policy
        # slot-residency-aware routing: when a host holds the tenant's slot
        # context (Host.adopt_context — a hosted engine shard's KV cache),
        # route there unconditionally; the policy below only places tenants
        # that have no home yet
        self.sticky = sticky
        # affinity hysteresis: a warm context's per-launch savings are
        # credited ~stickiness launches ahead, so transient port-backlog
        # spikes (one sequential macro-op deep) don't evict a residency
        # that keeps paying — yet a saturated port still spills, because
        # backlog grows without bound while the bonus is capped
        self.stickiness = stickiness
        self._rr = itertools.count()
        self._rng = random.Random(seed)  # deterministic p2c sampling

    def _eligible(self, req: LaunchRequest) -> list[Host]:
        hosts = [h for h in self.hosts if h.can_serve(req)]
        if not hosts:
            raise KeyError(f"no host carries a {req.accel!r} device")
        return hosts

    def home(self, tenant: str) -> Host | None:
        """The host holding ``tenant``'s slot context, if any."""
        for h in self.hosts:
            if h.hosts_context(tenant):
                return h
        return None

    def route(self, req: LaunchRequest, now: float) -> Host:
        hosts = self._eligible(req)
        if self.sticky:
            home = self.home(req.tenant)
            if home is not None and home.can_serve(req):
                return home  # KV residency is binding, not advisory
        if len(hosts) == 1:
            return hosts[0]
        if self.policy == "round_robin":
            return hosts[next(self._rr) % len(hosts)]
        if self.policy == "jsq":
            return min(hosts, key=lambda h: (h.port_wait_estimate(req, now), h.id))
        if self.policy == "p2c":
            a, b = self._rng.sample(hosts, 2)
            return min((a, b), key=lambda h: (h.port_wait_estimate(req, now), h.id))
        # affinity: cheapest end-to-end host-visible cost, minus the
        # residency credit (warm contexts are worth ~stickiness launches of
        # elision, not one). Cost ties (e.g. every host cold for this
        # tenant) break toward the least-loaded host so tenants spread
        # across the cluster before pinning — the router-level twin of the
        # scheduler's cold-tie rule — and residual full ties use rendezvous
        # hashing, giving each tenant a stable deterministic home instead
        # of herding onto the first host id
        return min(hosts, key=lambda h: (
            h.probe_cost(req, now, self.stickiness),
            h.port_wait_estimate(req, now),
            h.launches,
            -_rendezvous(req.tenant, h.id),
        ))


class Cluster:
    """A pool of hosts + a router: the open-loop serving fabric."""

    def __init__(self, hosts: Sequence[Host], *, policy: str = "affinity",
                 seed: int = 0, sticky: bool = False, tracer=None):
        self.hosts = list(hosts)
        self.router = Router(self.hosts, policy=policy, seed=seed,
                             sticky=sticky)
        # the cluster-wide tracer (repro.obs): hosts hold host-bound views
        # of it; the closed-loop bridge driver picks it up from here
        self.tracer = tracer

    @classmethod
    def uniform(
        cls,
        n_hosts: int,
        counts: Mapping[str, int],
        *,
        policy: str = "affinity",
        depth: int = 2,
        max_contexts: int = 4,
        host_policy: str = "affinity",
        cache_enabled: bool = True,
        seed: int = 0,
        link=None,
        sticky: bool = False,
        overlap: str = "serialized",
        staging_buffers: int = 2,
        transport: str = "auto",
        objective: str = "cycles",
        compute_model=None,
        power=None,
        quota=None,
        shared_port: bool = False,
        tracer=None,
    ) -> "Cluster":
        """``Cluster.uniform(4, {"gemmini": 1, "opengemm": 1})`` — n
        identical hosts, each carrying one shard of the mixed pool.
        ``link`` names the fabric every host's config port crosses
        (default: the paper's core-local CSR); ``sticky`` turns on
        slot-residency-aware routing (the serving bridge's decode path);
        ``overlap`` selects the engine's config-staging mode per host
        (``"overlapped"`` hides async burst-DMA T_set behind compute);
        ``shared_port=True`` puts every host behind **one** cluster-level
        :class:`~repro.fabric.link.LinkPort` — the PCIe-switch topology,
        where all hosts' config transfers contend FIFO on a single wire
        instead of each owning a private one; ``power`` attaches a
        :class:`~repro.power.model.PowerSpec` to every host's engine
        resources (observation-only joule metering) and ``objective``
        sets what "cheaper" means for the auto transport choice
        (``cycles``/``joules``/``edp``); ``compute_model`` prices each
        host's macro-ops (``None`` = the legacy flat per-launch constant,
        ``"calibrated"`` = the fitted analytical model,
        ``engine.costmodel``); ``quota`` caps per-tenant config bandwidth
        at every host port — pass ``(bytes_per_window, window)`` or a
        zero-arg factory returning a fresh
        :class:`~repro.cluster.host.ConfigQuota`; quota accounting is
        stateful, so each host gets its own instance; ``tracer`` attaches
        one :class:`~repro.obs.trace.Tracer` across every host (each shard
        binds its host id into the spans it emits)."""
        port = None
        if shared_port:
            shared = resolve_link(link)
            port = LinkPort(shared, name=f"cfg[{shared.name}]:shared")

        def host_quota() -> ConfigQuota | None:
            if quota is None:
                return None
            if callable(quota):
                return quota()
            if isinstance(quota, ConfigQuota):
                # a shared instance would pool windows across hosts;
                # clone its parameters into per-host accounting instead
                return ConfigQuota(quota.bytes_per_window, quota.window,
                                   quota.budgets)
            bytes_per_window, window = quota
            return ConfigQuota(bytes_per_window, window)

        hosts = [
            Host.from_registry(f"h{i}", dict(counts), depth=depth,
                               max_contexts=max_contexts, policy=host_policy,
                               cache_enabled=cache_enabled, link=link,
                               overlap=overlap,
                               staging_buffers=staging_buffers,
                               transport=transport, objective=objective,
                               compute_model=compute_model,
                               power=power, quota=host_quota(), port=port,
                               tracer=tracer)
            for i in range(n_hosts)
        ]
        return cls(hosts, policy=policy, seed=seed, sticky=sticky,
                   tracer=tracer)

    def dispatch(self, req: LaunchRequest) -> Host:
        host = self.router.route(req, now=req.arrival_time)
        host.dispatch(req)
        return host

    def run(
        self,
        requests: Iterable[LaunchRequest],
        *,
        slo: Mapping[str, float] | None = None,
        order: str = "arrival",
    ) -> ClusterReport:
        """Event-driven drain: route and dispatch in admission order, then
        fold every host's scheduler report into one cluster report (``slo``
        maps tenant → latency target in cycles, cf. ``traffic.slo_targets``).

        ``order="arrival"`` admits in arrival order (ties to higher
        priority) — the classic drain. ``order="edf"`` makes cross-host
        admission deadline-aware: the router's backlog is everything that
        has arrived by the time the *earliest-free eligible host control
        thread* could take new work (``min`` over the clocks of hosts that
        can serve some still-queued device kind — a host whose kind
        receives no traffic must not pin the admission clock at zero and
        silently degrade EDF to arrival order; with one host this
        degenerates exactly to ``Scheduler.run_open_loop(order="edf")``),
        and the tightest deadline in that backlog is admitted first, so a
        burst's tight-deadline launches overtake loose ones cluster-wide
        instead of only inside whichever host they landed on. Eligibility
        is by device kind, not routing policy: a sticky tenant's home may
        be busier than the admission clock suggests — stickiness binds
        *placement*, while admission models the earliest capable port.
        ``order="warm"`` is a single-scheduler feature (it needs a warmth
        predicate bound to one device pool, ``Scheduler.run_open_loop``)
        and is not accepted here."""
        assert order in ("arrival", "edf"), order
        if order == "arrival":
            for req in sorted(requests, key=arrival_order):
                self.dispatch(req)
            return build_report(self.hosts, slo=slo)
        pending = list(requests)
        kinds = Counter(req.accel for req in pending)
        queue = AdmissionQueue(pending, mode=order)
        while len(queue):
            eligible = [h for h in self.hosts
                        if None in kinds or not kinds.keys().isdisjoint(h.kinds())]
            now = min(h.clock for h in eligible) if eligible else 0.0
            req = queue.pop(now)
            kinds[req.accel] -= 1
            if not kinds[req.accel]:
                del kinds[req.accel]
            self.dispatch(req)
        return build_report(self.hosts, slo=slo)
