"""Migration *trigger* policy: decide when a hot host sheds a tenant.

``fabric.migrate.MigrationPlanner`` prices and executes a move (warm
register-snapshot hand-off vs. cold resend), but nothing decided *when* a
move should happen — the ROADMAP gap this module closes. The rule is a
deliberately simple threshold:

    a host whose ``port_wait_estimate`` stays above ``k ×`` the cluster
    median for ``sustain`` consecutive observations sheds its hottest
    tenant to the least-backlogged host that can serve it.

``port_wait_estimate`` is the *single* backlog signal routers and the SLO
report already share (the engine's resource-interval query), so the
trigger, the router, and telemetry can never disagree about which host is
hot. The median — not the mean — is the baseline so one runaway host
cannot drag the threshold up after itself; ``sustain`` debounces transient
spikes (one deep macro-op should not trigger a hand-off that costs real
wire cycles).

The victim is the hot host's most-launched resident tenant (its heaviest
stream — moving it sheds the most future port pressure), priced with the
tenant's last dispatched request as the probe. The planner then executes
whichever of warm/cold is cheaper over the shared migration link, and the
tenant's slot context (KV-cache residency, ``repro.bridge``) follows it so
a sticky router immediately routes the stream to its new home.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from ..fabric.migrate import MigrationPlanner, MigrationRecord
from ..obs.monitor import SustainedThreshold
from .host import Host
from .slo import percentile


@dataclass(frozen=True)
class ShedDecision:
    """One executed shed: why it fired and what it moved."""

    tenant: str
    src: str
    dst: str
    now: float
    src_wait: float  # the hot host's port wait when the trigger fired
    median_wait: float  # the cluster median it was judged against
    record: MigrationRecord  # the planner's executed move (warm or cold)


class ShedTrigger:
    """Threshold rule driving the migration planner.

    Call :meth:`observe` periodically (each admission epoch, each bridge
    step, ...). Debouncing is the obs layer's
    :class:`~repro.obs.monitor.SustainedThreshold` keyed by host: a host
    must stay hot for ``sustain`` consecutive observations before it
    sheds, and the key is acknowledged (reset) after a shed — give the
    move time to drain — or whenever it dips back under the threshold.
    A failed shed attempt (no viable victim or destination) leaves the
    alert fired, so it retries next epoch.

    ``monitor`` (an :class:`~repro.obs.monitor.StreamMonitor`) optionally
    receives every observation under ``cluster.port_wait`` keyed by host,
    so dashboards window the same pressure signal the trigger acts on.
    """

    def __init__(self, planner: MigrationPlanner, *, k: float = 1.5,
                 sustain: int = 2, monitor=None):
        assert k > 1.0, "threshold must exceed the median or every host is hot"
        assert sustain >= 1
        self.planner = planner
        self.k = k
        self.sustain = sustain
        self.decisions: list[ShedDecision] = []
        self.pressure = SustainedThreshold(sustain=sustain)
        self.monitor = monitor

    # -- the rule -------------------------------------------------------------

    def hot_hosts(self, waits: dict[str, float]) -> tuple[list[str], float]:
        """(hosts above k×median right now, the median). A host is hot
        when its wait exceeds k× the cluster median *and* is nonzero: an
        idle cluster (all waits 0) has nothing to rebalance, but one
        backlogged host among idle peers — where the median itself is 0 —
        is exactly the case that must shed."""
        median = percentile(list(waits.values()), 50)
        return ([h for h, w in waits.items()
                 if w > self.k * median and w > 0.0], median)

    def observe(self, hosts: Sequence[Host], now: float) -> list[ShedDecision]:
        """One observation epoch: update streaks, shed where sustained.
        When several hosts run hot in one epoch, each shed takes a
        *distinct* destination — the epoch's backlog numbers are stale the
        moment the first hand-off is committed, so piling every victim
        onto the single coldest host would just mint the next hot host."""
        waits = {h.id: h.port_wait_estimate(now=now) for h in hosts}
        if self.monitor is not None:
            for host_id, wait in waits.items():
                self.monitor.observe("cluster.port_wait", now, wait,
                                     host=host_id)
        hot, median = self.hot_hosts(waits)
        fired: list[ShedDecision] = []
        used_dsts: set[str] = set()
        for host in hosts:
            if not self.pressure.update(host.id, host.id in hot):
                continue
            decision = self._shed(host, hosts, waits, now, median, used_dsts)
            if decision is not None:
                fired.append(decision)
                used_dsts.add(decision.dst)
                self.pressure.reset(host.id)
        self.decisions.extend(fired)
        return fired

    # -- execution ------------------------------------------------------------

    def _victim(self, src: Host) -> tuple[str, object] | None:
        """The hot host's heaviest stream that is still *resident* here
        (most launches, ties to the tenant name for determinism). Launch
        counts are cumulative, so residency is the filter that keeps an
        already-shed tenant — whose context the migration invalidated —
        from being 'moved' again on the strength of its history."""
        resident = {t for dev in src.devices for t in dev.cache.tenants()}
        for tenant, _ in sorted(src.tenant_launches().items(),
                                key=lambda kv: (-kv[1], kv[0])):
            if tenant not in resident:
                continue
            probe = src.last_request(tenant)
            if probe is not None:
                return tenant, probe
        return None

    def _shed(self, src: Host, hosts: Sequence[Host],
              waits: dict[str, float], now: float, median: float,
              used_dsts: set[str] = frozenset()) -> ShedDecision | None:
        picked = self._victim(src)
        if picked is None:
            return None
        tenant, probe = picked
        targets = [h for h in hosts
                   if h is not src and h.id not in used_dsts
                   and h.can_serve(probe)]
        if not targets:
            return None
        dst = min(targets, key=lambda h: (waits[h.id], h.id))
        if waits[dst.id] >= waits[src.id]:
            return None  # nowhere meaningfully colder to shed to
        record = self.planner.migrate(tenant, src, dst, probe, now=now)
        if src.hosts_context(tenant):
            # slot residency (KV cache) follows the register context, so a
            # sticky router re-homes the stream immediately
            src.drop_context(tenant)
            dst.adopt_context(tenant)
        decision = ShedDecision(
            tenant=tenant,
            src=src.id,
            dst=dst.id,
            now=now,
            src_wait=waits[src.id],
            median_wait=median,
            record=record,
        )
        return decision
