"""Deterministic open-loop traffic generation for cluster serving runs.

Closed-loop benchmarks (PR 1's ``multi_tenant_sched``) replay a fixed batch
of launches and report makespan; production serving is *open-loop* — work
arrives on its own clock whether or not the pool is ready, and what matters
is the tail of the queueing delay, not the mean. This module synthesizes
such arrival streams:

* **Arrival processes** — ``poisson`` (memoryless, the M/G/k baseline),
  ``bursty`` (a two-state Markov-modulated Poisson process: quiet vs. burst
  episodes, same mean rate), and ``diurnal`` (sinusoidally-modulated rate
  via Lewis-Shedler thinning — the daily peak/trough of user traffic).
  All are driven by one ``random.Random(seed)``, so a given
  ``(profiles, process, rate, horizon, seed)`` tuple always produces the
  identical request list — runs are replayable and A/B router comparisons
  see byte-identical traffic.

* **Tenant-mix profiles** — each :class:`TenantProfile` names a tenant, its
  GEMM tile (derivable from the ``configs/`` model zoo via
  :meth:`TenantProfile.from_arch`: decode-step tiles of ``d_model``/``d_ff``),
  a traffic ``weight``, a ``priority`` class and an SLO target. Per-launch
  operand addresses cycle through ``n_bufs`` buffers, so a warm
  ``ConfigStateCache`` context elides the static dims/strides but still
  pays for the advancing pointers — the realistic partial-delta regime.

Times are in host cycles, the unit every layer below already speaks.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator

from ..configs import get as get_arch
from ..sched.scheduler import LaunchRequest


def _pow2_tile(x: int, lo: int = 8, hi: int = 64) -> int:
    """Largest power-of-two tile ≤ x, clamped to the accelerator-friendly
    [lo, hi] range (systolic arrays want multiples of the PE grid)."""
    if x <= lo:
        return lo
    return min(hi, 1 << int(math.log2(x)))


@dataclass(frozen=True)
class TenantProfile:
    """One tenant's contribution to the cluster mix."""

    tenant: str
    dims: tuple[int, int, int]  # per-launch GEMM tile (M, K, N)
    accel: str | None = None  # restrict to one device kind, None = any
    weight: float = 1.0  # share of arrivals
    priority: int = 0  # preemption class (sched.queue)
    slo_cycles: float | None = None  # per-launch latency target
    n_bufs: int = 4  # operand buffers the stream cycles through
    base_addr: int = 0x1000  # first operand address (kept distinct per tenant)

    @classmethod
    def from_arch(
        cls,
        tenant: str,
        arch: str,
        *,
        batch_tile: int = 16,
        **kwargs,
    ) -> "TenantProfile":
        """Derive a decode-step GEMM tile from a model-zoo architecture:
        M = decode batch tile, K = tile of ``d_model``, N = tile of
        ``d_ff`` — the dominant MLP GEMM of one decode launch."""
        cfg = get_arch(arch)
        dims = (
            _pow2_tile(batch_tile),
            _pow2_tile(cfg.d_model),
            _pow2_tile(cfg.d_ff),
        )
        return cls(tenant=tenant, dims=dims, **kwargs)

    def regs_extra(self, index: int) -> dict[str, int]:
        """Register fields beyond the dims for the ``index``-th launch:
        operand/result pointers advancing through the buffer ring."""
        slot = index % self.n_bufs
        stride = 64 * max(self.dims[0], 8)
        return {
            "A": self.base_addr + slot * stride,
            "B": self.base_addr + 0x100000 + slot * stride,
            "C": self.base_addr + 0x200000 + slot * stride,
            "zp": 0,
        }


# -- arrival processes ------------------------------------------------------
#
# Each generator yields strictly increasing arrival times in [0, horizon),
# consuming randomness only from the passed Random instance.


def poisson_arrivals(rate: float, horizon: float,
                     rng: random.Random) -> Iterator[float]:
    """Homogeneous Poisson process: i.i.d. exponential inter-arrivals."""
    assert rate > 0.0
    t = rng.expovariate(rate)
    while t < horizon:
        yield t
        t += rng.expovariate(rate)


def bursty_arrivals(rate: float, horizon: float, rng: random.Random, *,
                    burst_factor: float = 4.0,
                    burst_fraction: float = 0.1,
                    episode: float = 2_000.0) -> Iterator[float]:
    """Two-state MMPP with the same *mean* rate as ``poisson_arrivals``:
    the process alternates exponential episodes of quiet traffic and
    ``burst_factor``-times-hotter bursts (``burst_fraction`` of the time
    spent bursting). Mean-rate preservation needs the quiet state to carry
    the leftover rate, so ``burst_fraction * burst_factor < 1`` is required
    — an infeasible pair is rejected rather than silently re-rated."""
    assert rate > 0.0 and burst_factor > 1.0 and 0.0 < burst_fraction < 1.0
    assert burst_fraction * burst_factor < 1.0, (
        "burst state alone exceeds the requested mean rate")
    quiet_rate = rate * (1.0 - burst_fraction * burst_factor) / (1.0 - burst_fraction)
    burst_rate = rate * burst_factor
    t = 0.0
    bursting = False
    while t < horizon:
        mean_stay = episode * (burst_fraction if bursting else 1.0 - burst_fraction)
        t_switch = t + rng.expovariate(1.0 / mean_stay)
        lam = burst_rate if bursting else quiet_rate
        t += rng.expovariate(lam)
        while t < min(t_switch, horizon):
            yield t
            t += rng.expovariate(lam)
        t = min(t_switch, horizon)
        bursting = not bursting


def diurnal_arrivals(rate: float, horizon: float, rng: random.Random, *,
                     period: float | None = None,
                     depth: float = 0.8) -> Iterator[float]:
    """Sinusoidally-modulated Poisson process (Lewis-Shedler thinning):
    instantaneous rate ``rate * (1 + depth * sin(2πt/period))`` — the daily
    swell and trough of user traffic, mean rate preserved."""
    assert rate > 0.0 and 0.0 <= depth < 1.0
    if period is None:
        period = horizon  # one "day" per run by default
    peak = rate * (1.0 + depth)
    t = rng.expovariate(peak)
    while t < horizon:
        lam_t = rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.random() < lam_t / peak:
            yield t
        t += rng.expovariate(peak)


ARRIVALS: dict[str, Callable[..., Iterator[float]]] = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
    "diurnal": diurnal_arrivals,
}


# -- workload synthesis -----------------------------------------------------


def generate(
    profiles: Iterable[TenantProfile],
    *,
    rate: float,
    horizon: float,
    process: str = "poisson",
    seed: int = 0,
    **process_kwargs,
) -> list[LaunchRequest]:
    """Synthesize one open-loop request stream over the tenant mix.

    Every arrival of the aggregate process is assigned to a tenant by
    weighted choice; the tenant's per-launch register stream (advancing
    buffer pointers over static dims) becomes the request's fields, and the
    arrival time is stamped onto :class:`LaunchRequest.arrival_time`.
    Deterministic: one ``random.Random(seed)`` drives arrivals and tenant
    assignment alike.
    """
    profiles = list(profiles)
    assert profiles, "need at least one tenant profile"
    assert process in ARRIVALS, f"unknown process {process!r} (have {sorted(ARRIVALS)})"
    rng = random.Random(seed)
    # distinct per-tenant address spaces even if callers reuse base_addr:
    # any profile whose base collides with an earlier one is shifted to a
    # fresh 4 MiB-spaced region
    spaced: list[TenantProfile] = []
    seen_bases: set[int] = set()
    for i, p in enumerate(profiles):
        if p.base_addr in seen_bases:
            p = TenantProfile(**{**p.__dict__,
                                 "base_addr": 0x1000 + i * 0x400000})
        seen_bases.add(p.base_addr)
        spaced.append(p)
    weights = [p.weight for p in spaced]
    counters = {p.tenant: 0 for p in spaced}
    requests: list[LaunchRequest] = []
    for t in ARRIVALS[process](rate, horizon, rng, **process_kwargs):
        prof = rng.choices(spaced, weights=weights)[0]
        idx = counters[prof.tenant]
        counters[prof.tenant] = idx + 1
        requests.append(LaunchRequest(
            tenant=prof.tenant,
            dims=prof.dims,
            extra=prof.regs_extra(idx),
            accel=prof.accel,
            arrival_time=t,
            priority=prof.priority,
        ))
    return requests


def slo_targets(profiles: Iterable[TenantProfile]) -> dict[str, float]:
    """The per-tenant latency targets the mix declares (tenants without an
    explicit ``slo_cycles`` are omitted — the report treats them as best
    effort)."""
    return {p.tenant: p.slo_cycles for p in profiles if p.slo_cycles is not None}
