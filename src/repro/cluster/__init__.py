"""repro.cluster — multi-host open-loop serving above `repro.sched`.

The paper characterizes the configuration wall for one host–accelerator
pair; PR 1's scheduler eliminates redundant config traffic for one host's
*pool*. This package lifts the system to production shape — many hosts,
open-loop traffic, tail-latency SLOs — the regime where the ROADMAP's
"heavy traffic from millions of users" lives:

* :mod:`~repro.cluster.traffic` — deterministic open-loop workload
  generation: Poisson / bursty (MMPP) / diurnal arrival processes over
  tenant-mix profiles drawn from the ``configs/`` model zoo, stamping
  ``arrival_time`` (and priority class) onto every ``LaunchRequest``.
* :mod:`~repro.cluster.host` — a :class:`Host` wraps one scheduler shard of
  the device pool behind a *serialized config-write port*: concurrent
  devices still contend for one control thread, so T_set amplifies with
  pool width (Colagrande & Benini's offload amplification).
* :mod:`~repro.cluster.router` — cross-host placement: the config-affinity
  scalar extended with port congestion and tenant-context residency, plus
  ``round_robin`` / ``jsq`` / ``p2c`` baselines, and the :class:`Cluster`
  drain loop.
* :mod:`~repro.cluster.slo` — per-tenant queueing-delay/latency percentiles
  (p50/p95/p99), SLO attainment and goodput, exported as ``interp.Trace``
  timelines and per-host configuration-roofline points so cluster runs plot
  beside compiled programs.
* :mod:`~repro.cluster.shed` — the migration *trigger*: a host whose
  ``port_wait_estimate`` stays above k× the cluster median sheds its
  hottest tenant through ``fabric.migrate``'s planner (which prices warm
  hand-off vs. cold resend and executes the cheaper).

The full runtime stack is now ``compile → dispatch → schedule → route →
transport``: hosts name the fabric link their config port crosses
(``repro.fabric``), and the router prices link distance alongside
congestion and residency.
"""

from . import host, router, shed, slo, traffic
from .host import Host
from .router import ROUTERS, Cluster, Router
from .shed import ShedDecision, ShedTrigger
from .slo import ClusterReport, TenantSLO, TenantServing, build_report, percentile
from .traffic import ARRIVALS, TenantProfile, generate, slo_targets

__all__ = [
    "ARRIVALS",
    "Cluster",
    "ClusterReport",
    "Host",
    "ROUTERS",
    "Router",
    "ShedDecision",
    "ShedTrigger",
    "TenantProfile",
    "TenantSLO",
    "TenantServing",
    "build_report",
    "generate",
    "host",
    "percentile",
    "router",
    "shed",
    "slo",
    "slo_targets",
    "traffic",
]
