import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile one (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init): the dry-run — and only the dry-run — materializes 512
placeholder host devices so the production meshes (16×16 single-pod, 2×16×16
multi-pod) can be built. Smoke tests and benches see the real single device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--out results/cell.json]

Success criteria: ``.lower().compile()`` completes; ``memory_analysis()`` and
``cost_analysis()`` are printed (bytes/device proves (non-)fit, FLOPs/bytes
feed §Roofline); collective bytes are parsed from the post-SPMD HLO.
"""

import argparse
import json
import sys
import time


def optimized_policy(cfg, shape_kind: str, global_batch: int = 0,
                     chips: int = 256) -> dict:
    """The best-known distribution policy per (family × step kind), derived
    from the §Perf hillclimb (EXPERIMENTS.md). This is the beyond-paper
    configuration — the baseline tables use the naive defaults."""
    over: dict = {}
    if cfg.n_experts:
        over["moe_impl"] = "shard_map"  # explicit a2a EP (all step kinds)
    if shape_kind in ("train", "prefill"):
        over["attn_chunk"] = 512  # flash-style attention on the XLA path
        if shape_kind == "train":
            over["grad_compression"] = "bf16"
        # GQA/odd head counts that don't divide the 16-way model axis force
        # S² score resharding: replicate attention, keep TP in the MLPs.
        # RWKV: TP in the time-mix puts a reduce inside every scan step.
        if cfg.family == "ssm" or (
            cfg.n_kv_heads and (cfg.n_kv_heads % 16 or cfg.n_heads % 16)
        ):
            over["tp_attention"] = 0
        if cfg.family == "hybrid":
            over["ssm_chunk"] = 512  # SSD-style chunked Mamba scan (memory)
        if (
            cfg.param_count() < 2e9
            and shape_kind == "train"
            and global_batch % chips == 0  # the batch must tile every chip
        ):
            over["pure_dp"] = 1
            over.pop("tp_attention", None)
    else:  # decode
        over["cache_shard_seq"] = 1  # flash-decoding cache layout
    return over


def run_cell(arch: str, shape_name: str, multi_pod: bool, donate: bool = True,
             overrides: dict | None = None, preset: str = "") -> dict:
    """One dry-run cell. Methodology (see EXPERIMENTS.md §Dry-run):

    * compile the *scanned* trunk — proves the sharding config lowers and
      compiles, gives ``memory_analysis`` (realistic buffer scheduling) and
      the collective schedule (while-body collectives weighted by trip count);
    * additionally *lower* (not compile) the scan-unrolled trunk — its
      ``cost_analysis`` gives exact global FLOPs / bytes including remat,
      which a while-body-counted-once analysis would undercount.
    """
    import dataclasses

    import jax

    from repro.configs import SHAPES, applicable, get
    from repro.launch import steps as steps_lib
    from repro.launch.hlo_analysis import RooflineTerms, collective_bytes_weighted
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model
    from repro.optim import AdamW

    cfg = get(arch)
    shape = SHAPES[shape_name]
    if preset == "optimized":
        chips = 512 if multi_pod else 256
        cfg = dataclasses.replace(
            cfg, **optimized_policy(cfg, shape.kind, shape.global_batch, chips)
        )
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": reason,
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    optimizer = AdamW() if shape.kind == "train" else None

    # --- scanned compile: sharding proof + memory + collectives ------------
    model = Model(cfg)
    step = steps_lib.build_step_for(model, shape, optimizer)
    kind, abstract_args, donate_argnums = steps_lib.abstract_cell_args(
        model, shape, mesh, optimizer
    )
    if not donate:
        donate_argnums = ()
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(step, donate_argnums=donate_argnums).lower(*abstract_args)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_weighted(hlo, default_trip=cfg.n_layers)

    # --- unrolled lowering: exact global FLOPs / bytes ---------------------
    model_u = Model(dataclasses.replace(cfg, scan_unroll=True))
    step_u = steps_lib.build_step_for(model_u, shape, optimizer)
    _, args_u, _ = steps_lib.abstract_cell_args(model_u, shape, mesh, optimizer)
    with jax.set_mesh(mesh):
        cost = jax.jit(step_u).lower(*args_u).cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    global_flops = float(cost.get("flops", 0.0))
    global_bytes = float(cost.get("bytes accessed", 0.0))

    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_fields[f] = int(getattr(mem, f, 0) or 0)
    live_bytes = (
        mem_fields["argument_size_in_bytes"]
        + mem_fields["output_size_in_bytes"]
        - mem_fields["alias_size_in_bytes"]
        + mem_fields["temp_size_in_bytes"]
    )

    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if kind in ("train", "prefill") else 1)
    model_flops = (6 if kind == "train" else 2) * n_active * tokens

    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=global_flops,
        hlo_bytes=global_bytes,
        coll_bytes=coll.total_bytes,
        model_flops=model_flops,
        per_device_bytes=live_bytes,
        collectives={
            k: {"bytes": coll.bytes_by_kind[k], "count": coll.count_by_kind[k]}
            for k in coll.bytes_by_kind
        },
    )
    record = {
        "status": "ok", "kind": kind, "compile_s": compile_s,
        "memory_analysis": mem_fields,
        **terms.to_dict(),
    }
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default="")
    p.add_argument("--no-donate", action="store_true")
    p.add_argument("--preset", default="", choices=("", "optimized"),
                   help="optimized = best-known policy from §Perf hillclimbs")
    p.add_argument("--override", action="append", default=[],
                   help="cfg overrides, e.g. --override remat=dots")
    args = p.parse_args()

    overrides = {}
    for item in args.override:
        k, v = item.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    record = run_cell(args.arch, args.shape, args.multi_pod,
                      donate=not args.no_donate, overrides=overrides or None,
                      preset=args.preset)

    if record["status"] == "ok":
        print(f"[dryrun] {args.arch} × {args.shape} × {record['mesh']}: COMPILED "
              f"in {record['compile_s']:.1f}s")
        print(f"  memory_analysis: {record['memory_analysis']}")
        print(f"  bytes/device (live): {record['per_device_bytes']/2**30:.2f} GiB")
        print(f"  cost_analysis (global): flops={record['hlo_flops']:.3e} "
              f"bytes={record['hlo_bytes']:.3e}")
        print(f"  collectives: {record['collectives']}")
        print(f"  roofline terms (s): compute={record['compute_s']:.4e} "
              f"memory={record['memory_s']:.4e} collective={record['collective_s']:.4e}"
              f"  dominant={record['dominant']}")
        print(f"  MODEL_FLOPS={record['model_flops']:.3e} "
              f"useful/HLO={record['useful_flops_ratio']:.3f} "
              f"roofline_fraction={record['roofline_fraction']:.3f}")
    else:
        print(f"[dryrun] {args.arch} × {args.shape}: SKIPPED — {record['reason']}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)


if __name__ == "__main__":
    main()
