"""Step-function builders shared by training, serving, and the dry-run.

Everything here is mesh-agnostic: the functions close over a Model (+
optimizer) only; shardings are attached at lower/compile time by giving
``jax.jit`` ShapeDtypeStruct arguments that carry NamedShardings
(``with_shardings``), so the same step lowers on the 1-device smoke mesh and
the 512-chip production mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.distributed import (
    cache_shardings,
    input_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.optim import AdamW


def build_train_step(model: Model, optimizer: AdamW):
    compress = model.cfg.grad_compression

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        if compress == "bf16":
            # gradient compression: force the cross-data reduction to happen
            # in bf16 (halves the dominant all-reduce wire bytes)
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        new_params, new_opt, opt_metrics = optimizer.update(params, grads, opt_state)
        return new_params, new_opt, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def build_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step


def build_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return decode_step


# --------------------------------------------------------------------------
# Abstract (ShapeDtypeStruct) argument trees with shardings attached
# --------------------------------------------------------------------------


def with_shardings(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        abstract_tree,
        sharding_tree,
    )


def serve_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Decode-step inputs: one new token against a seq_len-deep cache."""
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def abstract_cell_args(model: Model, shape: ShapeSpec, mesh, optimizer: AdamW | None):
    """Abstract, sharded argument trees for one (arch × shape) dry-run cell.

    Returns (step_kind, args tuple, donate_argnums)."""
    cfg = model.cfg
    if shape.kind == "train":
        params = model.abstract_params()
        assert optimizer is not None
        opt = jax.eval_shape(optimizer.init, params)
        batch = model.input_specs(shape.global_batch, shape.seq_len)
        args = (
            with_shardings(params, param_shardings(mesh, params, cfg)),
            with_shardings(opt, opt_state_shardings(mesh, opt, cfg)),
            with_shardings(batch, input_shardings(mesh, batch, cfg)),
        )
        return "train", args, (0, 1)
    if shape.kind == "prefill":
        params = model.abstract_params()
        batch = model.input_specs(shape.global_batch, shape.seq_len)
        batch.pop("labels")
        args = (
            with_shardings(params, param_shardings(mesh, params, cfg)),
            with_shardings(batch, input_shardings(mesh, batch, cfg)),
        )
        return "prefill", args, ()
    # decode
    params = model.abstract_params()
    cache = model.init_cache(shape.global_batch, shape.seq_len, concrete=False)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_sh = input_shardings(mesh, {"tokens": tokens}, cfg)["tokens"]
    from jax.sharding import NamedSharding, PartitionSpec as P

    args = (
        with_shardings(params, param_shardings(mesh, params, cfg)),
        with_shardings(cache, cache_shardings(mesh, cfg, cache)),
        jax.ShapeDtypeStruct(tokens.shape, tokens.dtype, sharding=tok_sh),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    )
    return "decode", args, (1,)


def build_step_for(model: Model, shape: ShapeSpec, optimizer: AdamW | None):
    if shape.kind == "train":
        return build_train_step(model, optimizer)
    if shape.kind == "prefill":
        return build_prefill_step(model)
    return build_decode_step(model)
