"""Production mesh construction.

A function — not a module-level constant — so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16); the pod axis is
pure data parallelism across the cross-pod (DCN-class) links.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1-device mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
