"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` supplies FLOPs and bytes accessed; collective
traffic is NOT in cost_analysis, so we parse the (post-SPMD, per-device) HLO
text and sum the operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (incl. async -start forms).

Hardware constants (TPU v5e-class, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_GROUPS_DIMS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_DIMS_RE.search(line)
    if m:  # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(kind: str, result_bytes: int, s: int) -> float:
    """Per-device bytes on the interconnect under ring algorithms."""
    if kind == "collective-permute":  # point-to-point: no replica groups
        return float(result_bytes)
    if s <= 1:
        return 0.0
    frac = (s - 1) / s
    if kind == "all-reduce":
        return 2.0 * result_bytes * frac  # reduce-scatter + all-gather phases
    if kind == "all-gather":
        return result_bytes * frac  # result is the gathered (full) buffer
    if kind == "reduce-scatter":
        return result_bytes * (s - 1)  # operand = result × S; wire ≈ operand·frac
    if kind == "all-to-all":
        return result_bytes * frac
    return float(result_bytes)  # collective-permute


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _collective_in_line(line: str) -> tuple[str, int] | None:
    """Returns (kind, index of the op *invocation*) — the kind string also
    appears in result variable names (``%all-reduce.1 = ...``), so we anchor
    on the ``kind(`` call syntax."""
    for k in _COLLECTIVES:
        for form in (f" {k}(", f" {k}-start("):
            idx = line.find(form)
            if idx >= 0:
                return k, idx
    return None


def _line_wire_bytes(line: str, default_group: int) -> tuple[str, int] | None:
    if "-done(" in line:
        return None  # async pair: count the -start only
    hit = _collective_in_line(line)
    if hit is None:
        return None
    kind, idx = hit
    head = line[:idx]  # "%name = <result shape(s)>"
    shapes = _SHAPE_RE.findall(head)
    if not shapes:
        return kind, 0
    # async-start results are tuples (operand, result): take the largest
    result_bytes = max(_shape_bytes(d, dims) for d, dims in shapes)
    s = _group_size(line, default_group)
    return kind, int(_wire_bytes(kind, result_bytes, s))


def collective_bytes(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    """Flat sum of per-device wire traffic over all collectives (no loop
    trip-count weighting — see :func:`collective_bytes_weighted`)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        out = _line_wire_bytes(line, default_group)
        if out is None:
            continue
        kind, nbytes = out
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


# --------------------------------------------------------------------------
# Loop-aware attribution: collectives inside a `while` body execute once per
# trip, but the HLO text prints the body once. We reconstruct the computation
# graph, extract trip counts (backend_config known_trip_count, falling back
# to the loop bound constant in the condition computation), and weight.
# --------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\-.]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w\-.]+),\s*body=%?([\w\-.]+)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def collective_bytes_weighted(hlo_text: str, default_trip: int = 1,
                              default_group: int = 1) -> CollectiveStats:
    comps: dict[str, list[str]] = {}
    entry = None
    current: list[str] | None = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line.strip()) if "{" in line and "->" in line else None
        if m:
            name = m.group(2)
            comps[name] = current = []
            if m.group(1):
                entry = name
            continue
        if current is not None:
            current.append(line)

    # per-computation collective totals and while edges
    per_comp: dict[str, CollectiveStats] = {}
    edges: dict[str, list[tuple[str, str, int | None]]] = {}
    for name, lines in comps.items():
        st = CollectiveStats()
        edges[name] = []
        for line in lines:
            out = _line_wire_bytes(line, default_group)
            if out is not None:
                kind, nbytes = out
                st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + nbytes
                st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else None
                if trip is None and cond in comps:
                    consts = [int(c) for l in comps[cond] for c in _CONST_RE.findall(l)]
                    trip = max(consts) if consts else None
                edges[name].append((cond, body, trip))
        per_comp[name] = st

    total = CollectiveStats()
    visited: set[str] = set()

    def visit(comp: str, mult: int, seen: frozenset):
        if comp not in per_comp or comp in seen:
            return
        visited.add(comp)
        st = per_comp[comp]
        for k, v in st.bytes_by_kind.items():
            total.bytes_by_kind[k] = total.bytes_by_kind.get(k, 0) + v * mult
            total.count_by_kind[k] = total.count_by_kind.get(k, 0) + st.count_by_kind[k] * mult
        for cond, body, trip in edges.get(comp, []):
            t = trip if trip is not None else default_trip
            visit(body, mult * max(t, 1), seen | {comp})
            visit(cond, mult * max(t, 1), seen | {comp})

    if entry is None:  # fallback: flat count
        return collective_bytes(hlo_text, default_group)
    visit(entry, 1, frozenset())
    # computations not reachable via while edges (async wrappers etc.): ×1
    for name, st in per_comp.items():
        if name in visited or not st.bytes_by_kind:
            continue
        for k, v in st.bytes_by_kind.items():
            total.bytes_by_kind[k] = total.bytes_by_kind.get(k, 0) + v
            total.count_by_kind[k] = total.count_by_kind.get(k, 0) + st.count_by_kind[k]
    return total


@dataclass
class RooflineTerms:
    """The three dry-run roofline terms, in seconds, plus provenance."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # GLOBAL flops (per-device × chips)
    hlo_bytes: float  # GLOBAL bytes accessed
    coll_bytes: float  # per-device collective bytes on the wire
    model_flops: float  # 6·N·D (or 6·N_active·D)
    per_device_bytes: float  # peak memory from memory_analysis
    collectives: dict[str, int] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # per-device wire bytes over one chip's ICI links
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: overlapped comms ⇒ max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound implied by the three terms."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "per_device_bytes": self.per_device_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }
