"""End-to-end training driver (CPU-runnable; mesh-portable).

    PYTHONPATH=src python -m repro.launch.train --arch paper-lm-100m \
        --steps 300 --batch 8 --seq 256 [--reduced] [--ckpt-dir /tmp/ckpt]

Runs the full stack: synthetic data pipeline with prefetch (configuration
overlap at the data layer), jitted donated train step, fault-tolerant
supervisor with async checkpoints, straggler monitoring, and a final loss
report. ``--arch`` accepts any pool architecture; ``--reduced`` swaps in the
same-family smoke-scale config so every arch trains on one CPU.
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-lm-100m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    import jax
    import numpy as np

    from repro.checkpoint import CheckpointStore
    from repro.configs import get
    from repro.data import make_train_iterator
    from repro.models.model import Model
    from repro.optim import AdamW, CosineSchedule
    from repro.runtime import TrainSupervisor

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat="none")
    model = Model(cfg)
    optimizer = AdamW(
        schedule=CosineSchedule(peak_lr=args.lr, warmup_steps=20, total_steps=args.steps)
    )

    key = jax.random.key(0)
    params = model.init(key)
    opt_state = optimizer.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    def add_frontend(batch):
        if cfg.family in ("vlm",):
            batch["frontend_embeds"] = np.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), np.float32
            )
        if cfg.family == "encdec":
            batch["frontend_embeds"] = np.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model), np.float32
            )
        return batch

    @jax.jit
    def train_step(state, batch):
        params, opt_state = state
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        params, opt_state, om = optimizer.update(params, grads, opt_state)
        return (params, opt_state), {**metrics, **om, "loss": loss}

    data = make_train_iterator(cfg.vocab_size, args.seq, args.batch, prefetch=2)
    batches = {}

    def batch_fn(step):
        while True:
            s, b = next(data)
            batches[s] = add_frontend(b)
            if step in batches:
                return batches.pop(step)

    losses = []
    t0 = time.time()

    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)

        def step_fn(state, batch):
            new_state, metrics = train_step(state, batch)
            losses.append(float(metrics["loss"]))
            return new_state

        sup = TrainSupervisor(step_fn, store, ckpt_every=args.ckpt_every)
        state = sup.run((params, opt_state), batch_fn, args.steps)
        params, opt_state = state
        print(f"[train] straggler events: {len(sup.monitor.flagged)}; "
              f"restarts: {sup.restarts}")
    else:
        state = (params, opt_state)
        for step in range(args.steps):
            state, metrics = train_step(state, batch_fn(step))
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(f"  step {step:4d} loss={losses[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
    data.close()

    dt = time.time() - t0
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
