"""Batched serving driver: prefill-free decode loop with the paper's two
optimizations applied at the dispatch layer.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --steps 64 [--mode sequential|concurrent|fused]

Modes map to the configuration roofline (§4):
* ``sequential``  — block per token + re-send full descriptor: the paper's
                    sequential-configuration baseline.
* ``concurrent``  — async dispatch + deduped descriptors (only the position
                    scalar crosses the boundary): dedup + overlap.
* ``fused``       — k tokens per launch via ``lax.scan`` inside the jitted
                    step: configuration hoisting, I_OC × k (§4.2's rightward
                    move; the decisive serving-side win).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-0.5b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--steps", type=int, default=64)
    p.add_argument("--cache-len", type=int, default=256)
    p.add_argument("--mode", default="concurrent",
                   choices=("sequential", "concurrent", "fused"))
    p.add_argument("--fuse", type=int, default=8, help="tokens per launch (fused)")
    args = p.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get
    from repro.models.model import Model

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve driver targets decoder-only archs; "
                         "use examples/serve_decode.py for stubs")

    params = model.init(jax.random.key(0))
    cache = model.init_cache(args.batch, args.cache_len)
    tokens = jnp.ones((args.batch, 1), jnp.int32)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def fused_decode(params, cache, tokens, pos0, k):
        def body(carry, i):
            cache, toks = carry
            logits, cache = model.decode_step(params, cache, toks, pos0 + i)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return (cache, nxt), nxt[:, 0]
        (cache, _), out = jax.lax.scan(
            body, (cache, tokens), jnp.arange(k, dtype=jnp.int32)
        )
        return out, cache

    fused = jax.jit(fused_decode, static_argnames=("k",), donate_argnums=(1,))

    # warmup
    if args.mode == "fused":
        out, cache = fused(params, cache, tokens, jnp.int32(0), args.fuse)
        jax.block_until_ready(out)
        start = args.fuse
    else:
        logits, cache = decode(params, cache, tokens, jnp.int32(0))
        jax.block_until_ready(logits)
        start = 1

    t0 = time.perf_counter()
    produced = 0
    if args.mode == "sequential":
        for i in range(start, args.steps):
            logits, cache = decode(params, cache, tokens, jnp.int32(i))
            jax.block_until_ready(logits)  # host blocked per token
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            produced += 1
    elif args.mode == "concurrent":
        for i in range(start, args.steps):
            logits, cache = decode(params, cache, tokens, jnp.int32(i))
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # async
            produced += 1
        jax.block_until_ready(tokens)
    else:  # fused
        pos = start
        while pos < args.steps:
            k = min(args.fuse, args.steps - pos)
            out, cache = fused(params, cache, tokens, jnp.int32(pos), k)
            tokens = out[-1:, :].T.astype(jnp.int32)
            pos += k
            produced += k
        jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0

    tps = produced * args.batch / dt
    print(f"[serve] arch={cfg.name} mode={args.mode} batch={args.batch} "
          f"steps={produced}: {dt*1e3:.1f} ms total, {tps:.0f} tok/s "
          f"({dt/max(produced,1)*1e3:.2f} ms/step)")


if __name__ == "__main__":
    main()
