"""Joule attribution and windowed power — the energy twin of obs.attribution.

:func:`attribute_energy` decomposes a finished run's total energy per
**resource lane** (host control thread, config wire(s), each device's
compute datapath) into named components under the same hard conservation
invariant the cycle attribution enforces: on every lane,

    sum(components) == lane total energy   (idle and wake included)

where the lane total is computed *independently* of the classification —
host/compute lanes from the telemetry's busy-cycle counter × the attached
:class:`~repro.power.model.EnergyModel`, wire lanes from the per-transfer
energies the fabric logged at acquire time (which are the *plan-time*
figures ``fabric.transport`` priced, threaded through
``OverlapPolicy.stage`` → ``LinkPort.acquire`` → ``Transfer.energy`` —
so metering cannot drift from planning by even a rounding step). A
residual therefore catches both a dropped transfer and a double-counted
one, exactly as in the cycle profiler; ``EnergyReport.check()`` enforces
residual ≤ 0.1% per lane and is asserted on every exported trace.

Lane components:

* ``host`` / ``compute`` — ``active`` (busy cycles × active power),
  ``wake`` (one dead-time charge per idle→busy transition: merged busy
  spans), ``idle`` ((makespan − busy union) × gated idle power).
* ``wire`` — the logged transfer energies classified with the *same*
  launch-record matching the cycle attribution uses:
  ``exposed_transfer`` vs ``overlapped_transfer`` (each launch transfer's
  joules split by its recorded hidden fraction — note overlap hides
  *time*, never joules: the split shows which joules bought exposed
  wall-clock and which streamed behind compute), ``preempted_transfer``
  (a cancelled launch's bytes crossed; the macro-op never ran),
  ``other_transfer`` (non-launch traffic, e.g. a migration snapshot —
  and zero-*cycle* CSR transfers, whose handshake energy is real even
  though they occupy no wire time and so are invisible to the cycle
  lanes), plus ``wake`` / ``idle`` for the link's standing burn.

The windowed helpers at the bottom (:func:`window_energy`,
:func:`pool_window_energy`, :func:`max_window_energy`) price *live*
resource logs over a time window — the substrate for the ``power_draw``
monitor signal and the cluster power cap (``cluster.powercap``).
"""

from __future__ import annotations

from bisect import bisect_left, bisect_right, insort
from dataclasses import dataclass

from ..engine.resources import merge_intervals
from ..obs.attribution import launch_records
from .model import ZERO_ENERGY, EnergyModel


@dataclass(frozen=True)
class EnergyLane:
    """One resource lane's energy decomposition (pJ)."""

    lane: str  # e.g. "host", "h0/compute[h0/opengemm:0]", "cfg[pcie]:shared"
    kind: str  # "host" | "wire" | "compute"
    total: float  # independently computed lane energy
    components: dict  # category -> pJ; includes "idle" and "wake"
    residual: float  # |sum(components) - total|: gap or double-booking

    @property
    def active_energy(self) -> float:
        return sum(v for k, v in self.components.items()
                   if k not in ("idle", "wake"))

    @property
    def residual_fraction(self) -> float:
        return self.residual / self.total if self.total else 0.0


@dataclass(frozen=True)
class EnergyReport:
    """The full joule decomposition of one run."""

    makespan: float
    total_energy: float  # pJ, sum of lane totals
    lanes: dict  # lane name -> EnergyLane
    summary: dict  # run-level split (config/compute/idle/wake/...)

    @property
    def max_residual(self) -> float:
        """Worst lane residual as a fraction of that lane's energy — the
        CI gate's conservation number, joule edition."""
        return max((l.residual_fraction for l in self.lanes.values()),
                   default=0.0)

    @property
    def mean_power(self) -> float:
        """Average draw over the run, pJ/cycle (≡ mW at 1 GHz)."""
        return self.total_energy / self.makespan if self.makespan else 0.0

    def tokens_per_joule(self, tokens: float) -> float:
        return tokens / self.total_energy if self.total_energy else 0.0

    def check(self, tolerance: float = 1e-3) -> "EnergyReport":
        """Enforce conservation: per-lane components sum to the lane's
        independently computed total within ``tolerance`` (0.1%), and no
        component is negative. Chains: ``attribute_energy(rep).check()``."""
        for lane in self.lanes.values():
            assert lane.residual <= max(tolerance * lane.total, 1e-9), (
                f"lane {lane.lane}: energy residual {lane.residual} over "
                f"total {lane.total} — components {lane.components}")
            for key, val in lane.components.items():
                assert val >= -1e-9, (
                    f"lane {lane.lane}: negative {key} energy {val}")
        drift = abs(sum(l.total for l in self.lanes.values())
                    - self.total_energy)
        assert drift <= max(tolerance * self.total_energy, 1e-9), drift
        return self

    def to_dict(self) -> dict:
        return {
            "makespan": self.makespan,
            "total_energy": self.total_energy,
            "mean_power": self.mean_power,
            "max_residual": self.max_residual,
            "summary": dict(self.summary),
            "lanes": {
                name: {
                    "kind": lane.kind,
                    "total": lane.total,
                    "residual": lane.residual,
                    "residual_fraction": lane.residual_fraction,
                    "components": dict(lane.components),
                }
                for name, lane in sorted(self.lanes.items())
            },
        }


# -- lane builders ------------------------------------------------------------


def _wakeups(intervals: list) -> tuple[int, list]:
    """(idle→busy transitions, merged busy spans). Each merged span's
    start is one wake — back-to-back reservations share one wake-up."""
    spans = merge_intervals(intervals)
    return len(spans), spans


def _occupancy_lane(name: str, kind: str, makespan: float, busy_cycles: float,
                    intervals: list, model: EnergyModel) -> EnergyLane:
    """A host or compute lane: classification walks the interval log,
    the total reads the telemetry's busy-cycle counter — independent
    enough that a log/counter mismatch shows up as residual."""
    wakes, spans = _wakeups(intervals)
    union = sum(e - s for s, e in spans)
    idle = model.idle_energy(makespan - union)
    wake = model.wake_cost(wakes)
    components = {
        "active": model.active_energy(union),
        "wake": wake,
        "idle": idle,
    }
    total = model.active_energy(busy_cycles) + wake + idle
    classified = sum(components.values())
    return EnergyLane(lane=name, kind=kind, total=total,
                      components=components,
                      residual=abs(classified - total))


def _wire_lane(link_tel, makespan: float, records: list,
               lane_name: str) -> EnergyLane:
    """Classify each logged transfer's joules by matching the launch that
    reserved it — the same (wire_start, config_done) exact-float matching
    as obs.attribution._wire_lane, extended to zero-length transfers
    (their handshake energy is real; their cycles are not)."""
    model = link_tel.energy if isinstance(link_tel.energy, EnergyModel) \
        else ZERO_ENERGY
    pending: dict[tuple, list] = {}
    for rec, alive in records:
        if rec.config_done > rec.wire_start:
            pending.setdefault((rec.wire_start, rec.config_done),
                               []).append((rec, alive))
    exposed = overlapped = preempted = other = 0.0
    logged = 0.0
    intervals = []
    for entry in link_tel.log:
        start, end = entry[0], entry[1]
        energy = entry[5] if len(entry) > 5 else 0.0
        logged += energy
        length = end - start
        if length <= 0.0:
            # zero-cycle CSR transfer: no wire occupancy to match, but the
            # handshakes happened on the host's critical path → exposed
            exposed += energy
            continue
        intervals.append((start, end))
        matches = pending.get((start, end))
        if matches:
            rec, alive = matches.pop(0)
            if not alive:
                preempted += energy
            else:
                hidden = min(max(rec.hidden_config, 0.0), length)
                hidden_e = energy * (hidden / length)
                overlapped += hidden_e
                exposed += energy - hidden_e
        else:
            other += energy
    wakes, spans = _wakeups(intervals)
    union = sum(e - s for s, e in spans)
    idle = model.idle_energy(makespan - union)
    wake = model.wake_cost(wakes)
    components = {
        "exposed_transfer": exposed,
        "overlapped_transfer": overlapped,
        "preempted_transfer": preempted,
        "other_transfer": other,
        "wake": wake,
        "idle": idle,
    }
    total = logged + wake + idle
    classified = sum(components.values())
    return EnergyLane(lane=lane_name, kind="wire", total=total,
                      components=components,
                      residual=abs(classified - total))


def _resource_model(tel) -> EnergyModel:
    return tel.energy if isinstance(tel.energy, EnergyModel) else ZERO_ENERGY


def _scheduler_lanes(rep, makespan: float, records: list,
                     prefix: str = "") -> dict:
    lanes: dict[str, EnergyLane] = {}
    for name, tel in rep.resources.items():
        if tel.kind == "wire":
            continue  # wire joules come from the transfer log, below
        intervals = [(s, e) for s, e, _ in tel.intervals]
        lanes[prefix + name] = _occupancy_lane(
            prefix + name, tel.kind, makespan, tel.busy_cycles, intervals,
            _resource_model(tel))
    return lanes


def _summary(lanes: dict) -> dict:
    def lane_sum(kind: str, comp: str) -> float:
        return sum(l.components.get(comp, 0.0) for l in lanes.values()
                   if l.kind == kind)

    return {
        "host_energy": lane_sum("host", "active"),
        "compute_energy": lane_sum("compute", "active"),
        "exposed_transfer_energy": lane_sum("wire", "exposed_transfer"),
        "overlapped_transfer_energy": lane_sum("wire", "overlapped_transfer"),
        "preempted_transfer_energy": lane_sum("wire", "preempted_transfer"),
        "other_transfer_energy": lane_sum("wire", "other_transfer"),
        "wake_energy": sum(l.components.get("wake", 0.0)
                           for l in lanes.values()),
        "idle_energy": sum(l.components.get("idle", 0.0)
                           for l in lanes.values()),
    }


def _config_energy(summary: dict) -> float:
    """The run's configuration energy: host instruction issue plus every
    launch transfer's wire joules — the joule twin of config_cycles."""
    return (summary["host_energy"] + summary["exposed_transfer_energy"]
            + summary["overlapped_transfer_energy"]
            + summary["preempted_transfer_energy"])


# -- entry points -------------------------------------------------------------


def _attribute_scheduler(rep) -> EnergyReport:
    makespan = rep.makespan
    records = launch_records(rep)
    lanes = _scheduler_lanes(rep, makespan, records)
    for name, ltel in rep.links.items():
        lanes[name] = _wire_lane(ltel, makespan, records, name)
    summary = _summary(lanes)
    summary["config_energy"] = _config_energy(summary)
    return EnergyReport(
        makespan=makespan,
        total_energy=sum(l.total for l in lanes.values()),
        lanes=lanes,
        summary=summary,
    )


def _attribute_cluster(rep) -> EnergyReport:
    makespan = rep.makespan
    lanes: dict[str, EnergyLane] = {}
    # a shared cluster port appears once per host report with the *same*
    # full transfer log; fold it into one cluster-wide lane matched against
    # every sharer's launches — metering the one physical wire once
    shared: dict[str, list] = {}
    for host_id, hrep in sorted(rep.hosts.items()):
        records = launch_records(hrep)
        lanes.update(_scheduler_lanes(hrep, makespan, records,
                                      prefix=f"{host_id}/"))
        for name, ltel in hrep.links.items():
            if name.endswith(":shared"):
                entry = shared.setdefault(name, [ltel, []])
                entry[1].extend(records)
            else:
                lanes[f"{host_id}/{name}"] = _wire_lane(
                    ltel, makespan, records, f"{host_id}/{name}")
    for name, (ltel, records) in shared.items():
        lanes[name] = _wire_lane(ltel, makespan, records, name)
    summary = _summary(lanes)
    summary["config_energy"] = _config_energy(summary)
    return EnergyReport(
        makespan=makespan,
        total_energy=sum(l.total for l in lanes.values()),
        lanes=lanes,
        summary=summary,
    )


def attribute_energy(report) -> EnergyReport:
    """Decompose a run's joules per resource lane. Accepts a
    ``SchedulerReport``, a ``ClusterReport``, or a ``BridgeReport`` (which
    delegates to its cluster view) — duck-typed like ``obs.attribute``.
    Reports from runs without a :class:`~repro.power.model.PowerSpec`
    attribute to all-zero joules (and a zero-spec run reproduces the
    cycle-only report bit-exactly — the satellite pin)."""
    cluster = getattr(report, "cluster", None)
    if cluster is not None and hasattr(cluster, "hosts"):
        report = cluster
    if hasattr(report, "hosts"):
        return _attribute_cluster(report)
    return _attribute_scheduler(report)


# -- windowed power (live engines) --------------------------------------------


def _interval_overlap(start: float, end: float, t0: float, t1: float) -> float:
    return max(0.0, min(end, t1) - max(start, t0))


def resource_window_energy(res, t0: float, t1: float) -> float:
    """Joules a live :class:`~repro.engine.resources.Resource` burns in
    ``[t0, t1)``: busy overlap × active power, the remainder at the gated
    idle rate, plus a wake charge for each merged busy span *starting*
    inside the window. Adjacent windows therefore tile: summing them
    reproduces the run total (each wake counted exactly once)."""
    model = res.energy if isinstance(res.energy, EnergyModel) else ZERO_ENERGY
    spans = merge_intervals(res.intervals())
    busy = sum(_interval_overlap(s, e, t0, t1) for s, e in spans)
    wakes = sum(1 for s, _ in spans if t0 <= s < t1)
    return (model.active_energy(busy)
            + model.idle_energy((t1 - t0) - busy)
            + model.wake_cost(wakes))


def transfers_window_energy(log, t0: float, t1: float) -> float:
    """Wire joules of logged transfers prorated into ``[t0, t1)``.
    Zero-length transfers charge fully at their start instant."""
    total = 0.0
    for t in log:
        length = t.end - t.start
        if length <= 0.0:
            if t0 <= t.start < t1:
                total += t.energy
        else:
            total += t.energy * (_interval_overlap(t.start, t.end, t0, t1)
                                 / length)
    return total


def host_window_energy(host, t0: float, t1: float, *,
                       include_port: bool = True) -> float:
    """Joules one live ``cluster.Host``'s engine burns in ``[t0, t1)``:
    every engine resource's occupancy energy plus (optionally) its port's
    transfer joules. Pass ``include_port=False`` for sharers of a cluster
    port — the pool aggregator meters the shared wire once."""
    sched = host.sched
    total = sum(resource_window_energy(res, t0, t1)
                for res in sched.res.all().values()
                if include_port or res is not sched.res.wire)
    if include_port:
        total += transfers_window_energy(sched.port.log, t0, t1)
    return total


def pool_window_energy(hosts, t0: float, t1: float) -> float:
    """Joules the whole pool burns in ``[t0, t1)``. A port shared by
    several hosts is counted exactly once (dedup by port identity)."""
    seen_ports: set[int] = set()
    total = 0.0
    for host in hosts:
        port = host.sched.port
        first = id(port) not in seen_ports
        seen_ports.add(id(port))
        total += host_window_energy(host, t0, t1, include_port=first)
    return total


def pool_window_power(hosts, t0: float, t1: float) -> float:
    """Mean pool draw over ``[t0, t1)``, pJ/cycle."""
    return pool_window_energy(hosts, t0, t1) / (t1 - t0) if t1 > t0 else 0.0


def _edge_candidates(hosts) -> list[float]:
    edges: set[float] = {0.0}
    for host in hosts:
        for res in host.sched.res.all().values():
            for s, e, _ in res.intervals():
                edges.add(s)
                edges.add(e)
        for t in host.sched.port.log:
            edges.add(t.start)
            edges.add(t.end)
    return sorted(edges)


def max_window_energy(hosts, window: float,
                      start_from: float = 0.0) -> tuple[float, float]:
    """(worst-case joules in any ``window``-cycle span starting at or
    after ``start_from``, the span's start). Candidate window positions
    are interval edges and edges − window: the window energy is piecewise
    linear in the start position, so its maximum sits at a breakpoint —
    scanning edges is exact, not a sampling approximation."""
    return PoolEnergySnapshot(hosts).max_window(window, start_from)


class _Track:
    """Sorted non-overlapping weighted spans with a prefix-summed
    integral: ``integral(t0, t1)`` in O(log n) instead of a full scan —
    the difference between the power cap's admission check being linear
    or quadratic in the number of committed launches."""

    def __init__(self, spans):  # [(start, end, density)], sorted, disjoint
        self.starts = [s for s, _, _ in spans]
        self.ends = [e for _, e, _ in spans]
        self.dens = [d for _, _, d in spans]
        self.cum = [0.0]
        for s, e, d in spans:
            self.cum.append(self.cum[-1] + (e - s) * d)

    def integral(self, t0: float, t1: float) -> float:
        i = bisect_right(self.ends, t0)  # first span ending after t0
        j = bisect_left(self.starts, t1)  # first span starting at/after t1
        if i >= j:
            return 0.0
        total = self.cum[j] - self.cum[i]
        total -= max(0.0, t0 - self.starts[i]) * self.dens[i]
        total -= max(0.0, self.ends[j - 1] - t1) * self.dens[j - 1]
        return total

    def count_starts(self, t0: float, t1: float) -> int:
        return bisect_left(self.starts, t1) - bisect_left(self.starts, t0)

    def append(self, s: float, e: float, d: float) -> bool:
        """Append a span known to start at/after every existing span
        (engine logs grow at the frontier). Returns False — caller must
        rebuild — if the new span lands out of order."""
        if e <= s:
            return True  # zero-length occupancy carries no energy or wake
        if self.starts and s < self.starts[-1]:
            return False
        if self.ends and s <= self.ends[-1]:
            if d != self.dens[-1]:
                return False
            if e > self.ends[-1]:  # same-density overlap: extend in place
                self.cum[-1] += (e - self.ends[-1]) * d
                self.ends[-1] = e
            return True
        self.starts.append(s)
        self.ends.append(e)
        self.dens.append(d)
        self.cum.append(self.cum[-1] + (e - s) * d)
        return True


class PoolEnergySnapshot:
    """Frozen O(log n)-queryable view of a pool's committed energy.

    Built from the live engine logs (merged busy spans per resource, the
    transfer log per physical port — shared resources/ports deduped by
    identity, matching :func:`pool_window_energy` exactly), then
    :meth:`window_energy` prices any ``[t0, t1)`` via prefix sums. The
    power cap builds one snapshot per run and calls :meth:`extend` after
    each dispatch: engine logs are append-only and grow at the frontier,
    so new spans merge onto the track tails in O(1) — if a log ever grows
    out of order, the snapshot falls back to a full rebuild."""

    def __init__(self, hosts):
        self._hosts = list(hosts)
        self._build()

    def _build(self) -> None:
        edges: set[float] = {0.0}
        self._res: list[tuple[EnergyModel, _Track]] = []
        self._xfer: list[_Track] = []  # streaming transfers, density pJ/cyc
        self._imp_ts: list[list[float]] = []  # zero-length transfer instants
        self._imp_cum: list[list[float]] = []
        self._res_src: list = []  # (res, track, consumed log length)
        self._port_src: list = []  # (port, slot index, consumed log length)
        seen: set[int] = set()
        for host in self._hosts:
            sched = host.sched
            for res in sched.res.all().values():
                if id(res) in seen:
                    continue  # a shared wire belongs to the pool, once
                seen.add(id(res))
                model = (res.energy if isinstance(res.energy, EnergyModel)
                         else ZERO_ENERGY)
                spans = merge_intervals(res.intervals())
                for s, e in spans:
                    edges.add(s)
                    edges.add(e)
                track = _Track([(s, e, 1.0) for s, e in spans])
                self._res.append((model, track))
                self._res_src.append((res, track, len(res.log)))
            port = sched.port
            if id(port) in seen:
                continue
            seen.add(id(port))
            streamed, impulses = [], []
            for t in port.log:
                edges.add(t.start)
                edges.add(t.end)
                length = t.end - t.start
                if length <= 0.0:
                    impulses.append((t.start, t.energy))
                else:
                    streamed.append((t.start, t.end, t.energy / length))
            self._port_src.append((port, len(self._xfer), len(port.log)))
            self._xfer.append(_Track(sorted(streamed)))
            impulses.sort()
            cum = [0.0]
            for _, en in impulses:
                cum.append(cum[-1] + en)
            self._imp_ts.append([ts for ts, _ in impulses])
            self._imp_cum.append(cum)
        self.edges: list[float] = sorted(edges)

    def extend(self) -> None:
        """Fold log entries appended since the last build/extend into the
        tracks. O(new entries) on the frontier-append fast path."""
        new_edges: list[float] = []
        for i, (res, track, done) in enumerate(self._res_src):
            log = res.log
            for iv in log[done:]:
                if not track.append(iv.start, iv.end, 1.0):
                    self._build()  # out-of-order growth: start over
                    return
                if iv.end > iv.start:
                    new_edges.append(iv.start)
                    new_edges.append(iv.end)
            self._res_src[i] = (res, track, len(log))
        for i, (port, slot, done) in enumerate(self._port_src):
            log = port.log
            for t in log[done:]:
                length = t.end - t.start
                if length <= 0.0:
                    ts, cum = self._imp_ts[slot], self._imp_cum[slot]
                    if ts and t.start < ts[-1]:
                        self._build()
                        return
                    ts.append(t.start)
                    cum.append(cum[-1] + t.energy)
                elif not self._xfer[slot].append(t.start, t.end,
                                                t.energy / length):
                    self._build()
                    return
                new_edges.append(t.start)
                new_edges.append(t.end)
            self._port_src[i] = (port, slot, len(log))
        for e in new_edges:  # near-frontier inserts: short memmove tails
            if not self.edges or e >= self.edges[-1]:
                self.edges.append(e)
            else:
                insort(self.edges, e)

    def window_energy(self, t0: float, t1: float) -> float:
        total = 0.0
        for model, track in self._res:
            busy = track.integral(t0, t1)
            total += (model.active_energy(busy)
                      + model.idle_energy((t1 - t0) - busy)
                      + model.wake_cost(track.count_starts(t0, t1)))
        for track in self._xfer:
            total += track.integral(t0, t1)
        for ts, cum in zip(self._imp_ts, self._imp_cum):
            total += cum[bisect_left(ts, t1)] - cum[bisect_left(ts, t0)]
        return total

    def max_window(self, window: float,
                   start_from: float = 0.0) -> tuple[float, float]:
        assert window > 0.0, window
        candidates = {start_from}
        for e in self.edges:
            if e >= start_from:
                candidates.add(e)
            if e - window >= start_from:
                candidates.add(e - window)
        worst, at = 0.0, start_from
        for t0 in sorted(candidates):
            energy = self.window_energy(t0, t0 + window)
            if energy > worst:
                worst, at = energy, t0
        return worst, at

    def next_breakpoint(self, admit: float, window: float) -> float | None:
        """The earliest admission time past ``admit`` at which the
        worst-window figure (over windows starting ≥ admit − window) can
        change: the next edge, or the next edge to leave the trailing
        window. None once admission is past every committed edge."""
        i = bisect_right(self.edges, admit)
        c1 = self.edges[i] if i < len(self.edges) else None
        # an edge barely above admit − window can round back to exactly
        # admit when the window is added — skip candidates that do not
        # strictly advance, or the caller's stepping loop never moves
        c2 = None
        j = bisect_right(self.edges, admit - window)
        while j < len(self.edges):
            cand = self.edges[j] + window
            if cand > admit:
                c2 = cand
                break
            j += 1
        if c1 is None:
            return c2
        if c2 is None or c1 <= c2:
            return c1
        return c2

    def _candidates_desc(self, lo: float, window: float):
        """Candidate window starts (edges and edges − window) at or after
        ``lo``, yielded in strictly descending order."""
        i = j = len(self.edges) - 1
        prev = None
        while i >= 0 or j >= 0:
            a = self.edges[i] if i >= 0 else None
            b = self.edges[j] - window if j >= 0 else None
            if b is None or (a is not None and a >= b):
                c = a
                i -= 1
            else:
                c = b
                j -= 1
            if c < lo:
                return  # merged stream is descending: nothing ≥ lo remains
            if c != prev:
                prev = c
                yield c

    def earliest_admission(self, arrival: float, window: float,
                           threshold: float) -> float:
        """Earliest time at/after ``arrival`` to admit work whose energy
        bound needs every window starting at/after admission − window to
        hold at most ``threshold`` pJ.

        Candidate windows are scanned newest-first: the scan stops at the
        *last* over-threshold window, so under a binding cap (hot windows
        sit at the commit frontier) it exits within a few evaluations
        instead of sweeping the whole backlog. Admission lands just past
        that window; the trailing window ``[admit − window, admit]`` —
        the one window whose start is not an edge — is then stepped over
        breakpoints until it, too, fits. The caller's feasibility asserts
        (idle floor + bound under budget) guarantee termination: past the
        last committed edge only idle burn remains."""
        lo = arrival - window
        last_bad = None
        for c in self._candidates_desc(lo, window):
            if self.window_energy(c, c + window) > threshold:
                last_bad = c
                break
        admit = arrival
        if last_bad is not None:
            nxt = self.next_breakpoint(last_bad + window, window)
            assert nxt is not None, "hot window past every committed edge"
            admit = max(arrival, nxt)
        while self.window_energy(admit - window, admit) > threshold:
            nxt = self.next_breakpoint(admit, window)
            assert nxt is not None, (
                "no later admission point despite a feasible cap")
            admit = nxt
        return admit


# -- trace export -------------------------------------------------------------


def power_counter_series(report) -> dict[str, list[tuple[float, float]]]:
    """Per-lane (timestamp, pJ/cycle draw) step series for the Chrome
    trace's counter lanes: each lane steps to its active power at every
    busy-interval start and back to its gated idle rate at the end —
    drawn from the same telemetry the energy attribution meters."""
    cluster = getattr(report, "cluster", None)
    if cluster is not None and hasattr(cluster, "hosts"):
        report = cluster
    host_reps = (sorted(report.hosts.items())
                 if hasattr(report, "hosts") else [("", report)])
    series: dict[str, list[tuple[float, float]]] = {}
    seen_shared: set[str] = set()
    for host_id, rep in host_reps:
        prefix = f"{host_id}/" if host_id else ""
        for name, tel in rep.resources.items():
            model = _resource_model(tel)
            if model is ZERO_ENERGY:
                continue
            lane = name if name.endswith(":shared") else prefix + name
            if name.endswith(":shared"):
                if name in seen_shared:
                    continue
                seen_shared.add(name)
            points: list[tuple[float, float]] = [(0.0, model.idle_rate)]
            for s, e in merge_intervals(tel.intervals):
                points.append((s, model.active_power))
                points.append((e, model.idle_rate))
            series[lane] = points
    return series
