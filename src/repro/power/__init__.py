"""repro.power — the joule axis of the configuration wall.

Energy models for engine resources (:mod:`~repro.power.model`), a
conservation-checked joule attribution + windowed power meter over
finished runs and live engines (:mod:`~repro.power.meter`), and the
plan-time transfer pricing lives with the fabric
(``fabric.link.LinkModel`` energy rates, ``fabric.transport``'s
``objective`` knob) so mode choice and metering read the same numbers.
"""

from .meter import (
    EnergyLane,
    EnergyReport,
    PoolEnergySnapshot,
    attribute_energy,
    host_window_energy,
    max_window_energy,
    pool_window_energy,
    pool_window_power,
    power_counter_series,
    resource_window_energy,
    transfers_window_energy,
)
from .model import (
    DEFAULT_ENERGY_PER_OP,
    HOST_ACTIVE_POWER,
    ZERO_ENERGY,
    EnergyModel,
    PowerSpec,
)

__all__ = [
    "DEFAULT_ENERGY_PER_OP",
    "HOST_ACTIVE_POWER",
    "ZERO_ENERGY",
    "EnergyLane",
    "EnergyModel",
    "EnergyReport",
    "PowerSpec",
    "attribute_energy",
    "host_window_energy",
    "max_window_energy",
    "pool_window_energy",
    "pool_window_power",
    "power_counter_series",
    "resource_window_energy",
    "transfers_window_energy",
]
