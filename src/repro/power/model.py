"""Energy models for the engine's resources — the joule axis of the wall.

The paper's opening motivation is performance *per Watt*, yet everything
upstream of this module prices the configuration wall in cycles only.
"Know your rooflines!" (Verhelst et al.) argues the roofline family must
be extended along the energy axis; the neuromorphic bottleneck study
shows config/setup phases can dominate *energy* even when cycle counts
look healthy — MMIO's per-write handshakes burn joules that burst DMA
amortizes, and an idle-but-not-gated PCIe serdes burns them doing
nothing. This module supplies the rates; :mod:`repro.power.meter` turns
a finished run's busy-interval logs into a conservation-checked joule
attribution.

Three pieces:

* :class:`EnergyModel` — one resource's static rates: active power per
  busy cycle, idle power per idle cycle, a clock-gating factor scaling
  the idle burn (0 = perfect gating, 1 = no gating), and a wake-up /
  dead-time energy paid on every idle→busy transition (PLL relock,
  pipeline refill — the ESL-CGRA characterization's dead-time term).
* :class:`PowerSpec` — the rates for one scheduler's whole engine:
  ``host`` (the control thread), ``compute`` keyed by accelerator model
  name, ``wire`` keyed by link kind (idle/wake only — the wire's *busy*
  energy is per-transaction, priced on the
  :class:`~repro.fabric.link.LinkModel` itself so the transport layer's
  joule-objective mode choice and the meter read the same numbers).

Units are nominal picojoules with the cycle as the time unit, so
``active_power`` reads as pJ/cycle (≡ mW at 1 GHz) and every total is in
pJ. Nothing downstream depends on the unit — only on ratios.

All of this is observation-only: attaching an ``EnergyModel`` to a
resource never moves a clock, and a zero spec reproduces every cycle
report unchanged (pinned in ``tests/test_power.py``). The single place
energy may change *timing* is the explicit ``objective="joules"|"edp"``
transport knob (:func:`repro.fabric.transport.plan_fields`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

from ..core.accelerators import REGISTRY
from ..fabric.transport import HOST_ENERGY_PER_CYCLE

__all__ = ["EnergyModel", "PowerSpec", "ZERO_ENERGY",
           "DEFAULT_ENERGY_PER_OP", "HOST_ACTIVE_POWER"]

# the host control thread's active power, pJ per busy cycle — the *same*
# constant fabric.transport prices plan-time host energy with, so the
# joule objective and the meter can never disagree on what a cycle costs
HOST_ACTIVE_POWER = HOST_ENERGY_PER_CYCLE

# default datapath efficiency for REGISTRY models without explicit rates:
# active power = p_peak × this (pJ per op at full tilt)
DEFAULT_ENERGY_PER_OP = 0.25


@dataclass(frozen=True)
class EnergyModel:
    """Static power/energy rates of one serially-occupied resource."""

    active_power: float  # pJ per busy cycle
    idle_power: float = 0.0  # pJ per idle cycle, before gating
    gating: float = 1.0  # fraction of idle_power burned when idle (0..1]
    wake_energy: float = 0.0  # pJ dead-time cost per idle→busy transition

    def __post_init__(self) -> None:
        assert self.active_power >= 0.0, self.active_power
        assert self.idle_power >= 0.0, self.idle_power
        assert 0.0 <= self.gating <= 1.0, self.gating
        assert self.wake_energy >= 0.0, self.wake_energy

    @property
    def idle_rate(self) -> float:
        """Effective idle burn, pJ per idle cycle (gating applied)."""
        return self.idle_power * self.gating

    def active_energy(self, busy_cycles: float) -> float:
        return busy_cycles * self.active_power

    def idle_energy(self, idle_cycles: float) -> float:
        return max(0.0, idle_cycles) * self.idle_rate

    def wake_cost(self, wakeups: int) -> float:
        return wakeups * self.wake_energy


ZERO_ENERGY = EnergyModel(0.0, 0.0, 1.0, 0.0)


def _default_compute() -> dict[str, EnergyModel]:
    return {
        name: EnergyModel(
            active_power=model.p_peak * DEFAULT_ENERGY_PER_OP,
            idle_power=model.p_peak * DEFAULT_ENERGY_PER_OP * 0.1,
            gating=0.25,
            wake_energy=500.0,
        )
        for name, model in REGISTRY.items()
    }


def _default_wire() -> dict[str, EnergyModel]:
    # wire *busy* energy is per-transaction (LinkModel.transfer_energy);
    # these rates cover only the link's standing burn: a NoC router idles
    # cheap and gates well, a PCIe serdes burns real power just keeping
    # the lanes trained and pays a long recalibration on wake
    return {
        "csr": ZERO_ENERGY,
        "noc": EnergyModel(active_power=0.0, idle_power=0.5, gating=0.5,
                           wake_energy=20.0),
        "pcie": EnergyModel(active_power=0.0, idle_power=30.0, gating=0.8,
                            wake_energy=1000.0),
    }


@dataclass(frozen=True)
class PowerSpec:
    """The energy rates one scheduler's engine resources run at."""

    host: EnergyModel
    compute: Mapping[str, EnergyModel] = field(default_factory=dict)
    wire: Mapping[str, EnergyModel] = field(default_factory=dict)

    def compute_model(self, model_name: str) -> EnergyModel:
        return self.compute.get(model_name, ZERO_ENERGY)

    def wire_model(self, link_kind: str) -> EnergyModel:
        return self.wire.get(link_kind, ZERO_ENERGY)

    @classmethod
    def default(cls) -> "PowerSpec":
        """Nominal rates for every REGISTRY model and link kind: host at
        :data:`HOST_ACTIVE_POWER`, datapaths at
        :data:`DEFAULT_ENERGY_PER_OP` per op."""
        return cls(
            host=EnergyModel(active_power=HOST_ACTIVE_POWER, idle_power=0.25,
                             gating=0.4, wake_energy=50.0),
            compute=_default_compute(),
            wire=_default_wire(),
        )

    @classmethod
    def zero(cls) -> "PowerSpec":
        """All-zero occupancy rates: metering under this spec yields zero
        active/idle/wake joules on every lane — the regression pin that
        attaching energy observability cannot perturb cycle-only reports.
        Wire *transfer* joules are a property of the LinkModel, not of
        this spec, so launch traffic still meters its handshake/byte
        energy (zero only on links priced at zero)."""
        return cls(host=ZERO_ENERGY)
