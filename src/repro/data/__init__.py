from .pipeline import PrefetchIterator, SyntheticLMDataset, make_train_iterator

__all__ = ["PrefetchIterator", "SyntheticLMDataset", "make_train_iterator"]
