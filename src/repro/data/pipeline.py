"""Data pipeline: deterministic synthetic token stream + host-side prefetch.

Two configuration-wall-relevant properties:

* **Determinism & shardability** — batch ``i`` for data-shard ``s`` is a pure
  function of ``(seed, i, s)``, so any host in a multi-pod job can produce
  exactly its shard without coordination, and elastic rescaling (a host
  taking over another's shard range) needs no data-state handoff.

* **Prefetch = configuration–computation overlap** — the background thread
  prepares batch N+1 (the host-side "configuration" of the next launch)
  while the device runs step N, which is precisely the paper's §5.5 overlap
  applied at the data layer. ``repro.dispatch`` measures the win.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticLMDataset:
    """Zipf-distributed token stream with next-token labels."""

    vocab_size: int
    seq_len: int
    batch_size: int  # per-shard batch
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, n_shards])
        )
        raw = rng.zipf(self.zipf_a, size=(self.batch_size, self.seq_len + 1))
        tokens = np.minimum(raw - 1, self.vocab_size - 1).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class PrefetchIterator:
    """Wraps a ``step -> batch`` function with a background prefetch thread."""

    def __init__(self, fetch, depth: int = 2, start_step: int = 0):
        self._fetch = fetch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fetch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)


def make_train_iterator(
    vocab_size: int,
    seq_len: int,
    batch_size: int,
    *,
    seed: int = 0,
    shard: int = 0,
    n_shards: int = 1,
    prefetch: int = 2,
    start_step: int = 0,
) -> PrefetchIterator:
    ds = SyntheticLMDataset(vocab_size, seq_len, batch_size, seed)
    return PrefetchIterator(
        lambda step: ds.batch(step, shard, n_shards),
        depth=prefetch,
        start_step=start_step,
    )
