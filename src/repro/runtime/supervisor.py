"""Fault-tolerant training runtime: checkpoint/restart, straggler
mitigation, elastic rescaling.

At 1000+-node scale the train loop is a supervised process:

* **Checkpoint/restart** — periodic async checkpoints (atomic, CRC-checked,
  see ``repro.checkpoint``); any exception inside a step (preemption, ICI
  link flap, host OOM) rolls back to the last complete step and replays.
  Data determinism (``repro.data``) makes the replay exact.
* **Straggler mitigation** — per-step wall times feed a rolling median; a
  step exceeding ``factor ×`` the median is flagged and counted. On real
  pods the hook triggers requeueing of the slow host; here it is observable
  state the tests assert on.
* **Elastic rescaling** — ``reshard`` places a restored state onto a new
  mesh's shardings (grow or shrink the data axis between restarts); the
  deterministic data shards re-partition with no coordination.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import CheckpointStore


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 32
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        history = self.times[-self.window :]
        self.times.append(dt)
        if len(history) < 5:
            return False
        median = sorted(history)[len(history) // 2]
        if dt > self.factor * median:
            self.flagged.append((step, dt, median))
            return True
        return False


class TrainSupervisor:
    def __init__(
        self,
        step_fn,  # (state, batch) -> state  (jitted train step)
        store: CheckpointStore,
        *,
        ckpt_every: int = 50,
        straggler_factor: float = 3.0,
        max_restarts: int = 3,
    ):
        self.step_fn = step_fn
        self.store = store
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor(factor=straggler_factor)
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state, batch_fn, n_steps: int, *, fault_hook=None):
        """Run to ``n_steps``; ``fault_hook(step)`` may raise to simulate a
        node failure — the supervisor restores and replays."""
        step = 0
        if self.store.latest_step() is not None:
            step = self.store.latest_step()
            state = self.store.restore(step, state)
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if fault_hook is not None:
                    fault_hook(step)
                batch = batch_fn(step)
                state = self.step_fn(state, batch)
                if (step + 1) % self.ckpt_every == 0 or step + 1 == n_steps:
                    jax.block_until_ready(state)
                    self.store.save(step + 1, state, blocking=False)
                self.monitor.observe(step, time.perf_counter() - t0)
                step += 1
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.store.wait()
                last = self.store.latest_step()
                if last is None:
                    step = 0  # no checkpoint yet: replay from scratch
                    continue
                state = self.store.restore(last, state)
                step = last
        self.store.wait()
        return state

    # ------------------------------------------------------------- elasticity

    @staticmethod
    def reshard(state, new_shardings):
        """Place a state tree onto a new mesh's shardings (elastic rescale)."""
        return jax.tree.map(
            lambda x, sh: jax.device_put(x, sh), state, new_shardings
        )
