from .supervisor import StragglerMonitor, TrainSupervisor

__all__ = ["StragglerMonitor", "TrainSupervisor"]
