"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887; hf]. One attention layer per 8-layer block, MoE every
other layer (the published Jamba recipe, reproducing the ~398B total /
~94B active split).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_period=2,
    attn_period=8,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    notes="hybrid: runs long_500k (sub-quadratic: 63/72 layers are Mamba)",
)
