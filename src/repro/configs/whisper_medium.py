"""whisper-medium [audio] — encoder-decoder, conv frontend (STUB).

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]

The conv1d+GELU audio frontend is a stub per the assignment:
``input_specs()`` provides 1500 precomputed frame embeddings (30 s at 50 Hz
after the 2× downsampling conv stack). GELU 2-matrix MLPs, LayerNorm, tied
decoder embedding, sinusoidal positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    n_encoder_layers=24,
    encoder_seq_len=1500,
    mlp_kind="gelu",
    tie_embeddings=True,
    frontend="audio_stub",
)
