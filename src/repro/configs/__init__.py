"""Architecture registry: the 10 assigned architectures + the paper-native LM,
and the 4 assigned input shapes, with applicability rules.

Select with ``--arch <id>`` in the launchers; every (arch × shape) pair that
:func:`applicable` admits is a dry-run cell.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

from . import (
    jamba_1_5_large_398b,
    kimi_k2_1t_a32b,
    minitron_4b,
    paper_lm_100m,
    phi3_5_moe_42b_a6_6b,
    phi4_mini_3_8b,
    phi_3_vision_4_2b,
    qwen2_0_5b,
    qwen2_5_32b,
    rwkv6_7b,
    whisper_medium,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        jamba_1_5_large_398b,
        phi3_5_moe_42b_a6_6b,
        kimi_k2_1t_a32b,
        phi4_mini_3_8b,
        qwen2_5_32b,
        minitron_4b,
        qwen2_0_5b,
        phi_3_vision_4_2b,
        whisper_medium,
        rwkv6_7b,
    )
}

EXTRAS: dict[str, ModelConfig] = {paper_lm_100m.CONFIG.name: paper_lm_100m.CONFIG}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def get(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in EXTRAS:
        return EXTRAS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(EXTRAS)}")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 500k decode skipped per assignment"
    return True, ""


def cells(include_inapplicable: bool = False):
    """All (arch, shape) dry-run cells in a stable order."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = applicable(arch, shape)
            if ok or include_inapplicable:
                out.append((arch, shape, ok, reason))
    return out
