"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536.
[arXiv:2404.05892; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # 4096 / 64 rwkv heads
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    notes="attention-free: runs long_500k with O(1) decode state",
)
