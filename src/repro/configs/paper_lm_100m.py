"""paper-lm-100m — the ~100M-parameter dense LM used by the end-to-end
training example and the dispatch/configuration-wall benchmarks (the paper's
own evaluation is a GEMM workload; this is the framework-native stand-in)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
)
