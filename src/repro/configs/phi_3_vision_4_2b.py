"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP vision tower (STUB).

32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32064.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Per the assignment the modality frontend is a stub: ``input_specs()``
provides 576 precomputed patch embeddings (CLIP ViT-L/14 @ 336px) prepended
to the token sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision_stub",
    frontend_tokens=576,
)
