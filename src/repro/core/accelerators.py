"""Cycle-approximate models of register-configured accelerators.

The paper evaluates on two open-source RISC-V systems:

* **Gemmini** [19] — 16×16 systolic array behind a Rocket host. *Sequential*
  configuration: the host stalls while the accelerator runs (§2.2, §2.4).
  Config is conveyed by RoCC custom instructions carrying 16 bytes each; a
  load-store host needs ~2 register loads + 1 custom instruction per write, at
  ~3 cycles/instruction [17] ⇒ BW_config = 16/9 ≈ 1.77 B/cycle (§4.6).
* **OpenGeMM** [47] — 8×8×8 GeMM datapath (1024 ops/cycle) behind a tiny
  in-order Snitch core. *Concurrent* configuration: CSR writes can stage the
  next invocation while the accelerator runs (§6.2).

We reproduce those two points in the design space as parameterized
:class:`AcceleratorModel` instances. The models are deliberately simple —
everything the paper's roofline needs: a peak rate, a configuration-write cost,
a host CPI for parameter calculation (effective bandwidth, Eq. 4), and the
sequential/concurrent distinction.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class AcceleratorModel:
    name: str
    p_peak: float  # macro-op datapath throughput, ops/cycle
    concurrent: bool  # supports concurrent (staged) configuration?
    host_cpi: float  # host cycles per instruction (param calculation, Eq. 4)
    bytes_per_field: int  # config bytes conveyed per setup field
    fields_per_write: int  # fields per config-write instruction (RoCC: 2)
    instrs_per_write: int  # host instructions per config write
    launch_instrs: int = 1  # host instructions to issue the launch itself
    launch_latency: float = 0.0  # fixed pipeline-fill cycles per macro-op
    # register names used to derive the macro-op size: ops = 2 * M * K * N
    dim_fields: tuple[str, str, str] = ("M", "K", "N")
    # datapath tile (M, K, N): one grid step of the calibrated compute model
    # covers one tile, so ⌈M/tm⌉·⌈K/tk⌉·⌈N/tn⌉ issue cycles price the loop
    # control the flat macro_cycles model ignores (engine.costmodel)
    tile: tuple[int, int, int] = (8, 8, 8)

    # -- derived quantities (the roofline inputs) ---------------------------

    @property
    def config_write_cycles(self) -> float:
        """Host cycles to convey one setup field to the accelerator."""
        return self.instrs_per_write * self.host_cpi / self.fields_per_write

    @property
    def bw_config(self) -> float:
        """Theoretical configuration bandwidth, bytes/cycle (§4.2)."""
        return self.bytes_per_field / self.config_write_cycles

    def macro_ops(self, regs: dict[str, int]) -> int:
        m, k, n = (int(regs.get(f, 0)) for f in self.dim_fields)
        return 2 * m * k * n

    def macro_cycles(self, regs: dict[str, int]) -> float:
        return self.launch_latency + self.macro_ops(regs) / self.p_peak


def gemmini_like() -> AcceleratorModel:
    """Sequential-configuration point: Gemmini's weight-stationary flow.

    16×16 PEs × (mul+acc) = 512 ops/cycle; Rocket host at ~3 cycles/instr;
    RoCC writes convey two 8-byte fields in 3 instructions ⇒ 16 B / 9 cycles
    ≈ 1.77 B/cycle, exactly the paper's §4.6 estimate.
    """
    return AcceleratorModel(
        name="gemmini",
        p_peak=512.0,
        concurrent=False,
        host_cpi=3.0,
        bytes_per_field=8,
        fields_per_write=2,
        instrs_per_write=3,
        launch_instrs=1,
        launch_latency=16.0,  # systolic fill
        dim_fields=("I", "K", "J"),
        tile=(16, 16, 16),
    )


def opengemm_like() -> AcceleratorModel:
    """Concurrent-configuration point: OpenGeMM.

    8×8×8 MACs × 2 = 1024 ops/cycle; single-issue Snitch host (CPI ≈ 1);
    one 4-byte CSR per field at ~2 instructions (addi+csrw) per write.
    """
    return AcceleratorModel(
        name="opengemm",
        p_peak=1024.0,
        concurrent=True,
        host_cpi=1.0,
        bytes_per_field=4,
        fields_per_write=1,
        instrs_per_write=2,
        launch_instrs=1,
        launch_latency=8.0,
        dim_fields=("M", "K", "N"),
    )


REGISTRY: dict[str, AcceleratorModel] = {}


def register(model: AcceleratorModel) -> AcceleratorModel:
    REGISTRY[model.name] = model
    return model


register(gemmini_like())
register(opengemm_like())
