"""A minimal SSA intermediate representation for the ``accfg`` abstraction.

This is a faithful, self-contained re-implementation of the paper's MLIR/xDSL
dialect stack in pure Python. It models exactly the dialects the paper's passes
operate on:

* ``accfg``  — ``setup`` / ``launch`` / ``await`` plus ``!accfg.state`` and
  ``!accfg.token`` types (The Configuration Wall, §5.1).
* ``arith``  — integer constants and the bit-packing arithmetic that dominates
  effective configuration bandwidth (§4.4, Listing 1).
* ``scf``    — structured control flow (``for`` with iter_args, ``if``/``else``)
  that the state-tracing and overlap passes rewrite (§5.3-§5.5).
* ``func``   — functions and opaque external calls, which act as optimization
  barriers unless annotated with ``effects`` (§5.1's ``#accfg.effects<...>``).

The IR is deliberately small but structurally honest: ops hold operands (SSA
values), attributes (compile-time constants), results and regions; regions hold
a single block with block arguments. All passes mutate this structure in place,
as MLIR rewrites do.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional

# --------------------------------------------------------------------------
# Types
# --------------------------------------------------------------------------

I64 = "i64"
I1 = "i1"
INDEX = "index"
STATE = "!accfg.state"
TOKEN = "!accfg.token"

_counter = itertools.count()


def _fresh(prefix: str = "v") -> str:
    return f"%{prefix}{next(_counter)}"


# --------------------------------------------------------------------------
# Core structures
# --------------------------------------------------------------------------


@dataclass(eq=False)
class Value:
    """An SSA value. Identity (``is``) equality — the dedup pass relies on the
    SSA property that a value never changes after definition (§5.4)."""

    type: str
    name: str = field(default_factory=_fresh)
    owner: Optional["Op"] = None  # producing op; None for block arguments
    block: Optional["Block"] = None  # owning block if a block argument

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{self.name}: {self.type}"

    @property
    def is_block_arg(self) -> bool:
        return self.owner is None and self.block is not None


@dataclass(eq=False)
class Block:
    args: list[Value] = field(default_factory=list)
    ops: list["Op"] = field(default_factory=list)
    parent: Optional["Region"] = None

    def add_arg(self, type: str, name: str | None = None) -> Value:
        v = Value(type=type, name=name or _fresh("arg"))
        v.block = self
        self.args.append(v)
        return v

    def insert_before(self, anchor: "Op", op: "Op") -> None:
        op.parent = self
        self.ops.insert(self.ops.index(anchor), op)

    def insert_after(self, anchor: "Op", op: "Op") -> None:
        op.parent = self
        self.ops.insert(self.ops.index(anchor) + 1, op)

    def append(self, op: "Op") -> None:
        op.parent = self
        self.ops.append(op)

    def remove(self, op: "Op") -> None:
        self.ops.remove(op)
        op.parent = None


@dataclass(eq=False)
class Region:
    block: Block = field(default_factory=Block)
    parent: Optional["Op"] = None

    def __post_init__(self) -> None:
        self.block.parent = self


@dataclass(eq=False)
class Op:
    """A generic operation. ``name`` is the fully-qualified op name such as
    ``accfg.setup``; semantics live in the passes/interpreter, like MLIR."""

    name: str
    operands: list[Value] = field(default_factory=list)
    attrs: dict[str, Any] = field(default_factory=dict)
    result_types: list[str] = field(default_factory=list)
    regions: list[Region] = field(default_factory=list)
    parent: Optional[Block] = None

    results: list[Value] = field(init=False)

    def __post_init__(self) -> None:
        self.results = [Value(type=t, owner=self) for t in self.result_types]
        for r in self.regions:
            r.parent = self

    # -- convenience -------------------------------------------------------

    @property
    def result(self) -> Value:
        assert len(self.results) == 1, f"{self.name} has {len(self.results)} results"
        return self.results[0]

    def walk(self) -> Iterator["Op"]:
        yield self
        for region in self.regions:
            for op in list(region.block.ops):
                yield from op.walk()

    def replace_operand(self, old: Value, new: Value) -> None:
        self.operands = [new if o is old else o for o in self.operands]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return print_op(self)


@dataclass(eq=False)
class Module:
    ops: list[Op] = field(default_factory=list)

    def walk(self) -> Iterator[Op]:
        for op in list(self.ops):
            yield from op.walk()

    def func(self, name: str) -> Op:
        for op in self.ops:
            if op.name == "func.func" and op.attrs.get("sym_name") == name:
                return op
        raise KeyError(name)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "\n".join(print_op(op) for op in self.ops)


# --------------------------------------------------------------------------
# Op constructors (the "dialects")
# --------------------------------------------------------------------------


def constant(value: int, type: str = I64) -> Op:
    return Op("arith.constant", attrs={"value": value}, result_types=[type])


_BINARY_FNS: dict[str, Callable[[int, int], int]] = {
    "arith.addi": lambda a, b: a + b,
    "arith.subi": lambda a, b: a - b,
    "arith.muli": lambda a, b: a * b,
    "arith.ori": lambda a, b: a | b,
    "arith.andi": lambda a, b: a & b,
    "arith.xori": lambda a, b: a ^ b,
    "arith.shli": lambda a, b: a << b,
    "arith.shrui": lambda a, b: a >> b,
}

_CMP_FNS: dict[str, Callable[[int, int], bool]] = {
    "slt": lambda a, b: a < b,
    "sle": lambda a, b: a <= b,
    "sgt": lambda a, b: a > b,
    "sge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
}


def binary(name: str, lhs: Value, rhs: Value) -> Op:
    assert name in _BINARY_FNS, name
    return Op(name, operands=[lhs, rhs], result_types=[lhs.type])


def cmpi(pred: str, lhs: Value, rhs: Value) -> Op:
    assert pred in _CMP_FNS, pred
    return Op("arith.cmpi", operands=[lhs, rhs], attrs={"pred": pred}, result_types=[I1])


def setup(
    accel: str,
    fields: dict[str, Value],
    in_state: Value | None = None,
) -> Op:
    """``accfg.setup``: write configuration registers; yields the new
    ``!accfg.state`` (§5.1, Figure 6 (1)). ``in_state`` chains to the previous
    live state so the compiler can compute a setup delta."""
    names = list(fields.keys())
    operands = [fields[n] for n in names]
    if in_state is not None:
        assert in_state.type == STATE
        operands.append(in_state)
    return Op(
        "accfg.setup",
        operands=operands,
        attrs={"accel": accel, "fields": names, "has_in_state": in_state is not None},
        result_types=[STATE],
    )


def setup_fields(op: Op) -> dict[str, Value]:
    assert op.name == "accfg.setup"
    names = op.attrs["fields"]
    return dict(zip(names, op.operands[: len(names)]))


def setup_in_state(op: Op) -> Value | None:
    assert op.name == "accfg.setup"
    return op.operands[-1] if op.attrs["has_in_state"] else None


def set_setup_in_state(op: Op, state: Value | None) -> None:
    """Attach/detach the chained input state of an ``accfg.setup``."""
    assert op.name == "accfg.setup"
    n = len(op.attrs["fields"])
    op.operands = op.operands[:n] + ([state] if state is not None else [])
    op.attrs["has_in_state"] = state is not None


def launch(state: Value, accel: str) -> Op:
    assert state.type == STATE
    return Op("accfg.launch", operands=[state], attrs={"accel": accel}, result_types=[TOKEN])


def await_(token: Value) -> Op:
    assert token.type == TOKEN
    return Op("accfg.await", operands=[token])


def for_(
    lb: Value,
    ub: Value,
    step: Value,
    iter_inits: list[Value] | None = None,
) -> Op:
    """``scf.for`` with iter_args. The body block receives (iv, *iter_args)."""
    iter_inits = iter_inits or []
    region = Region()
    region.block.add_arg(INDEX, _fresh("iv"))
    for init in iter_inits:
        region.block.add_arg(init.type)
    return Op(
        "scf.for",
        operands=[lb, ub, step, *iter_inits],
        result_types=[v.type for v in iter_inits],
        regions=[region],
    )


def if_(cond: Value, result_types: list[str] | None = None) -> Op:
    assert cond.type == I1
    return Op(
        "scf.if",
        operands=[cond],
        result_types=result_types or [],
        regions=[Region(), Region()],
    )


def yield_(values: list[Value]) -> Op:
    return Op("scf.yield", operands=list(values))


def func(name: str) -> Op:
    return Op("func.func", attrs={"sym_name": name}, regions=[Region()])


def call(callee: str, args: list[Value], effects: str = "all") -> Op:
    """An opaque external call. ``effects`` mirrors ``#accfg.effects<...>``:
    ``"all"`` clobbers accelerator state (the pessimistic default), ``"none"``
    preserves it (§5.1)."""
    assert effects in ("all", "none")
    return Op("func.call", operands=list(args), attrs={"callee": callee, "effects": effects})


def return_(values: list[Value] | None = None) -> Op:
    return Op("func.return", operands=list(values or []))


# --------------------------------------------------------------------------
# Structural helpers shared by passes
# --------------------------------------------------------------------------


def replace_all_uses(root: Op | Module, old: Value, new: Value) -> None:
    """Replace every use of ``old`` with ``new`` underneath ``root``."""
    for op in root.walk() if isinstance(root, Module) else root.walk():
        op.replace_operand(old, new)


def uses(root: Op | Module, value: Value) -> list[Op]:
    return [op for op in root.walk() for o in op.operands if o is value]


def erase(op: Op) -> None:
    assert op.parent is not None, "op not attached"
    op.parent.remove(op)


def for_iter_args(op: Op) -> list[Value]:
    assert op.name == "scf.for"
    return op.regions[0].block.args[1:]


def for_iter_inits(op: Op) -> list[Value]:
    assert op.name == "scf.for"
    return op.operands[3:]


def for_yield(op: Op) -> Op:
    assert op.name == "scf.for"
    term = op.regions[0].block.ops[-1]
    assert term.name == "scf.yield"
    return term


def add_iter_arg(loop: Op, init: Value, yielded: Value) -> tuple[Value, Value]:
    """Grow an ``scf.for`` by one iter_arg. Returns (block_arg, loop_result)."""
    assert loop.name == "scf.for"
    loop.operands.append(init)
    block_arg = loop.regions[0].block.add_arg(init.type)
    for_yield(loop).operands.append(yielded)
    result = Value(type=init.type, owner=loop)
    loop.results.append(result)
    loop.result_types.append(init.type)
    return block_arg, result


def if_yields(op: Op) -> tuple[Op, Op]:
    assert op.name == "scf.if"
    then_term = op.regions[0].block.ops[-1]
    else_term = op.regions[1].block.ops[-1]
    assert then_term.name == "scf.yield" and else_term.name == "scf.yield"
    return then_term, else_term


def add_if_result(op: Op, then_val: Value, else_val: Value) -> Value:
    """Grow an ``scf.if`` by one result yielded from both branches."""
    assert then_val.type == else_val.type
    then_term, else_term = if_yields(op)
    then_term.operands.append(then_val)
    else_term.operands.append(else_val)
    result = Value(type=then_val.type, owner=op)
    op.results.append(result)
    op.result_types.append(then_val.type)
    return result


def clone_op(op: Op, mapping: dict[Value, Value]) -> Op:
    """Clone a region-free op, remapping operands through ``mapping``."""
    assert not op.regions, "clone_op only supports region-free ops"
    new = Op(
        op.name,
        operands=[mapping.get(o, o) for o in op.operands],
        attrs=dict(op.attrs),
        result_types=list(op.result_types),
    )
    for old_res, new_res in zip(op.results, new.results):
        mapping[old_res] = new_res
    return new


def defined_in(value: Value, op: Op) -> bool:
    """True if ``value`` is defined inside (any region of) ``op``."""
    node: Optional[Block] = value.block if value.is_block_arg else (
        value.owner.parent if value.owner is not None else None
    )
    while node is not None:
        parent_op = node.parent.parent if node.parent is not None else None
        if parent_op is op:
            return True
        node = parent_op.parent if parent_op is not None else None
    return False


def is_pure(op: Op) -> bool:
    """Pure ops can be duplicated/moved freely by the overlap pass (§5.5)."""
    return op.name.startswith("arith.")


# --------------------------------------------------------------------------
# Printing (textual IR, for debugging and golden tests)
# --------------------------------------------------------------------------


def print_op(op: Op, indent: int = 0) -> str:
    pad = "  " * indent
    parts: list[str] = []
    res = ", ".join(v.name for v in op.results)
    head = f"{res} = " if op.results else ""
    if op.name == "accfg.setup":
        fields = setup_fields(op)
        in_state = setup_in_state(op)
        frm = f" from {in_state.name}" if in_state is not None else ""
        body = ", ".join(f'"{k}" = {v.name}' for k, v in fields.items())
        parts.append(f'{pad}{head}accfg.setup on "{op.attrs["accel"]}"{frm} to ({body})')
    elif op.name == "arith.constant":
        parts.append(f"{pad}{head}arith.constant {op.attrs['value']}")
    else:
        args = ", ".join(v.name for v in op.operands)
        attrs = {k: v for k, v in op.attrs.items() if k not in ("fields", "has_in_state")}
        suffix = f" {attrs}" if attrs else ""
        parts.append(f"{pad}{head}{op.name}({args}){suffix}")
    for region in op.regions:
        args = ", ".join(f"{a.name}: {a.type}" for a in region.block.args)
        parts.append(f"{pad}{{ ({args})")
        for inner in region.block.ops:
            parts.append(print_op(inner, indent + 1))
        parts.append(f"{pad}}}")
    return "\n".join(parts)


def print_module(module: Module) -> str:
    return "\n".join(print_op(op) for op in module.ops)
