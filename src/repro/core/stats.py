"""Shared summary statistics — one ``geomean`` for the whole repo.

Two definitions used to coexist: ``sched.telemetry.geomean`` collapsed any
non-positive term to 0.0 (a collapsed benchmark cell must drag the summary
to zero, not vanish from it), while ``core.evaluate.geomean`` assumed
all-positive inputs and raised on zeros. Every ``BENCH_*.json`` summary,
CI geomean gate, and report now shares the collapsing definition below;
both historical call sites re-export it unchanged.
"""

from __future__ import annotations

from typing import Iterable


def geomean(values: Iterable[float]) -> float:
    """Geometric mean; 0.0 for an empty sequence or any non-positive term —
    a collapsed cell must drag the summary to zero, not vanish from it."""
    vals = list(values)
    if not vals or any(v <= 0.0 for v in vals):
        return 0.0
    prod = 1.0
    for v in vals:
        prod *= v
    return prod ** (1.0 / len(vals))
