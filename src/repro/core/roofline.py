"""The Configuration Roofline Model (§4, Eqs. 1–5).

Implements, verbatim:

* Eq. 1 — classical processor roofline: ``min(P_peak, BW_mem × I_op)``.
* Eq. 2 — concurrent configuration roofline: ``min(P_peak, BW_cfg × I_OC)``.
* Eq. 3 — sequential configuration roofline (harmonic composition):
  ``1 / (1/P_peak + 1/(BW_cfg × I_OC))``.
* Eq. 4 — effective configuration bandwidth:
  ``N_cfg_bytes / (T_calc + T_set)``.
* Eq. 5 — the combined "roofsurface":
  ``min(P_peak, BW_mem × I_op, BW_cfg × I_OC)``.

Also ships the §4.6 Gemmini worked example as executable constants, which the
test suite asserts against the paper's published 41.49% / 26.78% utilization
numbers.
"""

from __future__ import annotations

from dataclasses import dataclass


def processor_roofline(p_peak: float, bw_mem: float, i_op: float) -> float:
    """Eq. 1 — attainable performance under the classical roofline."""
    return min(p_peak, bw_mem * i_op)


def concurrent_config_roofline(p_peak: float, bw_config: float, i_oc: float) -> float:
    """Eq. 2 — attainable performance with concurrent configuration."""
    return min(p_peak, bw_config * i_oc)


def sequential_config_roofline(p_peak: float, bw_config: float, i_oc: float) -> float:
    """Eq. 3 — attainable performance with sequential configuration."""
    if i_oc == float("inf"):
        return p_peak
    return 1.0 / (1.0 / p_peak + 1.0 / (bw_config * i_oc))


def effective_config_bandwidth(n_config_bytes: float, t_calc: float, t_set: float) -> float:
    """Eq. 4 — configuration bandwidth degraded by parameter calculation."""
    return n_config_bytes / (t_calc + t_set)


def roofsurface(
    p_peak: float, bw_mem: float, i_op: float, bw_config: float, i_oc: float
) -> float:
    """Eq. 5 — the combined processor + configuration roofline."""
    return min(p_peak, bw_mem * i_op, bw_config * i_oc)


def config_bound(p_peak: float, bw_config: float, i_oc: float) -> bool:
    """A workload is configuration-bound when the config term minimizes Eq. 2
    — i.e. it sits left of the knee point (§4.2)."""
    return bw_config * i_oc < p_peak


def knee_point(p_peak: float, bw_config: float) -> float:
    """The I_OC at which configuration and computation take equal time."""
    return p_peak / bw_config


@dataclass(frozen=True)
class RooflinePoint:
    """One measurement on the configuration roofline plot (Figure 12)."""

    name: str
    i_oc: float
    performance: float  # ops/cycle
    p_peak: float
    bw_config: float

    @property
    def bound(self) -> str:
        return "configuration" if config_bound(self.p_peak, self.bw_config, self.i_oc) else "compute"

    @property
    def attainable_sequential(self) -> float:
        return sequential_config_roofline(self.p_peak, self.bw_config, self.i_oc)

    @property
    def attainable_concurrent(self) -> float:
        return concurrent_config_roofline(self.p_peak, self.bw_config, self.i_oc)

    @property
    def utilization(self) -> float:
        return self.performance / self.p_peak


def host_roofline_point(
    name: str,
    *,
    total_ops: float,
    config_bytes: float,
    config_cycles: float,
    makespan: float,
    p_peak: float,
    calc_cycles: float = 0.0,
) -> RooflinePoint:
    """Configuration-roofline placement for one *host* of a cluster.

    Every device behind one control processor shares a serialized config
    port (Colagrande & Benini's offload amplification), so the host's
    ``BW_cfg`` is the port's *effective* bandwidth (Eq. 4 over the cycles
    the port actually spent writing plus computing parameters) and its
    ``P_peak`` is the sum over the pool — adding devices raises the roof
    but leaves the config bandwidth fixed, pushing the knee point right.
    """
    t_set = max(config_cycles, 1e-12)
    bw = effective_config_bandwidth(config_bytes, calc_cycles, t_set)
    return RooflinePoint(
        name=name,
        i_oc=total_ops / max(config_bytes, 1e-12),
        performance=total_ops / makespan if makespan else 0.0,
        p_peak=p_peak,
        bw_config=bw,
    )


def fabric_roofline_point(
    name: str,
    *,
    total_ops: float,
    config_bytes: float,
    host_cycles: float,
    link_cycles: float,
    makespan: float,
    p_peak: float,
) -> RooflinePoint:
    """Configuration-roofline placement with the *interconnect* priced in.

    When config writes cross a fabric link (``repro.fabric``) instead of a
    core-local CSR port, Eq. 4's split becomes: T_calc is the host's
    instruction time (parameter calculation + descriptor/write issue) and
    T_set is the cycles the bytes spent on the wire — so ``BW_cfg`` is the
    *link-effective* configuration bandwidth. "Know your rooflines!" in
    practice: the transfer term appears as an explicit ceiling, and a slow
    link drags the knee point right even when the host itself is fast.
    """
    bw = effective_config_bandwidth(config_bytes, host_cycles,
                                    max(link_cycles, 1e-12))
    return RooflinePoint(
        name=name,
        i_oc=total_ops / max(config_bytes, 1e-12),
        performance=total_ops / makespan if makespan else 0.0,
        p_peak=p_peak,
        bw_config=bw,
    )


def overlap_roofline_point(
    name: str,
    *,
    total_ops: float,
    config_bytes: float,
    exposed_cycles: float,
    makespan: float,
    p_peak: float,
    calc_cycles: float = 0.0,
) -> RooflinePoint:
    """Configuration-roofline placement with *runtime overlap* priced in.

    When the engine stages config transfers behind compute
    (``repro.engine.overlap``), part of T_set leaves the critical path: the
    effective configuration term of Eq. 4 is only the **exposed** config
    cycles — host instruction time plus whatever wire time compute failed
    to cover. ``BW_cfg`` rises accordingly and the ridge (knee) point
    ``P_peak / BW_cfg`` shifts left: workloads that were configuration-bound
    under serialized dispatch become compute-bound once their T_set hides.
    A serialized run has ``exposed == config_cycles`` and this point
    degenerates to :func:`host_roofline_point`.
    """
    t_set = max(exposed_cycles, 1e-12)
    bw = effective_config_bandwidth(config_bytes, calc_cycles, t_set)
    return RooflinePoint(
        name=name,
        i_oc=total_ops / max(config_bytes, 1e-12),
        performance=total_ops / makespan if makespan else 0.0,
        p_peak=p_peak,
        bw_config=bw,
    )


def decode_roofline_point(
    name: str,
    *,
    tokens: float,
    ops_per_token: float,
    descriptor_bytes: float,
    config_cycles: float,
    makespan: float,
    p_peak: float,
) -> RooflinePoint:
    """Configuration-roofline placement for a *serving* workload
    (``repro.bridge``): the operational unit is the decode step, so I_OC is
    token work over the **descriptor bytes actually sent** — the
    {tokens, positions, live-mask} delta each step ships against the
    device-resident KV cache and weights (§5.4's deduplicated-configuration
    serving design). ``BW_cfg`` is Eq. 4 over the cycles those bytes held
    the config port. Descriptor elision moves a serving point rightward on
    exactly the same axes as the compiled-program points — the roofline now
    answers "is this *LLM serving* configuration-bound?", not a GEMM proxy.
    """
    total_ops = tokens * ops_per_token
    bw = effective_config_bandwidth(descriptor_bytes, 0.0,
                                    max(config_cycles, 1e-12))
    return RooflinePoint(
        name=name,
        i_oc=total_ops / max(descriptor_bytes, 1e-12),
        performance=total_ops / makespan if makespan else 0.0,
        p_peak=p_peak,
        bw_config=bw,
    )


def predicted_roofline_point(
    name: str,
    *,
    ops: float,
    config_bytes: float,
    compute_cycles: float,
    config_cycles: float,
    p_peak: float,
    concurrent: bool = True,
) -> RooflinePoint:
    """A *model-predicted* placement on the configuration roofline — no
    run required. The calibrated analytical compute model
    (``engine.costmodel``) predicts the kernel's compute cycles and the
    fabric transport plan prices its config bytes; the steady-state launch
    period is their ``max`` under concurrent configuration (config streams
    behind compute) and their sum under sequential (the host is captive
    through T_set, Eq. 3's serialization). The resulting point answers
    "where *would* this shape land?" before any launch happens — the
    what-if twin of :func:`host_roofline_point`, and the quantity the
    overlap autotuner's wire/compute ratio is read off of."""
    t_set = max(config_cycles, 1e-12)
    period = max(compute_cycles, t_set) if concurrent \
        else compute_cycles + t_set
    bw = effective_config_bandwidth(config_bytes, 0.0, t_set)
    return RooflinePoint(
        name=name,
        i_oc=ops / max(config_bytes, 1e-12),
        performance=ops / period if period else 0.0,
        p_peak=p_peak,
        bw_config=bw,
    )


# --------------------------------------------------------------------------
# the energy roofline — Eq. 4 along the joule axis
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class EnergyRooflinePoint:
    """One measurement on the *energy* roofline ("Know your rooflines!"
    extended per-Watt): efficiency (ops/pJ) against configuration energy
    intensity, with the same harmonic composition as the cycle plot.

    The analogy is exact. Cycles: work and configuration serialize in
    *time*, so attainable ops/cycle = 1/(1/P_peak + 1/(BW_cfg·I_OC)).
    Joules: every op and every config byte costs *energy*, so attainable
    ops/pJ = 1/(1/peak_ops_per_joule + 1/(bw_e·I_OC)) where ``bw_e`` is
    config bytes per joule of configuration energy — and the ridge sits
    at I_OC = peak_ops_per_joule / bw_e, in ops per joule-normalized
    byte. Runtime overlap does **not** save config joules (the handshakes
    happen either way), but descriptor elision and burst DMA do — they
    raise ``bw_e`` and shift the energy ridge left, exactly as exposed
    T_set reduction shifts the cycle ridge."""

    name: str
    i_oc: float  # ops per config byte — same x-axis as the cycle plot
    efficiency: float  # achieved ops/pJ (total_ops / total_energy)
    peak_ops_per_joule: float  # datapath efficiency at full tilt
    bw_energy: float  # config bytes per pJ of configuration energy

    @property
    def attainable(self) -> float:
        """Roofline ceiling at this I_OC, ops/pJ (harmonic composition —
        the sequential/energy analogue of Eq. 5)."""
        return 1.0 / (1.0 / self.peak_ops_per_joule
                      + 1.0 / (self.bw_energy * self.i_oc))

    @property
    def ridge(self) -> float:
        """I_OC where config and compute burn equal joules — left of it,
        the workload is configuration-*energy*-bound."""
        return self.peak_ops_per_joule / self.bw_energy

    @property
    def energy_bound(self) -> str:
        return "configuration" if self.i_oc < self.ridge else "compute"

    @property
    def utilization(self) -> float:
        """Achieved fraction of the datapath's peak efficiency."""
        return self.efficiency / self.peak_ops_per_joule


def energy_roofline_point(
    name: str,
    *,
    total_ops: float,
    config_bytes: float,
    config_energy: float,
    total_energy: float,
    compute_power: float,
    p_peak: float,
) -> EnergyRooflinePoint:
    """Place one run on the energy roofline (tokens/ops per joule).

    ``config_energy`` is the run's configuration joules — host instruction
    issue plus wire transfer energy, i.e. ``repro.power`` 's metered
    ``summary["config_energy"]`` — playing T_set's role: ``bw_e`` =
    config bytes per config joule, so cheaper transport (burst DMA,
    elision) raises it and moves the ridge left. ``compute_power`` is the
    datapath's active pJ/cycle, giving peak efficiency ``p_peak /
    compute_power`` ops/pJ. For serving, pass token counts as
    ``total_ops`` to read tokens-per-joule off the same plot."""
    peak_opj = p_peak / max(compute_power, 1e-12)
    bw_e = config_bytes / max(config_energy, 1e-12)
    return EnergyRooflinePoint(
        name=name,
        i_oc=total_ops / max(config_bytes, 1e-12),
        efficiency=total_ops / total_energy if total_energy else 0.0,
        peak_ops_per_joule=peak_opj,
        bw_energy=bw_e,
    )


# --------------------------------------------------------------------------
# §4.6 worked example: Gemmini output-stationary 64×64×64 matmul
# --------------------------------------------------------------------------

GEMMINI_EXAMPLE = dict(
    total_ops=2 * 64 * 64 * 64,  # 524,288 ops
    p_peak=16 * 16 * 2,  # 512 ops/cycle
    rocc_bytes=16,  # bytes per RoCC custom instruction
    instrs_per_rocc=3,  # 2 loads + 1 custom
    cycles_per_instr=3,  # Rocket CPI from [17]
    n_rocc_setup=160,  # traced RoCC instructions to configure
    n_total_instrs=935,  # incl. 775 bit-packing/parameter calculation
)


def gemmini_example_theoretical() -> tuple[float, float, float]:
    """Returns (BW_config, I_OC, utilization) with the theoretical bandwidth —
    the paper derives ≈1.77 B/cycle, I_OC ≈ 204.8, utilization ≈ 41.5%."""
    e = GEMMINI_EXAMPLE
    bw = e["rocc_bytes"] / (e["instrs_per_rocc"] * e["cycles_per_instr"])
    i_oc = e["total_ops"] / (e["n_rocc_setup"] * e["rocc_bytes"])
    util = sequential_config_roofline(e["p_peak"], bw, i_oc) / e["p_peak"]
    return bw, i_oc, util


def gemmini_example_effective() -> tuple[float, float, float]:
    """Returns (BW_eff, I_OC, utilization) with the *effective* bandwidth
    (Eq. 4) — the paper reports ≈0.913 B/cycle and ≈26.78% utilization."""
    e = GEMMINI_EXAMPLE
    n_bytes = e["n_rocc_setup"] * e["rocc_bytes"]
    total_cycles = e["n_total_instrs"] * e["cycles_per_instr"]
    bw_eff = n_bytes / total_cycles
    i_oc = e["total_ops"] / n_bytes
    util = sequential_config_roofline(e["p_peak"], bw_eff, i_oc) / e["p_peak"]
    return bw_eff, i_oc, util
