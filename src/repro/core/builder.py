"""An imperative builder for constructing accfg IR programs from Python.

Mirrors MLIR's ``OpBuilder`` + xDSL's builder pattern: a cursor into a block,
context managers for structured control flow, and tiny helpers for the arith
ops that dominate configuration-parameter calculation (bit packing, address
arithmetic — §4.4 of the paper).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator

from . import ir
from .ir import Block, Module, Op, Value


class Builder:
    def __init__(self) -> None:
        self.module = Module()
        self._block_stack: list[Block] = []

    # -- insertion ----------------------------------------------------------

    @property
    def block(self) -> Block:
        return self._block_stack[-1]

    def insert(self, op: Op) -> Op:
        self.block.append(op)
        return op

    # -- functions ----------------------------------------------------------

    @contextmanager
    def function(self, name: str) -> Iterator[Op]:
        fn = ir.func(name)
        self.module.ops.append(fn)
        self._block_stack.append(fn.regions[0].block)
        try:
            yield fn
        finally:
            if not self.block.ops or self.block.ops[-1].name != "func.return":
                self.insert(ir.return_())
            self._block_stack.pop()

    # -- arith ---------------------------------------------------------------

    def const(self, value: int, type: str = ir.I64) -> Value:
        return self.insert(ir.constant(value, type)).result

    def index(self, value: int) -> Value:
        return self.insert(ir.constant(value, ir.INDEX)).result

    def add(self, a: Value, b: Value) -> Value:
        return self.insert(ir.binary("arith.addi", a, b)).result

    def sub(self, a: Value, b: Value) -> Value:
        return self.insert(ir.binary("arith.subi", a, b)).result

    def mul(self, a: Value, b: Value) -> Value:
        return self.insert(ir.binary("arith.muli", a, b)).result

    def or_(self, a: Value, b: Value) -> Value:
        return self.insert(ir.binary("arith.ori", a, b)).result

    def shl(self, a: Value, b: Value) -> Value:
        return self.insert(ir.binary("arith.shli", a, b)).result

    def cmp(self, pred: str, a: Value, b: Value) -> Value:
        return self.insert(ir.cmpi(pred, a, b)).result

    def pack(self, *parts: tuple[Value, int]) -> Value:
        """Bit-pack ``(value, shift)`` pairs with shl/or — the pattern from
        Gemmini's C API (Listing 1) whose host cycles degrade the *effective*
        configuration bandwidth (Eq. 4)."""
        acc: Value | None = None
        for value, shift in parts:
            shifted = self.shl(value, self.const(shift)) if shift else value
            acc = shifted if acc is None else self.or_(acc, shifted)
        assert acc is not None
        return acc

    # -- accfg ----------------------------------------------------------------

    def setup(
        self,
        accel: str,
        fields: dict[str, Value],
        in_state: Value | None = None,
    ) -> Value:
        return self.insert(ir.setup(accel, fields, in_state)).result

    def launch(self, state: Value, accel: str) -> Value:
        return self.insert(ir.launch(state, accel)).result

    def await_(self, token: Value) -> None:
        self.insert(ir.await_(token))

    def call(self, callee: str, args: list[Value] | None = None, effects: str = "all") -> None:
        self.insert(ir.call(callee, args or [], effects))

    # -- scf ------------------------------------------------------------------

    @contextmanager
    def for_(
        self,
        lb: Value,
        ub: Value,
        step: Value,
        iter_inits: list[Value] | None = None,
    ) -> Iterator[tuple[Op, Value, list[Value]]]:
        """``with b.for_(lb, ub, step, [init]) as (loop, iv, iters): ...``

        The body must end by calling :meth:`yield_` with one value per
        iter_arg (checked on exit)."""
        loop = ir.for_(lb, ub, step, iter_inits)
        self.insert(loop)
        body = loop.regions[0].block
        self._block_stack.append(body)
        try:
            yield loop, body.args[0], body.args[1:]
        finally:
            if not body.ops or body.ops[-1].name != "scf.yield":
                assert not loop.results, "loop with iter_args must yield"
                self.insert(ir.yield_([]))
            self._block_stack.pop()

    def yield_(self, values: list[Value] | None = None) -> None:
        self.insert(ir.yield_(values or []))

    @contextmanager
    def if_(self, cond: Value, result_types: list[str] | None = None) -> Iterator[Op]:
        op = ir.if_(cond, result_types)
        self.insert(op)
        yield op

    @contextmanager
    def then(self, if_op: Op) -> Iterator[Block]:
        self._block_stack.append(if_op.regions[0].block)
        try:
            yield self.block
        finally:
            if not self.block.ops or self.block.ops[-1].name != "scf.yield":
                self.insert(ir.yield_([]))
            self._block_stack.pop()

    @contextmanager
    def else_(self, if_op: Op) -> Iterator[Block]:
        self._block_stack.append(if_op.regions[1].block)
        try:
            yield self.block
        finally:
            if not self.block.ops or self.block.ops[-1].name != "scf.yield":
                self.insert(ir.yield_([]))
            self._block_stack.pop()
