"""Target lowering (Figure 8, step 5): accfg → accelerator setup sequences.

The only accelerator-specific stage of the pipeline. Each backend translates
``accfg.setup`` / ``launch`` / ``await`` into its native configuration
instructions — RoCC custom instructions for the Gemmini-class target (two
64-bit fields per instruction, Listing 1 style), CSR writes for the
OpenGeMM-class target — and leaves the surrounding scalar/loop code as a
portable pseudo-assembly. The emitted program is a faithful instruction-level
rendering of what the interpreter charges cycles for, so instruction counts
reconcile with the timing model (tested).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from . import ir
from .accelerators import AcceleratorModel
from .ir import Module, Op


@dataclass
class LoweredProgram:
    lines: list[str] = field(default_factory=list)
    config_instrs: int = 0  # setup-register writes (static sites)
    launch_instrs: int = 0
    calc_instrs: int = 0  # scalar parameter computation
    control_instrs: int = 0  # loops/branches
    # trip-weighted (dynamic) counts, for statically-bounded loops
    dyn_config_instrs: int = 0
    dyn_calc_instrs: int = 0

    @property
    def total_instrs(self) -> int:
        return (
            self.config_instrs + self.launch_instrs + self.calc_instrs
            + self.control_instrs
        )

    def text(self) -> str:
        return "\n".join(self.lines)


_CALC_MNEMONIC = {
    "arith.addi": "add", "arith.subi": "sub", "arith.muli": "mul",
    "arith.ori": "or", "arith.andi": "and", "arith.xori": "xor",
    "arith.shli": "slli", "arith.shrui": "srli", "arith.cmpi": "slt",
    "arith.constant": "li",
}


class Lowering:
    def __init__(self, models: dict[str, AcceleratorModel]):
        self.models = models
        self.prog = LoweredProgram()
        self._reg = 0
        self._regs: dict[int, str] = {}
        self._mult = 1  # trip-count multiplier of the enclosing loops

    def reg(self, value) -> str:
        key = id(value)
        if key not in self._regs:
            self._regs[key] = f"x{self._reg % 28 + 4}"
            self._reg += 1
        return self._regs[key]

    def emit(self, line: str, kind: str, n: int = 1, indent: int = 1) -> None:
        self.prog.lines.append("  " * indent + line)
        setattr(self.prog, f"{kind}_instrs", getattr(self.prog, f"{kind}_instrs") + n)
        if kind in ("config", "calc"):
            attr = f"dyn_{kind}_instrs"
            setattr(self.prog, attr, getattr(self.prog, attr) + n * self._mult)

    def lower(self, module: Module, fn: str = "main") -> LoweredProgram:
        func = module.func(fn)
        self.prog.lines.append(f"{fn}:")
        self._block(func.regions[0].block, 1)
        self.prog.lines.append("  ret")
        return self.prog

    def _block(self, block: ir.Block, indent: int) -> None:
        for op in block.ops:
            self._op(op, indent)

    def _op(self, op: Op, indent: int) -> None:
        name = op.name
        if name == "arith.constant":
            self.emit(f"li    {self.reg(op.result)}, {op.attrs['value']}",
                      "calc", 1, indent)
        elif name in _CALC_MNEMONIC and name != "arith.constant":
            args = ", ".join(self.reg(o) for o in op.operands)
            self.emit(f"{_CALC_MNEMONIC[name]:5s} {self.reg(op.results[0])}, {args}",
                      "calc", 1, indent)
        elif name == "accfg.setup":
            self._setup(op, indent)
        elif name == "accfg.launch":
            model = self.models[op.attrs["accel"]]
            mnem = "rocc.launch" if model.fields_per_write == 2 else "csrw  launch, 1"
            self.emit(f"{mnem:24s} # start {op.attrs['accel']}",
                      "launch", model.launch_instrs, indent)
        elif name == "accfg.await":
            self.emit("await                    # poll status register",
                      "launch", 1, indent)
        elif name == "scf.for":
            lb, ub, step = (self.reg(o) for o in op.operands[:3])
            iv = self.reg(op.regions[0].block.args[0])
            self.emit(f"loop  {iv} = {lb}..{ub} step {step}:", "control", 2, indent)
            trips = self._static_trips(op)
            outer = self._mult
            self._mult *= trips
            self._block(op.regions[0].block, indent + 1)
            self._mult = outer
        elif name == "scf.if":
            self.emit(f"bnez  {self.reg(op.operands[0])}, then:", "control", 1, indent)
            self._block(op.regions[0].block, indent + 1)
            self.prog.lines.append("  " * indent + "else:")
            self._block(op.regions[1].block, indent + 1)
        elif name == "func.call":
            self.emit(f"call  {op.attrs['callee']}", "control", 1, indent)
        elif name in ("scf.yield", "func.return"):
            pass
        else:  # pragma: no cover
            raise NotImplementedError(name)

    @staticmethod
    def _static_trips(op: Op) -> int:
        vals = []
        for o in op.operands[:3]:
            if o.owner is not None and o.owner.name == "arith.constant":
                vals.append(o.owner.attrs["value"])
            else:
                return 1  # dynamic bounds: count the body once
        lb, ub, step = vals
        return max((ub - lb + step - 1) // step, 0) if step else 1

    def _setup(self, op: Op, indent: int) -> None:
        model = self.models[op.attrs["accel"]]
        fields = ir.setup_fields(op)
        names = list(fields)
        if model.fields_per_write == 2:  # RoCC: rs1/rs2 pairs
            for i in range(0, len(names), 2):
                pair = names[i : i + 2]
                regs = ", ".join(self.reg(fields[p]) for p in pair)
                self.emit(
                    f"rocc.cfg {regs:14s} # {'+'.join(pair)}",
                    "config", model.instrs_per_write, indent,
                )
        else:  # CSR-mapped configuration registers
            for n in names:
                self.emit(
                    f"csrw  {n}, {self.reg(fields[n])}",
                    "config", model.instrs_per_write, indent,
                )


def lower(module: Module, models: dict[str, AcceleratorModel]) -> LoweredProgram:
    return Lowering(models).lower(module)
