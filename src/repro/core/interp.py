"""Functional + cycle-approximate interpreter for accfg IR.

Two jobs:

1. **Functional oracle.** Execute a program and record the *invocation log*:
   for every ``accfg.launch``, a snapshot of the accelerator's configuration
   registers at launch time. Two programs are observationally equivalent for
   the accelerator iff their invocation logs match — this is the correctness
   criterion all optimization passes are tested against (configuration
   registers retain values, §3.2, which is exactly what deduplication relies
   on).

2. **Timing model.** A two-clock model (host clock, per-accelerator busy-until
   clock) that distinguishes *sequential* configuration (host stalls at launch
   until the macro-op retires, §2.2) from *concurrent* configuration (launch
   returns; ``accfg.await`` synchronizes; setups in between write staging
   registers). Host instruction costs follow the paper: every arith op is one
   host instruction at CPI cycles; every setup field costs the model's
   config-write cycles; Eq. 4's ``T_calc`` emerges naturally from the arith
   ops left in the program.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from . import ir
from .accelerators import AcceleratorModel
from .ir import Module, Op, Value

LOOP_OVERHEAD_INSTRS = 2  # induction add + back-branch per iteration
BRANCH_INSTRS = 1
CALL_INSTRS = 10  # opaque external call (pessimistic)


@dataclass
class Invocation:
    accel: str
    regs: dict[str, int]
    start: float
    end: float


@dataclass
class Trace:
    """Everything the evaluation needs from one program execution."""

    invocations: list[Invocation] = field(default_factory=list)
    host_cycles: float = 0.0  # final host clock
    total_cycles: float = 0.0  # makespan incl. accelerator drain
    config_cycles: float = 0.0  # host cycles writing config registers
    calc_cycles: float = 0.0  # host cycles computing config params (T_calc)
    stall_cycles: float = 0.0  # host cycles stalled on launch/await
    total_ops: int = 0  # accelerator macro-op work
    accel_busy_cycles: float = 0.0

    @property
    def performance(self) -> float:
        """ops/cycle — the y-axis of the configuration roofline plots."""
        return self.total_ops / self.total_cycles if self.total_cycles else 0.0

    @property
    def config_bytes(self) -> int:
        return self._config_bytes

    _config_bytes: int = 0

    @property
    def i_oc(self) -> float:
        """Observed operation-to-configuration intensity (§4.2)."""
        return self.total_ops / self._config_bytes if self._config_bytes else float("inf")

    def log_signature(self) -> list[tuple[str, tuple[tuple[str, int], ...]]]:
        """Hashable form of the invocation log for equivalence checks."""
        return [(i.accel, tuple(sorted(i.regs.items()))) for i in self.invocations]


class Interpreter:
    def __init__(self, models: dict[str, AcceleratorModel]):
        self.models = models
        self.regs: dict[str, dict[str, int]] = {name: {} for name in models}
        self.accel_free: dict[str, float] = {name: 0.0 for name in models}
        self.trace = Trace()
        self.host = 0.0

    # -- cost helpers --------------------------------------------------------

    def _host_instrs(self, n: float, cpi: float, kind: str) -> None:
        cycles = n * cpi
        self.host += cycles
        if kind == "calc":
            self.trace.calc_cycles += cycles
        elif kind == "config":
            self.trace.config_cycles += cycles

    # -- execution -----------------------------------------------------------

    def run(self, module: Module, fn_name: str = "main") -> Trace:
        fn = module.func(fn_name)
        self._run_block(fn.regions[0].block, {})
        # drain: the program is only done once every accelerator retired
        drain = max([self.host, *self.accel_free.values()])
        self.trace.host_cycles = self.host
        self.trace.total_cycles = drain
        return self.trace

    def _run_block(self, block: ir.Block, env: dict[Value, int]) -> list[int]:
        """Execute a block; returns the operand values of its terminator."""
        default_cpi = max(m.host_cpi for m in self.models.values())
        for op in block.ops:
            name = op.name
            if name == "arith.constant":
                env[op.result] = op.attrs["value"]
                self._host_instrs(1, default_cpi, "calc")
            elif name in ir._BINARY_FNS:
                a, b = (env[o] for o in op.operands)
                env[op.result] = ir._BINARY_FNS[name](a, b)
                self._host_instrs(1, default_cpi, "calc")
            elif name == "arith.cmpi":
                a, b = (env[o] for o in op.operands)
                env[op.result] = int(ir._CMP_FNS[op.attrs["pred"]](a, b))
                self._host_instrs(1, default_cpi, "calc")
            elif name == "accfg.setup":
                self._exec_setup(op, env)
            elif name == "accfg.launch":
                self._exec_launch(op, env)
            elif name == "accfg.await":
                self._exec_await(op, env)
            elif name == "scf.for":
                self._exec_for(op, env, default_cpi)
            elif name == "scf.if":
                cond = env[op.operands[0]]
                self._host_instrs(BRANCH_INSTRS, default_cpi, "calc")
                branch = op.regions[0] if cond else op.regions[1]
                outs = self._run_block(branch.block, env)
                for res, val in zip(op.results, outs):
                    env[res] = val
            elif name == "func.call":
                self._host_instrs(CALL_INSTRS, default_cpi, "calc")
            elif name in ("scf.yield", "func.return"):
                return [env.get(o, 0) for o in op.operands]
            else:  # pragma: no cover
                raise NotImplementedError(name)
        return []

    def _exec_for(self, op: Op, env: dict[Value, int], cpi: float) -> None:
        lb, ub, step = (env[o] for o in op.operands[:3])
        body = op.regions[0].block
        iters = [env.get(o, 0) for o in op.operands[3:]]
        for i in range(lb, ub, step):
            env[body.args[0]] = i
            for arg, val in zip(body.args[1:], iters):
                env[arg] = val
            self._host_instrs(LOOP_OVERHEAD_INSTRS, cpi, "calc")
            iters = self._run_block(body, env)
        for res, val in zip(op.results, iters):
            env[res] = val

    def _exec_setup(self, op: Op, env: dict[Value, int]) -> None:
        accel = op.attrs["accel"]
        model = self.models[accel]
        fields = ir.setup_fields(op)
        for fname, value in fields.items():
            self.regs[accel][fname] = env.get(value, 0)
        n = len(fields)
        writes = -(-n // model.fields_per_write) if n else 0  # ceil
        self._host_instrs(writes * model.instrs_per_write, model.host_cpi, "config")
        self.trace._config_bytes += n * model.bytes_per_field
        env[op.result] = 0  # states carry no runtime payload

    def _exec_launch(self, op: Op, env: dict[Value, int]) -> None:
        accel = op.attrs["accel"]
        model = self.models[accel]
        regs = dict(self.regs[accel])
        self._host_instrs(model.launch_instrs, model.host_cpi, "config")
        self.trace._config_bytes += model.bytes_per_field

        duration = model.macro_cycles(regs)
        ops = model.macro_ops(regs)
        if model.concurrent:
            # staged configuration: host only stalls if the unit is still busy
            start = max(self.host, self.accel_free[accel])
            if self.accel_free[accel] > self.host:
                self.trace.stall_cycles += self.accel_free[accel] - self.host
                self.host = self.accel_free[accel]
        else:
            # sequential configuration: the host is stalled until retirement
            start = max(self.host, self.accel_free[accel])
        end = start + duration
        self.accel_free[accel] = end
        if not model.concurrent:
            self.trace.stall_cycles += end - self.host
            self.host = end

        self.trace.invocations.append(Invocation(accel, regs, start, end))
        self.trace.total_ops += ops
        self.trace.accel_busy_cycles += duration
        env[op.result] = len(self.trace.invocations) - 1  # token = invocation id

    def _exec_await(self, op: Op, env: dict[Value, int]) -> None:
        idx = env.get(op.operands[0])
        if idx is None or idx < 0 or idx >= len(self.trace.invocations):
            return
        inv = self.trace.invocations[idx]
        if self.models[inv.accel].concurrent and inv.end > self.host:
            self.trace.stall_cycles += inv.end - self.host
            self.host = inv.end
        # sequential targets already synchronized at launch (await is a no-op)


def run(module: Module, models: dict[str, AcceleratorModel], fn: str = "main") -> Trace:
    return Interpreter(models).run(module, fn)
