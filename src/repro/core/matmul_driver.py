"""Tiled matrix-multiplication program generators — the paper's workload (§6).

Generates accfg IR that mirrors what the C/MLIR sources in the paper's
artifact compile to:

* :func:`opengemm_tiled_matmul` — K×K×K GeMM tiled as 8-by-K-by-8 calls into
  an OpenGeMM-style concurrent-configuration accelerator (§6.2). Per tile the
  host computes three pointers (base + row/col offsets) and writes ~11 CSRs
  (pointers, sizes, strides, zero-points).

* :func:`gemmini_tiled_matmul` — K×K×K GeMM tiled into ``loop_ws``-style
  weight-stationary macro-invocations on a Gemmini-style sequential target
  (§6.1), with the Table-1 field set (addresses, sizes+padding bit-packed the
  way Listing 1 does, strides, activation/transpose flags). Matrices beyond
  the scratchpad-capacity tile are covered by multiple invocations — which is
  exactly where deduplication starts to pay (§6.1: "smaller sizes only
  require a single invocation").

Both emit *naive-but-idiomatic* code: every invocation writes the full
configuration, constants re-materialized per iteration — precisely the shape
of the C APIs (Listing 1) that a compiler sees as opaque volatile asm.
"""

from __future__ import annotations

from .builder import Builder
from .ir import Module

ELEM_BYTES = 1  # int8 inputs
ACC_BYTES = 4  # int32 accumulators


def opengemm_tiled_matmul(k: int, tile_m: int = 8, tile_n: int = 8) -> Module:
    """C = A·B with A,B ∈ int8^{K×K}, tiled 8-by-K-by-8 (§6.2)."""
    assert k % tile_m == 0 and k % tile_n == 0
    b = Builder()
    with b.function("main"):
        base_a = b.const(0x1000_0000)
        base_b = b.const(0x2000_0000)
        base_c = b.const(0x3000_0000)
        lb = b.index(0)
        ub_i = b.index(k // tile_m)
        ub_j = b.index(k // tile_n)
        one = b.index(1)
        with b.for_(lb, ub_i, one) as (_loop_i, i, _):
            with b.for_(lb, ub_j, one) as (_loop_j, j, _):
                # pointer arithmetic the host must do per tile (T_calc, Eq. 4)
                row = b.mul(i, b.const(tile_m * k * ELEM_BYTES))
                col = b.mul(j, b.const(tile_n * ELEM_BYTES))
                ptr_a = b.add(base_a, row)
                ptr_b = b.add(base_b, col)
                crow = b.mul(i, b.const(tile_m * k * ACC_BYTES))
                ccol = b.mul(j, b.const(tile_n * ACC_BYTES))
                ptr_c = b.add(base_c, b.add(crow, ccol))
                state = b.setup(
                    "opengemm",
                    {
                        "ptr_a": ptr_a,
                        "ptr_b": ptr_b,
                        "ptr_c": ptr_c,
                        "M": b.const(tile_m),
                        "K": b.const(k),
                        "N": b.const(tile_n),
                        "lda": b.const(k * ELEM_BYTES),
                        "ldb": b.const(k * ELEM_BYTES),
                        "ldc": b.const(k * ACC_BYTES),
                        "zpa": b.const(0),
                        "zpb": b.const(0),
                    },
                )
                token = b.launch(state, "opengemm")
                b.await_(token)
    return b.module


def gemmini_tiled_matmul(k: int, max_tile: int = 64) -> Module:
    """C = A·B + D via weight-stationary ``loop_ws`` invocations (§6.1).

    One invocation covers an I×K'×J block of at most ``max_tile`` per dim
    (scratchpad capacity); larger problems iterate block-wise.
    """
    tile = min(k, max_tile)
    assert k % tile == 0
    blocks = k // tile
    b = Builder()
    with b.function("main"):
        base_a = b.const(0x8000_0000)
        base_b = b.const(0x9000_0000)
        base_d = b.const(0xA000_0000)
        base_c = b.const(0xB000_0000)
        lb = b.index(0)
        ub = b.index(blocks)
        one = b.index(1)
        with b.for_(lb, ub, one) as (_li, bi, _):
            with b.for_(lb, ub, one) as (_lj, bj, _):
                with b.for_(lb, ub, one) as (_lk, bk, _):
                    # addresses: base + block offsets (row-major int8 / int32)
                    a_off = b.add(
                        b.mul(bi, b.const(tile * k * ELEM_BYTES)),
                        b.mul(bk, b.const(tile * ELEM_BYTES)),
                    )
                    b_off = b.add(
                        b.mul(bk, b.const(tile * k * ELEM_BYTES)),
                        b.mul(bj, b.const(tile * ELEM_BYTES)),
                    )
                    c_off = b.add(
                        b.mul(bi, b.const(tile * k * ACC_BYTES)),
                        b.mul(bj, b.const(tile * ACC_BYTES)),
                    )
                    ptr_a = b.add(base_a, a_off)
                    ptr_b = b.add(base_b, b_off)
                    ptr_d = b.add(base_d, c_off)
                    ptr_c = b.add(base_c, c_off)
                    # Listing-1 style bit packing of sizes and padding
                    sizes = b.pack(
                        (b.const(tile), 0), (b.const(tile), 16), (b.const(tile), 32)
                    )
                    pads = b.pack((b.const(0), 0), (b.const(0), 16), (b.const(0), 32))
                    flags = b.pack((b.const(0), 0), (b.const(0), 1), (b.const(0), 2))
                    # config_ex / config_ld / config_st preamble that Gemmini's
                    # C API re-issues on every tiled_matmul invocation
                    ex_cfg = b.pack(
                        (b.const(1), 0),  # dataflow = WS
                        (b.const(0), 2),  # activation
                        (b.const(1), 16),  # sys_shift
                        (b.const(0), 32),  # a_transpose | b_transpose
                    )
                    ld_a = b.pack((b.const(k * ELEM_BYTES), 0), (b.const(1), 32))
                    ld_b = b.pack((b.const(k * ELEM_BYTES), 0), (b.const(1), 32))
                    ld_d = b.pack((b.const(k * ACC_BYTES), 0), (b.const(1), 32))
                    st_c = b.pack((b.const(k * ACC_BYTES), 0), (b.const(0), 32))
                    state = b.setup(
                        "gemmini",
                        {
                            "cfg_ex": ex_cfg,
                            "cfg_ex_scale": b.const(0),
                            "cfg_ld_a": ld_a,
                            "cfg_ld_b": ld_b,
                            "cfg_ld_d": ld_d,
                            "cfg_st_c": st_c,
                            "A": ptr_a,
                            "B": ptr_b,
                            "D": ptr_d,
                            "C": ptr_c,
                            "I": b.const(tile),
                            "J": b.const(tile),
                            "K": b.const(tile),
                            "sizes_pads": b.pack((sizes, 0)),
                            "pad_word": pads,
                            "stride_A": b.const(k * ELEM_BYTES),
                            "stride_B": b.const(k * ELEM_BYTES),
                            "stride_D": b.const(k * ACC_BYTES),
                            "stride_C": b.const(k * ACC_BYTES),
                            "act_flags": flags,
                        },
                    )
                    token = b.launch(state, "gemmini")
                    b.await_(token)
    return b.module
