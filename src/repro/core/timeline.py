"""Figure-2-style timelines from interpreter traces.

The paper's Figure 2 shows the configuration wall as idle accelerator gaps
between macro-operations while the host configures. This module renders the
same picture from a :class:`~repro.core.interp.Trace`: an ASCII gantt of
accelerator busy intervals, plus the utilization summary the figure implies.
"""

from __future__ import annotations

from .interp import Trace


def accel_utilization(trace: Trace) -> float:
    if trace.total_cycles == 0:
        return 0.0
    return trace.accel_busy_cycles / trace.total_cycles


def idle_gaps(trace: Trace) -> list[tuple[float, float]]:
    """Gaps where the accelerator sits idle between macro-operations."""
    gaps = []
    t = 0.0
    for inv in trace.invocations:
        if inv.start > t:
            gaps.append((t, inv.start))
        t = max(t, inv.end)
    if trace.total_cycles > t:
        gaps.append((t, trace.total_cycles))
    return gaps


def render(trace: Trace, width: int = 72, label: str = "") -> str:
    """ASCII gantt: each cell shows the fraction of its time-slice the
    accelerator was busy ('#' ≥ 2/3, '+' ≥ 1/3, '.' mostly idle)."""
    total = trace.total_cycles or 1.0
    busy = [0.0] * width
    cell_w = total / width
    for inv in trace.invocations:
        lo_f, hi_f = inv.start / cell_w, inv.end / cell_w
        lo, hi = int(lo_f), min(int(hi_f), width - 1)
        for i in range(lo, hi + 1):
            seg = min(hi_f, i + 1) - max(lo_f, i)
            busy[i] += max(seg, 0.0)
    bar = "".join(
        "#" if b >= 0.75 else "+" if b >= 0.4 else ":" if b >= 0.15 else "."
        for b in busy
    )
    util = accel_utilization(trace)
    head = f"{label:10s}" if label else ""
    return (
        f"{head}|{bar}| {trace.total_cycles:9.0f} cyc, "
        f"accel busy {util * 100:5.1f}%"
    )


def compare(traces: dict[str, Trace], width: int = 72) -> str:
    """Render several optimization levels one under another (Figure 7)."""
    return "\n".join(render(t, width, label=name) for name, t in traces.items())
