"""repro.core — the paper's contribution: the accfg abstraction, its
optimization passes, the configuration roofline model, and the
cycle-approximate evaluation substrate."""

from . import (
    accelerators,
    builder,
    evaluate,
    interp,
    ir,
    lowering,
    matmul_driver,
    passes,
    roofline,
    timeline,
)
from .accelerators import AcceleratorModel, gemmini_like, opengemm_like
from .builder import Builder
from .evaluate import evaluate as evaluate_levels
from .evaluate import geomean, speedup
from .interp import Trace, run
from .ir import Module
from .roofline import (
    RooflinePoint,
    concurrent_config_roofline,
    config_bound,
    effective_config_bandwidth,
    knee_point,
    processor_roofline,
    roofsurface,
    sequential_config_roofline,
)

__all__ = [
    "AcceleratorModel",
    "Builder",
    "Module",
    "RooflinePoint",
    "Trace",
    "accelerators",
    "builder",
    "concurrent_config_roofline",
    "config_bound",
    "effective_config_bandwidth",
    "evaluate",
    "evaluate_levels",
    "geomean",
    "gemmini_like",
    "interp",
    "ir",
    "knee_point",
    "matmul_driver",
    "opengemm_like",
    "passes",
    "processor_roofline",
    "roofsurface",
    "run",
    "sequential_config_roofline",
    "speedup",
]
