"""Optimization passes over accfg IR — the paper's §5.2 pipeline.

Pipelines:

* :func:`baseline` — what a C compiler can do around ``volatile`` inline
  assembly (§3.1): constant folding and per-iteration CSE of the pure arith,
  but *no* transformation may touch, reorder, or eliminate the (volatile)
  setup sequences, and packing chains feeding them cannot be hoisted.
* :func:`optimize` — the accfg pipeline (Figure 8, steps 2–4): state tracing,
  branch hoisting + loop-invariant setup hoisting + configuration
  deduplication, then configuration–computation overlap for concurrent
  targets, with canonicalization (CSE / LICM / const-fold / DCE) in between —
  all legal now because setups declare their effects (§5.2).
"""

from __future__ import annotations

from ..ir import Module
from .canonicalize import canonicalize, constant_fold_and_cse
from .dedup import dedup, hoist_setups_into_branches
from .licm import hoist_invariant_setup_fields
from .overlap import overlap
from .state_tracing import trace_states

__all__ = [
    "baseline",
    "optimize",
    "trace_states",
    "canonicalize",
    "dedup",
    "hoist_setups_into_branches",
    "hoist_invariant_setup_fields",
    "overlap",
]


def baseline(module: Module) -> Module:
    """GCC-around-volatile-asm model: fold + CSE only (no cross-loop motion of
    the operand chains feeding volatile setups, no setup rewrites)."""
    constant_fold_and_cse(module)
    return module


def optimize(
    module: Module,
    concurrent_accels: set[str] | frozenset[str] = frozenset(),
    do_dedup: bool = True,
    do_overlap: bool = True,
) -> Module:
    trace_states(module)  # step 2: connect setup clusters
    canonicalize(module)
    if do_dedup:  # step 3: redundant setup elimination
        hoist_setups_into_branches(module)
        hoist_invariant_setup_fields(module)
        dedup(module)
        canonicalize(module)
    if do_overlap and concurrent_accels:  # step 4: configuration overlap
        overlap(module, set(concurrent_accels))
        canonicalize(module)
    return module
