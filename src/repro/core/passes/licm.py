"""Loop-invariant setup-field hoisting (§5.4.1).

Follows MLIR's LICM with the paper's additional constraint: a parameter may
only be hoisted if it stays constant throughout the *whole* loop body — i.e.
the loop contains exactly one setup for that accelerator, and the field's
operand is defined outside the loop. Hoisted fields are moved into a new
setup right in front of the loop (Figure 9, middle), chained into the loop's
threaded state.
"""

from __future__ import annotations

from .. import ir
from ..ir import Module, Op


def hoist_invariant_setup_fields(module: Module) -> int:
    hoisted = 0
    for loop in [op for op in module.walk() if op.name == "scf.for"]:
        hoisted += _hoist_from_loop(loop)
    return hoisted


def _hoist_from_loop(loop: Op) -> int:
    body = loop.regions[0].block
    parent = loop.parent
    if parent is None:
        return 0

    # group top-level setups of the body by accelerator
    by_accel: dict[str, list[Op]] = {}
    for op in body.ops:
        if op.name == "accfg.setup":
            by_accel.setdefault(op.attrs["accel"], []).append(op)

    hoisted = 0
    for accel, setups in by_accel.items():
        if len(setups) != 1:
            continue  # two launches with different parameters: not hoistable (§5.4.1)
        setup_op = setups[0]
        in_state = ir.setup_in_state(setup_op)
        # state tracing must have threaded the state through the loop
        if in_state is None or not (in_state.is_block_arg and in_state.block is body):
            continue
        arg_idx = body.args.index(in_state) - 1  # 0 is the induction variable
        init = ir.for_iter_inits(loop)[arg_idx]

        invariant = {
            name: value
            for name, value in ir.setup_fields(setup_op).items()
            if not ir.defined_in(value, loop)
        }
        if not invariant:
            continue

        pre = ir.setup(accel, invariant, init)
        parent.insert_before(loop, pre)
        loop.operands[3 + arg_idx] = pre.result

        remaining = {
            k: v for k, v in ir.setup_fields(setup_op).items() if k not in invariant
        }
        setup_op.attrs["fields"] = list(remaining.keys())
        setup_op.operands = list(remaining.values()) + [in_state]
        hoisted += len(invariant)
    return hoisted
