"""Configuration deduplication (§5.4) + setup hoisting into branches (§5.4.1).

The pass walks the use-def chain of ``!accfg.state`` values to reconstruct,
per state, a map of configuration fields whose contents are *known as SSA
values*. A field write is redundant — and removed — when the traced input
state provably already holds the same SSA value. SSA-value identity is the
equivalence proxy: an SSA value never changes, so equal values imply equal
runtime register contents (§5.4). Loop-carried values (e.g. addresses derived
from the induction variable) are naturally distinct SSA values per iteration
and are never deduplicated.

Control flow is handled by *intersection*: the known map of a loop-carried
state is ``known(init) ∩ known(yielded)`` (fixpoint, computed optimistically
with a TOP marker on the back-edge), and an ``scf.if`` state result meets the
two branch yields.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .. import ir
from ..ir import Module, Op, Value


@dataclass(frozen=True)
class Known:
    """Abstract register file: ``fields`` maps register → SSA value; ``rest``
    says what we know about unlisted registers ("top" = preserved through the
    back-edge being computed; "unknown" = anything)."""

    fields: dict[str, Value] = field(default_factory=dict)
    rest: str = "unknown"  # "top" | "unknown"

    def lookup(self, name: str) -> Value | None:
        return self.fields.get(name)

    def with_writes(self, writes: dict[str, Value]) -> "Known":
        merged = dict(self.fields)
        merged.update(writes)
        return Known(merged, self.rest)


TOP = Known({}, "top")
UNKNOWN = Known({}, "unknown")

_SENTINEL_CONFLICT = object()


def intersect(a: Known, b: Known) -> Known:
    if a.rest == "top" and not a.fields:
        return b
    if b.rest == "top" and not b.fields:
        return a
    out: dict[str, Value] = {}
    for key in set(a.fields) | set(b.fields):
        va = a.fields.get(key, _SENTINEL_CONFLICT if a.rest == "unknown" else None)
        vb = b.fields.get(key, _SENTINEL_CONFLICT if b.rest == "unknown" else None)
        if va is None:  # a preserves: take b's knowledge
            va = vb
        if vb is None:
            vb = va
        if va is vb and va is not _SENTINEL_CONFLICT and va is not None:
            out[key] = va
    rest = "top" if (a.rest == "top" and b.rest == "top") else "unknown"
    return Known(out, rest)


class KnownMaps:
    """Memoized known-map computation over state values."""

    def __init__(self) -> None:
        self._cache: dict[int, Known] = {}
        self._in_progress: set[int] = set()

    def of(self, state: Value) -> Known:
        key = id(state)
        if key in self._cache:
            return self._cache[key]
        if key in self._in_progress:
            return TOP  # optimistic back-edge: "preserved"; intersection fixes it
        self._in_progress.add(key)
        try:
            result = self._compute(state)
        finally:
            self._in_progress.discard(key)
        self._cache[key] = result
        return result

    def _compute(self, state: Value) -> Known:
        if state.is_block_arg:
            block = state.block
            loop = block.parent.parent if block.parent else None
            if loop is not None and loop.name == "scf.for":
                idx = block.args.index(state) - 1  # skip induction variable
                init = ir.for_iter_inits(loop)[idx]
                yielded = ir.for_yield(loop).operands[idx]
                return intersect(self.of(init), self.of(yielded))
            return UNKNOWN  # e.g. function argument
        owner = state.owner
        assert owner is not None
        if owner.name == "accfg.setup":
            in_state = ir.setup_in_state(owner)
            base = self.of(in_state) if in_state is not None else UNKNOWN
            return base.with_writes(ir.setup_fields(owner))
        if owner.name == "scf.for":
            idx = owner.results.index(state)
            init = ir.for_iter_inits(owner)[idx]
            yielded = ir.for_yield(owner).operands[idx]
            return intersect(self.of(init), self.of(yielded))
        if owner.name == "scf.if":
            idx = owner.results.index(state)
            then_term, else_term = ir.if_yields(owner)
            return intersect(self.of(then_term.operands[idx]), self.of(else_term.operands[idx]))
        return UNKNOWN


def _remove_fields(op: Op, names: set[str]) -> None:
    fields = ir.setup_fields(op)
    in_state = ir.setup_in_state(op)
    kept = {k: v for k, v in fields.items() if k not in names}
    op.attrs["fields"] = list(kept.keys())
    op.attrs["has_in_state"] = in_state is not None
    op.operands = list(kept.values()) + ([in_state] if in_state is not None else [])


def dedup(module: Module) -> int:
    """Remove provably redundant field writes. Returns #fields removed."""
    maps = KnownMaps()
    removed = 0
    # compute first, mutate after: removing a *redundant* write never changes
    # any state's contents, so the memoized maps stay valid.
    plan: list[tuple[Op, set[str]]] = []
    for op in module.walk():
        if op.name != "accfg.setup":
            continue
        in_state = ir.setup_in_state(op)
        if in_state is None:
            continue
        prior = maps.of(in_state)
        redundant = {f for f, v in ir.setup_fields(op).items() if prior.lookup(f) is v}
        if redundant:
            plan.append((op, redundant))
    for op, redundant in plan:
        _remove_fields(op, redundant)
        removed += len(redundant)
    return removed


# --------------------------------------------------------------------------
# Hoisting setups into branches (§5.4.1)
# --------------------------------------------------------------------------


def hoist_setups_into_branches(module: Module) -> int:
    """If a setup's input state comes out of an ``scf.if``, clone it into both
    branches so each side regains a linear setup chain for dedup."""
    hoisted = 0
    changed = True
    while changed:
        changed = False
        for op in list(module.walk()):
            if op.name != "accfg.setup" or op.parent is None:
                continue
            in_state = ir.setup_in_state(op)
            if in_state is None or in_state.owner is None:
                continue
            if_op = in_state.owner
            if if_op.name != "scf.if" or if_op.parent is not op.parent:
                continue
            # no other op may consume the if's state between the if and the setup
            block = op.parent
            between = block.ops[block.ops.index(if_op) + 1 : block.ops.index(op)]
            if any(in_state in o.operands for o in between):
                continue
            # all field operands must dominate the scf.if
            if any(ir.defined_in(v, if_op) for v in ir.setup_fields(op).values()):
                continue
            if any(
                v.owner is not None
                and v.owner.parent is block
                and block.ops.index(v.owner) > block.ops.index(if_op)
                for v in ir.setup_fields(op).values()
            ):
                continue
            idx = if_op.results.index(in_state)
            then_term, else_term = ir.if_yields(if_op)
            for term in (then_term, else_term):
                clone = ir.setup(
                    op.attrs["accel"], dict(ir.setup_fields(op)), term.operands[idx]
                )
                term.parent.insert_before(term, clone)
                term.operands[idx] = clone.result
            # the if's state result now carries the post-setup state
            for use in module.walk():
                if use is not op:
                    use.replace_operand(op.result, in_state)
            ir.erase(op)
            hoisted += 1
            changed = True
            break
    return hoisted
