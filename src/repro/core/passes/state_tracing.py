"""State tracing (§5.3): connect ``accfg.setup`` ops through control flow.

Introduces a live state variable per accelerator, memory-SSA style: chains
straight-line setups, threads states through ``scf.for`` iter_args and
``scf.if`` results, and makes pessimistic assumptions about opaque calls
(``#accfg.effects<all>``). After this pass, every setup that has a statically
known predecessor carries it as its ``in_state`` operand — the substrate both
deduplication and overlap build on.

Where no predecessor state exists (e.g. the first setup lives inside a loop),
an *empty* setup is materialized in front of the region, exactly as in the
paper's Figure 9 (``%state = accfg.setup to ()``): it writes nothing and
represents the unknown-but-live register file.
"""

from __future__ import annotations

from .. import ir
from ..ir import Block, Module, Op, Value

# live-map entry sentinel: the accelerator's registers were clobbered by an
# opaque operation and no SSA value represents them.
_CLOBBERED = None


def accels_in(op: Op) -> set[str]:
    return {
        inner.attrs["accel"]
        for inner in op.walk()
        if inner.name in ("accfg.setup", "accfg.launch")
    }


def has_clobber(op: Op) -> bool:
    return any(
        inner.name == "func.call" and inner.attrs.get("effects", "all") == "all"
        for inner in op.walk()
    )


def trace_states(module: Module) -> None:
    for fn in module.ops:
        if fn.name == "func.func":
            _trace_block(fn.regions[0].block, {})


def _empty_setup_before(block: Block, anchor: Op, accel: str) -> Value:
    empty = ir.setup(accel, {}, None)
    block.insert_before(anchor, empty)
    return empty.result


def _empty_setup_before_terminator(block: Block, accel: str) -> Value:
    term = block.ops[-1]
    empty = ir.setup(accel, {}, None)
    block.insert_before(term, empty)
    return empty.result


def _trace_block(block: Block, live: dict[str, Value | None]) -> dict[str, Value | None]:
    for op in list(block.ops):
        if op.name == "accfg.setup":
            accel = op.attrs["accel"]
            if ir.setup_in_state(op) is None and live.get(accel) is not None:
                ir.set_setup_in_state(op, live[accel])
            live[accel] = op.result
        elif op.name == "func.call" and op.attrs.get("effects", "all") == "all":
            live = {k: _CLOBBERED for k in live}
        elif op.name == "scf.for":
            live = _trace_for(block, op, live)
        elif op.name == "scf.if":
            live = _trace_if(op, live)
    return live


def _trace_for(block: Block, loop: Op, live: dict[str, Value | None]) -> dict[str, Value | None]:
    body = loop.regions[0].block
    touched = accels_in(loop)
    threaded: dict[str, tuple[Value, Value, int]] = {}  # accel -> (arg, result, yield idx)
    for accel in sorted(touched):
        init = live.get(accel)
        if init is None:
            init = _empty_setup_before(block, loop, accel)
        arg, result = ir.add_iter_arg(loop, init, init)  # yield placeholder: fixed below
        threaded[accel] = (arg, result, len(ir.for_yield(loop).operands) - 1)

    inner_live: dict[str, Value | None] = dict(live)
    for accel, (arg, _, _) in threaded.items():
        inner_live[accel] = arg
    out = _trace_block(body, inner_live)

    yld = ir.for_yield(loop)
    for accel, (arg, result, idx) in threaded.items():
        final = out.get(accel)
        if final is None:  # clobbered inside the body: yield a fresh unknown state
            final = _empty_setup_before_terminator(body, accel)
        yld.operands[idx] = final
        live[accel] = result

    if has_clobber(loop):  # loop body may clobber non-threaded accelerators too
        for accel in list(live):
            if accel not in threaded:
                live[accel] = _CLOBBERED
    return live


def _trace_if(op: Op, live: dict[str, Value | None]) -> dict[str, Value | None]:
    then_blk, else_blk = op.regions[0].block, op.regions[1].block
    then_live = _trace_block(then_blk, dict(live))
    else_live = _trace_block(else_blk, dict(live))

    for accel in sorted(set(then_live) | set(else_live) | accels_in(op)):
        tv = then_live.get(accel, live.get(accel))
        ev = else_live.get(accel, live.get(accel))
        if tv is ev:  # untouched on both paths (or clobbered on both)
            live[accel] = tv
            continue
        if tv is None:
            tv = _empty_setup_before_terminator(then_blk, accel)
        if ev is None:
            ev = _empty_setup_before_terminator(else_blk, accel)
        live[accel] = ir.add_if_result(op, tv, ev)
    return live
