"""Configuration–computation overlap (§5.5).

For concurrent-configuration targets, reschedule setup sequences to run while
the accelerator is busy:

* **Loop pipelining** (Figure 9, right): for a loop body of the canonical
  ``setup → launch → await`` form, peel iteration-0's setup in front of the
  loop (induction variable replaced by the lower bound), launch from the
  loop-carried state, and stage iteration ``i+1``'s setup *between* launch and
  await. The setup sequence — the setup op plus the pure ops computing its
  fields — must be pure and depend only on the induction variable and
  loop-invariants.
* **Straight-line motion**: a setup whose operands all dominate an earlier
  ``await`` in the same block is moved up in front of that await.
"""

from __future__ import annotations

from .. import ir
from ..ir import Block, Module, Op, Value


def overlap(module: Module, concurrent_accels: set[str]) -> int:
    moved = 0
    for loop in [op for op in module.walk() if op.name == "scf.for"]:
        moved += _pipeline_loop(loop, concurrent_accels)
    for fn in module.ops:
        if fn.name == "func.func":
            for block in _all_blocks(fn):
                moved += _straight_line(block, concurrent_accels)
    return moved


def _all_blocks(op: Op) -> list[Block]:
    blocks = []
    for inner in op.walk():
        for region in inner.regions:
            blocks.append(region.block)
    return blocks


# --------------------------------------------------------------------------
# Loop pipelining
# --------------------------------------------------------------------------


def _pure_slice(setup_op: Op, body: Block, iv: Value) -> list[Op] | None:
    """Backward slice of the setup's field operands inside ``body``. Returns
    the slice in execution order, or None if it contains impure ops or leaves
    other than the induction variable / loop-external values."""
    loop = body.parent.parent
    slice_ops: list[Op] = []
    seen: set[int] = set()

    def visit(value: Value) -> bool:
        if value is iv or not ir.defined_in(value, loop):
            return True
        if value.is_block_arg:
            return False  # an iter_arg (e.g. the state): not movable
        owner = value.owner
        assert owner is not None
        if owner.parent is not body:
            return False
        if not ir.is_pure(owner):
            return False
        if id(owner) not in seen:
            seen.add(id(owner))
            for o in owner.operands:
                if not visit(o):
                    return False
            slice_ops.append(owner)
        return True

    for v in ir.setup_fields(setup_op).values():
        if not visit(v):
            return None
    return slice_ops


def _enclosing_function(op: Op) -> Op | None:
    node = op
    while node is not None:
        if node.name == "func.func":
            return node
        block = node.parent
        if block is None or block.parent is None:
            return None
        node = block.parent.parent
    return None


def _escape_is_safe(root: Op, state: Value, affected: frozenset, seen: set) -> bool:
    """Pipelining stages one extra setup whose writes (``affected`` fields)
    are observable through the loop's escaping state. That is only sound if
    every path from the escaping state to a later ``launch`` first rewrites
    all affected fields (or never reaches a launch)."""
    if (id(state), affected) in seen:
        return True
    seen.add((id(state), affected))
    for op in root.walk():
        for operand in op.operands:
            if operand is not state:
                continue
            if op.name == "accfg.launch":
                return False  # stale staged fields would be launched
            if op.name == "accfg.setup":
                remaining = affected - frozenset(op.attrs["fields"])
                if remaining and not _escape_is_safe(root, op.result, remaining, seen):
                    return False
            elif op.name == "scf.yield":
                parent_op = op.parent.parent.parent if op.parent.parent else None
                if parent_op is None:
                    continue
                idx = op.operands.index(operand)
                if parent_op.name == "scf.for":
                    arg = parent_op.regions[0].block.args[1 + idx]
                    if not _escape_is_safe(root, arg, affected, seen):
                        return False
                if idx < len(parent_op.results) and not _escape_is_safe(
                    root, parent_op.results[idx], affected, seen
                ):
                    return False
            elif op.name == "scf.for":
                # used as an iter init: flows into the block arg and result
                idx = op.operands.index(operand) - 3
                if idx >= 0:
                    arg = op.regions[0].block.args[1 + idx]
                    if not _escape_is_safe(root, arg, affected, seen):
                        return False
                    if not _escape_is_safe(root, op.results[idx], affected, seen):
                        return False
    return True


def _scan_successors(ops, accel: str, fields: frozenset) -> tuple[bool, frozenset]:
    """Walk ops in program order tracking which staged fields are still
    physically live in the register file. A same-accelerator launch while any
    staged field survives would observe the pipelined (future) configuration.
    Opaque calls do NOT sanitize — registers retain values across them."""
    for op in ops:
        if op.name == "accfg.setup" and op.attrs["accel"] == accel:
            fields = fields - frozenset(op.attrs["fields"])
        elif op.name == "accfg.launch" and op.attrs["accel"] == accel:
            if fields:
                return False, fields
        elif op.name == "scf.if":
            s1, f1 = _scan_successors(op.regions[0].block.ops, accel, fields)
            s2, f2 = _scan_successors(op.regions[1].block.ops, accel, fields)
            if not (s1 and s2):
                return False, fields
            fields = f1 | f2  # either branch may have executed
        elif op.name == "scf.for":
            s1, f1 = _scan_successors(op.regions[0].block.ops, accel, fields)
            if not s1:
                return False, fields
            fields = fields | f1  # 0-trip leaves fields; ≥1 trip leaves f1
    return True, fields


def _physically_safe(loop: Op, accel: str, affected: frozenset) -> bool:
    """The staged extra setup must never be observed by a later launch via
    the *physical* register file — including paths where opaque calls broke
    the SSA state chain (analysis barrier ≠ register reset)."""
    node: Op = loop
    fields = affected
    while True:
        block = node.parent
        if block is None:
            return True
        idx = block.ops.index(node)
        ok, fields = _scan_successors(block.ops[idx + 1 :], accel, fields)
        if not ok:
            return False
        if not fields:
            return True
        region = block.parent
        parent_op = region.parent if region is not None else None
        if parent_op is None or parent_op.name == "func.func":
            return True
        if parent_op.name == "scf.for":
            # next iteration of the enclosing loop re-executes its body
            ok, f1 = _scan_successors(block.ops, accel, fields)
            if not ok:
                return False
            fields = fields | f1
        node = parent_op


def _pipeline_loop(loop: Op, concurrent: set[str]) -> int:
    body = loop.regions[0].block
    parent = loop.parent
    if parent is None:
        return 0
    iv = body.args[0]
    lb, _ub, step = loop.operands[0], loop.operands[1], loop.operands[2]

    # find the canonical trio per concurrent accelerator
    trios: list[tuple[Op, Op, Op]] = []
    for accel in sorted(concurrent):
        setups = [o for o in body.ops if o.name == "accfg.setup" and o.attrs["accel"] == accel]
        launches = [o for o in body.ops if o.name == "accfg.launch" and o.attrs["accel"] == accel]
        if len(setups) != 1 or len(launches) != 1:
            continue
        s, l = setups[0], launches[0]
        if l.operands[0] is not s.result:
            continue
        awaits = [o for o in body.ops if o.name == "accfg.await" and o.operands[0] is l.result]
        if len(awaits) != 1:
            continue
        w = awaits[0]
        if not (body.ops.index(s) < body.ops.index(l) < body.ops.index(w)):
            continue
        trios.append((s, l, w))

    moved = 0
    for s, l, w in trios:
        in_state = ir.setup_in_state(s)
        if in_state is None or not (in_state.is_block_arg and in_state.block is body):
            continue
        arg_idx = body.args.index(in_state) - 1
        # the loop must yield this setup's state (state tracing guarantees it)
        yld = ir.for_yield(loop)
        if yld.operands[arg_idx] is not s.result:
            continue
        slice_ops = _pure_slice(s, body, iv)
        if slice_ops is None:
            continue

        # soundness: the staged extra setup escapes through the loop result
        # (SSA) AND through the physical register file (which opaque calls do
        # not reset) — no later launch may observe its fields un-rewritten
        fn = _enclosing_function(loop)
        affected = frozenset(s.attrs["fields"])
        if fn is not None and not _escape_is_safe(
            fn, loop.results[arg_idx], affected, set()
        ):
            continue
        if not _physically_safe(loop, s.attrs["accel"], affected):
            continue

        # 1. prologue: clone slice + setup before the loop with iv -> lb
        mapping: dict[Value, Value] = {iv: lb}
        for op in slice_ops:
            clone = ir.clone_op(op, mapping)
            parent.insert_before(loop, clone)
        init = ir.for_iter_inits(loop)[arg_idx]
        pre_setup = ir.setup(
            s.attrs["accel"],
            {k: mapping.get(v, v) for k, v in ir.setup_fields(s).items()},
            init,
        )
        parent.insert_before(loop, pre_setup)
        loop.operands[3 + arg_idx] = pre_setup.result

        # 2. launch from the loop-carried (staged-last-iteration) state
        l.replace_operand(s.result, in_state)

        # 3. stage iteration i+1 between launch and await
        iv_next_op = ir.binary("arith.addi", iv, step)
        body.insert_after(l, iv_next_op)
        next_mapping: dict[Value, Value] = {iv: iv_next_op.result}
        anchor = iv_next_op
        for op in slice_ops:
            clone = ir.clone_op(op, next_mapping)
            body.insert_after(anchor, clone)
            anchor = clone
        s_fields = {
            k: next_mapping.get(v, v) for k, v in ir.setup_fields(s).items()
        }
        new_setup = ir.setup(s.attrs["accel"], s_fields, in_state)
        body.insert_after(anchor, new_setup)
        # re-point every use of the old setup's state (yield, launches later)
        for use in loop.walk():
            if use is not new_setup:
                use.replace_operand(s.result, new_setup.result)
        ir.erase(s)
        moved += 1
    return moved


# --------------------------------------------------------------------------
# Straight-line motion
# --------------------------------------------------------------------------


def _straight_line(block: Block, concurrent: set[str]) -> int:
    moved = 0
    changed = True
    while changed:
        changed = False
        for idx, op in enumerate(block.ops):
            if op.name != "accfg.setup" or op.attrs["accel"] not in concurrent:
                continue
            target = _earliest_await(block, idx, op)
            if target is not None:
                block.remove(op)
                block.insert_before(target, op)
                moved += 1
                changed = True
                break
    return moved


def _earliest_await(block: Block, setup_idx: int, setup_op: Op) -> Op | None:
    """Earliest await (of a different accelerator invocation) the setup can
    move in front of: all of the setup's operands must be defined before it."""
    operands = set(map(id, setup_op.operands))
    best: Op | None = None
    for j in range(setup_idx - 1, -1, -1):
        op = block.ops[j]
        if any(id(r) in operands for r in op.results):
            break
        if op.name == "accfg.await":
            best = op
        elif op.name in ("accfg.launch", "accfg.setup") and op.attrs.get(
            "accel"
        ) == setup_op.attrs["accel"]:
            break  # don't cross same-accelerator configuration traffic
        elif op.name in ("scf.for", "scf.if", "func.call"):
            break
    return best
