"""Canonicalization: constant folding, CSE, arith LICM, DCE, setup cleanups.

These mirror "MLIR's already implemented optimizations ... more aggressive
constant folding, common-subexpression-elimination and loop-invariant code
motion" which the accfg dialect unlocks by declaring effects instead of hiding
behind volatile asm (§5.2), plus the two clean-up rewrites from §5.4.1:
removing empty setups and merging launch-free consecutive setups.
"""

from __future__ import annotations

from typing import Any

from .. import ir
from ..ir import Block, Module, Op, Value


# --------------------------------------------------------------------------
# Constant folding + CSE (scoped by dominance: nested blocks see outer defs)
# --------------------------------------------------------------------------


def constant_fold_and_cse(module: Module, cross_iteration: bool = False) -> None:
    """Fold arith on constants and deduplicate pure ops.

    ``cross_iteration=False`` models the baseline compiler: values are only
    reused within one straight-line stretch (loop bodies keep their own
    copies, as re-materialized around volatile asm). ``True`` is the accfg
    pipeline: full scoped CSE + LICM below.
    """
    for fn in module.ops:
        if fn.name == "func.func":
            _fold_cse_block(fn.regions[0].block, [{}], module, cross_iteration)


def _const_of(v: Value) -> int | None:
    if v.owner is not None and v.owner.name == "arith.constant":
        return v.owner.attrs["value"]
    return None


def _cse_key(op: Op) -> tuple[Any, ...]:
    return (op.name, tuple(id(o) for o in op.operands), tuple(sorted(op.attrs.items())))


def _fold_cse_block(
    block: Block, scopes: list[dict[tuple, Value]], module: Module, cross: bool
) -> None:
    seen = scopes[-1]
    for op in list(block.ops):
        for region in op.regions:
            _fold_cse_block(region.block, scopes + [{}], module, cross)
        if not ir.is_pure(op):
            continue
        # constant folding
        if op.name in ir._BINARY_FNS:
            a, b = (_const_of(o) for o in op.operands)
            if a is not None and b is not None:
                folded = ir.constant(ir._BINARY_FNS[op.name](a, b), op.result.type)
                block.insert_before(op, folded)
                _replace_uses_everywhere(module, op.result, folded.result)
                block.remove(op)
                op = folded
        elif op.name == "arith.cmpi":
            a, b = (_const_of(o) for o in op.operands)
            if a is not None and b is not None:
                folded = ir.constant(int(ir._CMP_FNS[op.attrs["pred"]](a, b)), ir.I1)
                block.insert_before(op, folded)
                _replace_uses_everywhere(module, op.result, folded.result)
                block.remove(op)
                op = folded
        # CSE
        key = _cse_key(op)
        existing = None
        lookup: list[dict[tuple, Value]] = scopes if cross else [seen]
        for scope in reversed(lookup):
            if key in scope:
                existing = scope[key]
                break
        if existing is not None and existing is not op.result:
            _replace_uses_everywhere(module, op.result, existing)
            block.remove(op)
        else:
            seen[key] = op.result


def _replace_uses_everywhere(module: Module, old: Value, new: Value) -> None:
    for op in module.walk():
        op.replace_operand(old, new)


# --------------------------------------------------------------------------
# LICM for pure arith (models MLIR's LICM, enabled by accfg's effect info)
# --------------------------------------------------------------------------


def licm_arith(module: Module) -> None:
    changed = True
    while changed:
        changed = False
        for loop in [op for op in module.walk() if op.name == "scf.for"]:
            body = loop.regions[0].block
            parent = loop.parent
            if parent is None:
                continue
            for op in list(body.ops):
                if not ir.is_pure(op):
                    continue
                if all(not ir.defined_in(o, loop) for o in op.operands):
                    body.remove(op)
                    parent.insert_before(loop, op)
                    changed = True


# --------------------------------------------------------------------------
# DCE
# --------------------------------------------------------------------------


def dce(module: Module) -> None:
    changed = True
    while changed:
        changed = False
        used: set[int] = set()
        for op in module.walk():
            for o in op.operands:
                used.add(id(o))
        for op in list(module.walk()):
            if op.parent is None:
                continue
            if ir.is_pure(op) and not any(id(r) in used for r in op.results):
                ir.erase(op)
                changed = True
            elif op.name == "accfg.setup" and not op.attrs["fields"]:
                # empty setup: forward its input state if it has one
                in_state = ir.setup_in_state(op)
                if in_state is not None:
                    _replace_uses_everywhere(module, op.result, in_state)
                    ir.erase(op)
                    changed = True
                elif id(op.result) not in used:
                    ir.erase(op)
                    changed = True


# --------------------------------------------------------------------------
# Setup merging (§5.4.1 clean-up: merge setups with no launch in between)
# --------------------------------------------------------------------------


def merge_consecutive_setups(module: Module) -> None:
    changed = True
    while changed:
        changed = False
        for op in list(module.walk()):
            if op.name != "accfg.setup" or op.parent is None:
                continue
            in_state = ir.setup_in_state(op)
            if in_state is None or in_state.owner is None:
                continue
            prev = in_state.owner
            if prev.name != "accfg.setup" or prev.parent is not op.parent:
                continue
            if prev.attrs["accel"] != op.attrs["accel"]:
                continue
            # the previous setup's state must feed only this setup
            if len(ir.uses(_root(module, op), in_state)) != 1:
                continue
            # no launch of this accel may sit between the two setups
            ops_between = _between(op.parent, prev, op)
            if any(o.name == "accfg.launch" for o in ops_between):
                continue
            merged = dict(ir.setup_fields(prev))
            merged.update(ir.setup_fields(op))  # later writes win
            new = ir.setup(op.attrs["accel"], merged, ir.setup_in_state(prev))
            # insert at the *later* op's position: all operands of both setups
            # dominate it, and nothing in between observes the register file
            # (no launch between — checked above).
            op.parent.insert_before(op, new)
            _replace_uses_everywhere(module, op.result, new.result)
            ir.erase(prev)
            ir.erase(op)
            changed = True
            break


def _root(module: Module, op: Op) -> Module:
    return module


def _between(block: Block, a: Op, b: Op) -> list[Op]:
    ia, ib = block.ops.index(a), block.ops.index(b)
    return block.ops[ia + 1 : ib]


# --------------------------------------------------------------------------
# The full canonicalization bundle used by the accfg pipeline
# --------------------------------------------------------------------------


def canonicalize(module: Module) -> None:
    constant_fold_and_cse(module, cross_iteration=True)
    licm_arith(module)
    constant_fold_and_cse(module, cross_iteration=True)
    merge_consecutive_setups(module)
    dce(module)
