"""Evaluation harness: run a workload under each optimization level.

Reproduces the paper's measurement methodology (§6): the same source program
is compiled at four levels — baseline (volatile-asm model), +dedup, +overlap,
+both — executed on the cycle-approximate interpreter, and placed on the
configuration roofline. Functional equivalence (identical launch logs) is
asserted on every run: an optimization that changes observable accelerator
behaviour is a compiler bug, not a speedup.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass

from . import passes
from .accelerators import AcceleratorModel
from .interp import Trace, run
from .ir import Module
from .roofline import RooflinePoint


@dataclass
class LevelResult:
    level: str
    trace: Trace
    point: RooflinePoint


def evaluate(
    module_fn,
    models: dict[str, AcceleratorModel],
    levels: tuple[str, ...] = ("baseline", "dedup", "overlap", "both"),
    check_equivalence: bool = True,
) -> dict[str, LevelResult]:
    """``module_fn`` builds a fresh module (passes mutate in place)."""
    concurrent = {name for name, m in models.items() if m.concurrent}
    results: dict[str, LevelResult] = {}
    reference_log = None
    for level in levels:
        module: Module = module_fn()
        if level == "baseline":
            passes.baseline(module)
        else:
            passes.optimize(
                module,
                concurrent_accels=concurrent,
                do_dedup=level in ("dedup", "both"),
                do_overlap=level in ("overlap", "both"),
            )
        trace = run(module, models)
        if check_equivalence:
            sig = trace.log_signature()
            if reference_log is None:
                reference_log = sig
            else:
                assert sig == reference_log, f"{level}: invocation log diverged"
        model = next(iter(models.values()))
        results[level] = LevelResult(
            level=level,
            trace=trace,
            point=RooflinePoint(
                name=level,
                i_oc=trace.i_oc,
                performance=trace.performance,
                p_peak=model.p_peak,
                bw_config=model.bw_config,
            ),
        )
    return results


def speedup(results: dict[str, LevelResult], level: str = "both") -> float:
    return results["baseline"].trace.total_cycles / results[level].trace.total_cycles


# one shared definition for every BENCH_* summary (re-exported here for
# the historical call sites; non-positive terms collapse the mean to 0.0)
from .stats import geomean  # noqa: E402
