"""repro.bridge — the closed-loop serving bridge.

Until now the cluster ate *synthetic* GEMM-tile launch requests
(``cluster.traffic``), while the real decode launch path lived apart in
``serving.ServingEngine``. This package replaces the synthetic seam with
the real one: serving engines **are** the cluster's tenants, and the
multi-host roofline/SLO numbers are produced by actual
``{tokens, positions, live-mask}`` decode descriptors — the workload the
paper's §5.4 deduplicated-configuration serving design was written for.

* :mod:`~repro.bridge.descriptors` — launch-descriptor → register-field
  translation, built so the engine executor's leaf-granular descriptor
  cache and the cluster device's field-granular config-state cache make
  identical elision decisions on the same stream.
* :mod:`~repro.bridge.tenant` — :class:`TenantEngine` wraps one engine as
  one tenant: mirrors its launch stream (via ``ServingEngine.on_launch``,
  observation-only — bridged token output stays bit-identical) and states
  the exact accounting identity between the two caches.
* :mod:`~repro.bridge.driver` — :class:`ClosedLoopDriver`: a tenant emits
  its next decode launch only after the previous one completes, so
  queueing delay throttles token throughput (closed-loop — the opposite
  contract from ``run_open_loop``).
* :mod:`~repro.bridge.report` — :class:`BridgeReport`: tokens/kcycle
  goodput, per-tenant decode-latency percentiles, per-step descriptor-byte
  timelines, serving roofline points, and the engine↔cluster config-byte
  parity check.

Slot residency completes the picture: a tenant's KV cache lives on the
host that ran its first launch (``Host.adopt_context``), and a sticky
router (``Cluster(..., sticky=True)``) binds its decode launches there —
round-robin baselines keep shuffling tenants and pay full descriptor
re-sends, which ``benchmarks/serving_bridge.py`` measures as a p99
decode-latency gap at every load cell.
"""

from . import descriptors, driver, report, tenant
from .descriptors import (
    descriptor_fields,
    descriptor_nbytes,
    descriptor_request,
    leaf_digest,
    padded_nbytes,
)
from .driver import ClosedLoopDriver, StepRecord
from .report import BridgeReport, build_bridge_report
from .tenant import TenantEngine, decode_tile

__all__ = [
    "BridgeReport",
    "ClosedLoopDriver",
    "StepRecord",
    "TenantEngine",
    "build_bridge_report",
    "decode_tile",
    "descriptor_fields",
    "descriptor_nbytes",
    "descriptor_request",
    "descriptors",
    "driver",
    "leaf_digest",
    "padded_nbytes",
    "report",
    "tenant",
]
