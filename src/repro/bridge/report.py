"""BridgeReport — the closed-loop run folded into the repo's vocabulary.

Three views of one run, mutually checkable:

* the **cluster view** — the ordinary :class:`~repro.cluster.slo.ClusterReport`
  (per-launch percentiles, per-host roofline points, link telemetry), with
  token-level :class:`~repro.cluster.slo.TenantServing` stats attached;
* the **step view** — per-tenant decode-step latencies and descriptor-byte
  timelines built from the driver's :class:`StepRecord` log;
* the **accounting parity** — :meth:`BridgeReport.config_parity` compares,
  per tenant, the bytes the cluster devices report against the engine's own
  ``config_traffic()`` plus the two documented launch-path terms
  (launch-command writes, tile registers). The two caches are independent
  implementations fed the same stream; the identity holding is evidence
  that slot-residency routing preserved warmth end to end, and its failure
  is the first observable of residency loss (eviction, a spilled launch).

Serving roofline: :meth:`serving_roofline` places each tenant on the
configuration roofline with **token work over descriptor bytes** as I_OC
(``core.roofline.decode_roofline_point``) — the multi-host serving points
the paper's Eq. 4 analysis was built to answer for, now produced by the
actual decode launch path instead of a GEMM proxy.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Sequence

from ..cluster.slo import ClusterReport, TenantServing, build_report
from ..core.roofline import RooflinePoint, decode_roofline_point
from .tenant import TenantEngine

if TYPE_CHECKING:  # the driver imports this module; avoid the cycle
    from .driver import StepRecord


@dataclass
class BridgeReport:
    """Everything observed about one closed-loop bridged run."""

    cluster: ClusterReport
    steps: list["StepRecord"]
    engine_traffic: dict[str, dict[str, float]]  # tenant -> config_traffic()
    expected: dict[str, dict[str, float]]  # tenant -> expected cluster bytes
    ops_per_token: dict[str, float]  # tenant -> decode-tile ops per token
    p_peak: dict[str, float]  # tenant -> its device kind's peak ops/cycle

    # -- tokens --------------------------------------------------------------

    @property
    def metrics(self):
        """The run's unified :class:`~repro.obs.metrics.MetricsRegistry`:
        the cluster registry (every host's ``sched.*`` series under a
        ``host=`` label) with the ``bridge.*`` step-level series folded in
        by :func:`build_bridge_report`."""
        return self.cluster.metrics

    @property
    def serving(self) -> dict[str, TenantServing]:
        return self.cluster.serving

    @property
    def tokens(self) -> int:
        return self.cluster.tokens

    @property
    def tokens_per_kcycle(self) -> float:
        return self.cluster.tokens_per_kcycle

    def decode_latencies(self, tenant: str) -> list[float]:
        return [s.latency for s in self.steps if s.tenant == tenant]

    def ttft_cycles(self) -> dict[str, float]:
        """Mean admission-step latency per tenant — the closed-loop
        time-to-first-token proxy: an admission step's latency spans its
        prefill chain plus the first decode launch, so this is exactly the
        quantity chunked prefill shortens vs. token-at-a-time (prompts of
        one token admit with no prefill launch and are excluded)."""
        out: dict[str, float] = {}
        for tenant in sorted({s.tenant for s in self.steps}):
            lats = [s.latency for s in self.steps
                    if s.tenant == tenant and s.prefill_launches > 0]
            if lats:
                out[tenant] = sum(lats) / len(lats)
        return out

    # -- descriptor traffic --------------------------------------------------

    def step_timeline(self, tenant: str) -> list[tuple[float, int, int]]:
        """Per-step ``(arrival, bytes_sent, bytes_elided)`` for one tenant —
        the decode-step descriptor-byte timeline (launches of one step are
        folded; ``cluster.descriptor_timeline`` keeps them separate)."""
        return [(s.arrival, s.bytes_sent, s.bytes_elided)
                for s in self.steps if s.tenant == tenant]

    def overlap_summary(self) -> dict[str, float]:
        """How much of the run's descriptor T_set the engine hid behind
        compute (0.0 everywhere on a serialized cluster) — the bridge-level
        view of the §5.5 runtime win that shortened every feedback edge."""
        m = self.metrics
        if m is not None and m.has("bridge.config_cycles"):
            cfg = m.total("bridge.config_cycles")
            hidden = cfg - m.total("bridge.exposed_config_cycles")
        else:
            cfg = sum(s.config_cycles for s in self.steps)
            hidden = sum(s.hidden_config for s in self.steps)
        return {
            "config_cycles": cfg,
            "exposed_config_cycles": cfg - hidden,
            "hidden_config_cycles": hidden,
            "hidden_fraction": hidden / cfg if cfg else 0.0,
        }

    def tenant_bytes(self, tenant: str) -> dict[str, float]:
        """Cluster-side config bytes for one tenant, summed over hosts."""
        recs = [r for r in self.cluster.records if r.tenant == tenant]
        return {
            "bytes_sent": float(sum(r.bytes_sent for r in recs)),
            "bytes_elided": float(sum(r.bytes_elided for r in recs)),
        }

    def config_parity(self) -> dict[str, dict[str, float | bool]]:
        """Per tenant: the engine's expected accounting vs. what the
        cluster devices actually reported. ``matched`` means both the sent
        and the elided bytes agree exactly — the bridged launch path sent
        precisely the descriptor deltas the engine's own cache says it
        should have (plus the documented launch/tile terms folded into
        ``expected`` by ``TenantEngine.expected_cluster_bytes``)."""
        out: dict[str, dict[str, float | bool]] = {}
        for tenant, want in self.expected.items():
            got = self.tenant_bytes(tenant)
            out[tenant] = {
                "engine_bytes_sent": self.engine_traffic[tenant]["bytes_sent"],
                "engine_bytes_elided": self.engine_traffic[tenant]["bytes_elided"],
                "expected_bytes_sent": want["bytes_sent"],
                "expected_bytes_elided": want["bytes_elided"],
                "cluster_bytes_sent": got["bytes_sent"],
                "cluster_bytes_elided": got["bytes_elided"],
                "matched": (got["bytes_sent"] == want["bytes_sent"]
                            and got["bytes_elided"] == want["bytes_elided"]),
            }
        return out

    # -- energy --------------------------------------------------------------

    def energy_report(self):
        """Joule attribution of the whole bridged run — an
        :class:`~repro.power.meter.EnergyReport` over every host's lanes
        (empty-model lanes price to zero; the conservation invariant still
        holds). Lazy import: the bridge stays importable without the power
        stack loaded."""
        from ..power.meter import attribute_energy
        return attribute_energy(self)

    def tokens_per_joule(self) -> float:
        """The serving-efficiency figure of merit (tokens per pJ): the
        energy-roofline twin of :attr:`tokens_per_kcycle`, and what the
        power-capped bench trades against SLO attainment."""
        return self.energy_report().tokens_per_joule(self.tokens)

    def serving_energy_roofline(self) -> list:
        """One *energy*-roofline point per bridged tenant
        (:func:`~repro.core.roofline.energy_roofline_point`): ops per
        joule attained vs. operational configuration intensity, ridge in
        ops/J. The run's configuration energy is split across tenants in
        proportion to descriptor bytes sent — energy attribution is
        per-lane, not per-tenant, so the split is the documented
        approximation (exact when one tenant dominates a lane)."""
        from ..core.roofline import energy_roofline_point
        er = self.energy_report()
        config_energy = er.summary.get("config_energy", 0.0)
        total_bytes = sum(r.bytes_sent for r in self.cluster.records)
        kind_power = {}  # device kind -> compute active power (pJ/cycle)
        for host in sorted(self.cluster.hosts):
            rep = self.cluster.hosts[host]
            for name, tel in rep.resources.items():
                model = getattr(tel, "energy", None)
                if tel.kind == "compute" and model is not None:
                    # lane names are "compute[<kind>:<i>]"
                    kind = name.split("[", 1)[1].split(":", 1)[0]
                    kind_power.setdefault(kind, model.active_power)
        points = []
        for tenant, stats in sorted(self.cluster.serving.items()):
            recs = [r for r in self.cluster.records if r.tenant == tenant]
            if not recs:
                continue
            nbytes = sum(r.bytes_sent for r in recs)
            share = nbytes / total_bytes if total_bytes else 0.0
            kind = recs[0].device.rsplit(":", 1)[0]
            points.append(energy_roofline_point(
                f"serve[{tenant}]",
                total_ops=stats.tokens * self.ops_per_token[tenant],
                config_bytes=max(nbytes, 1),
                config_energy=max(config_energy * share, 1e-12),
                total_energy=max(er.total_energy * share, 1e-12),
                compute_power=kind_power.get(kind, 1e-12),
                p_peak=self.p_peak[tenant],
            ))
        return points

    # -- roofline ------------------------------------------------------------

    def serving_roofline(self) -> list[RooflinePoint]:
        """One configuration-roofline point per bridged tenant: I_OC is
        token work over the descriptor bytes actually sent for it, BW_cfg
        the effective bandwidth those bytes saw on the config port."""
        points = []
        for tenant, stats in sorted(self.cluster.serving.items()):
            recs = [r for r in self.cluster.records if r.tenant == tenant]
            if not recs:
                continue
            points.append(decode_roofline_point(
                f"serve[{tenant}]",
                tokens=stats.tokens,
                ops_per_token=self.ops_per_token[tenant],
                descriptor_bytes=max(sum(r.bytes_sent for r in recs), 1),
                config_cycles=sum(r.config_cycles for r in recs),
                makespan=self.cluster.makespan,
                p_peak=self.p_peak[tenant],
            ))
        return points


def build_bridge_report(cluster, steps: Sequence["StepRecord"],
                        tenants: Sequence[TenantEngine]) -> BridgeReport:
    """Fold the driver's step log and the cluster state into one report."""
    slo = {te.tenant: te.slo_cycles for te in tenants
           if te.slo_cycles is not None}
    report = build_report(cluster.hosts, slo=slo)
    serving = {
        te.tenant: TenantServing.from_steps(
            te.tenant,
            [s.latency for s in steps if s.tenant == te.tenant],
            te.tokens,
            report.makespan,
        )
        for te in tenants
    }
    report.attach_serving(serving)
    if report.metrics is not None:
        # step-level series beside the cluster's launch-level ones, so one
        # registry answers both "how many tokens" and "how congested"
        m = report.metrics
        for s in steps:
            m.counter("bridge.tokens", tenant=s.tenant).add(s.tokens)
            m.counter("bridge.steps", tenant=s.tenant).add(1)
            m.counter("bridge.config_cycles",
                      tenant=s.tenant).add(s.config_cycles)
            m.counter("bridge.exposed_config_cycles",
                      tenant=s.tenant).add(s.exposed_config)
            m.histogram("bridge.decode_latency",
                        tenant=s.tenant).observe(s.latency)
    return BridgeReport(
        cluster=report,
        steps=list(steps),
        engine_traffic={te.tenant: te.config_traffic() for te in tenants},
        expected={te.tenant: te.expected_cluster_bytes() for te in tenants},
        ops_per_token={
            te.tenant: 2.0 * te.dims[0] * te.dims[1] * te.dims[2]
            / max(te.engine.max_slots, 1)
            for te in tenants
        },
        p_peak={te.tenant: te.model.p_peak for te in tenants},
    )
