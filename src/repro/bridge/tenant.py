"""TenantEngine — a serving engine adapted as a cluster tenant.

One :class:`~repro.serving.engine.ServingEngine` is one tenant of the
cluster: its KV cache is the slot context that pins decode launches to a
home host, and every launch its executor stages — prefill steps and batch
decode steps alike — is mirrored, descriptor-for-descriptor, into a
:class:`~repro.sched.scheduler.LaunchRequest` whose register fields *are*
the engine's real ``{tokens, positions, live-mask}`` descriptor
(``bridge.descriptors``). The engine's compute is never touched: the
adapter observes the launch stream through ``ServingEngine.on_launch``,
so bridged token output is bit-identical to the standalone engine
(the parity test's contract).

Two caches now see the same stream — the engine executor's leaf-granular
descriptor cache (``engine.config_traffic()``) and the home device's
field-granular :class:`~repro.sched.state_cache.ConfigStateCache` — and
:meth:`TenantEngine.expected_cluster_bytes` states the exact accounting
identity between them, which ``benchmarks/serving_bridge.py`` asserts.
"""

from __future__ import annotations

from ..cluster.traffic import _pow2_tile
from ..core.accelerators import REGISTRY, AcceleratorModel
from ..sched.scheduler import LaunchRequest
from ..serving.engine import ServingEngine
from .descriptors import descriptor_request


def decode_tile(engine: ServingEngine) -> tuple[int, int, int]:
    """The per-step GEMM tile a decode launch of this engine amounts to:
    M = the slot batch, K/N = accelerator-friendly tiles of the model's
    ``d_model``/``d_ff`` — the dominant MLP GEMM of one decode step (the
    same derivation as ``cluster.traffic.TenantProfile.from_arch``)."""
    cfg = engine.model.cfg
    return (
        _pow2_tile(engine.max_slots),
        _pow2_tile(cfg.d_model),
        _pow2_tile(cfg.d_ff),
    )


class TenantEngine:
    """One bridged tenant: a serving engine plus its cluster identity.

    ``accel`` names the :data:`~repro.core.accelerators.REGISTRY` model
    standing in for the engine's device; ``dims`` overrides the decode
    GEMM tile (default: derived from the engine's model config)."""

    def __init__(
        self,
        tenant: str,
        engine: ServingEngine,
        *,
        accel: str | AcceleratorModel = "opengemm",
        dims: tuple[int, int, int] | None = None,
        priority: int = 0,
        slo_cycles: float | None = None,
    ):
        self.tenant = tenant
        self.engine = engine
        self.model = accel if isinstance(accel, AcceleratorModel) else REGISTRY[accel]
        self.dims = tuple(dims) if dims is not None else decode_tile(engine)
        self.priority = priority
        self.slo_cycles = slo_cycles
        self.tokens = 0
        self.steps = 0
        self.launches = 0
        # per-dim-field send count for the accounting identity: a dim
        # register crosses the boundary whenever its value differs from the
        # previous launch's (prefill launches scale M by the chunk length,
        # so entering/leaving prefill re-sends M while K/N stay resident)
        self._dim_sends = [0] * len(self.dims)
        self._last_dims: tuple[int, ...] | None = None
        self._pending: list[dict] = []
        assert engine.on_launch is None, (
            "engine already has a launch observer — one bridge per engine")
        engine.on_launch = self._pending.append

    @property
    def done(self) -> bool:
        """No queued requests and no live slots — the engine has drained."""
        return not (self.engine.queue or self.engine.live_slots)

    def step(self) -> tuple[int, list[dict]]:
        """Advance the engine one continuous-batching step and hand back
        the launch descriptors it actually issued (possibly several: an
        admission's prefill launches ride ahead of the decode launch)."""
        produced = self.engine.step()
        # drain in place: the engine's observer holds this very list
        descs = list(self._pending)
        self._pending.clear()
        self.tokens += produced
        self.steps += 1 if descs else 0
        self.launches += len(descs)
        return produced, descs

    def launch_dims(self, desc: dict) -> tuple[int, ...]:
        """The GEMM dims one captured launch amounts to. A chunked prefill
        launch runs ``prefill_len`` masked decode steps, so its macro-op is
        the decode tile with M scaled by the valid chunk length — the
        cluster then prices its compute honestly (``2·M·K·N``) instead of
        as a single decode step."""
        if "prefill_tokens" in desc:
            n = max(int(desc["prefill_len"]), 1)
            return (self.dims[0] * n, *self.dims[1:])
        return self.dims

    def request(self, desc: dict, arrival_time: float) -> LaunchRequest:
        """Mirror one captured descriptor into a cluster launch request.
        Calls must follow the engine's launch order — the per-dim-field
        accounting mirrors the device cache's value comparison."""
        dims = self.launch_dims(desc)
        for i, d in enumerate(dims):
            if self._last_dims is None or self._last_dims[i] != d:
                self._dim_sends[i] += 1
        self._last_dims = dims
        # tag the cost-model shape class: a calibrated scheduler prices
        # chunked prefill (M scaled by the chunk) and single-step decode
        # through the same fitted GEMM model but as distinct streams
        kernel = "prefill" if "prefill_tokens" in desc else "decode"
        return descriptor_request(
            self.tenant, desc, self.model, dims,
            arrival_time=arrival_time, priority=self.priority,
            kernel=kernel,
        )

    @property
    def sync_bytes(self) -> int:
        """The engine's per-decode-step device→host sync payload (sampled
        ids under fused sampling; full logits under host sampling)."""
        return getattr(self.engine, "sync_bytes", 0)

    def config_traffic(self) -> dict[str, float]:
        """The engine executor's own sent/elided split (leaf-granular)."""
        return self.engine.config_traffic()

    def expected_cluster_bytes(self) -> dict[str, float]:
        """What the home device's cache must report for this tenant when
        slot-residency routing held (no eviction, every launch on one
        device), stated from the engine's own accounting:

        * ``bytes_sent``  = engine bytes sent
                            + one launch-command write per launch
                            + one tile-register write per dim-field *value
                              change* (``_dim_sends`` — the first launch,
                              plus every prefill↔decode M transition);
        * ``bytes_elided`` = engine bytes elided
                             + the tile registers on every launch whose
                               value the device already held.

        With constant dims this reduces to the classic form (tile sent
        once, elided ever after). Exact whenever each descriptor leaf's
        size divides the device's ``bytes_per_field`` (int32 leaves on a
        4-byte-field device); any divergence means the cluster path dropped
        residency the engine kept — the accounting-parity failure the
        benchmark must catch."""
        t = self.engine.config_traffic()
        bpf = self.model.bytes_per_field
        tile_sends = sum(self._dim_sends)
        tile_slots = len(self.dims) * self.launches
        return {
            "bytes_sent": t["bytes_sent"] + self.launches * bpf
            + tile_sends * bpf,
            "bytes_elided": t["bytes_elided"]
            + (tile_slots - tile_sends) * bpf,
        }

    def drain(self) -> None:
        """Retire the engine's still-staged launches (end of run)."""
        self.engine.executor.drain()
