"""ClosedLoopDriver — N serving engines against the cluster, with feedback.

``Cluster.run`` / ``Scheduler.run_open_loop`` are *open-loop*: the request
stream is fixed up front and arrives on its own clock no matter how far
the pool falls behind. Production decode is not like that — a tenant
cannot ask for token *t+1* until token *t* exists. This driver closes the
loop: each tenant's next step is released only at the completion cycle of
its previous step's launches, so queueing delay does not just show up in
a percentile — it **throttles token throughput** (the cluster's
tokens/kcycle falls as ports congest, which no open-loop replay can show).

The event loop is a single min-heap over tenant ready-times (ties broken
by tenant name, so runs are deterministic): pop the earliest-ready tenant,
advance its engine one continuous-batching step (real JAX compute — the
engine's own launch path, not a synthetic proxy), mirror the step's
captured descriptors into launch requests arriving back-to-back (each
launch's arrival is its predecessor's completion — prefill chains
serialize the same way the engine's staging ring issues them), route and
dispatch them, and push the tenant back at the last launch's retirement.

On a tenant's first dispatch the chosen host adopts its slot context
(``Host.adopt_context``): under a sticky router every later launch of
that tenant is bound to this home (KV-cache residency), while non-sticky
baselines (round-robin) keep shuffling it — the A/B the benchmark runs.

Runtime config overlap (``repro.engine``) threads straight through this
loop: on an ``overlap="overlapped"`` cluster each descriptor's burst DMA
streams behind the previous launch's compute, so the launch retires
earlier, the feedback edge (``rec.end``) moves earlier, and the tenant's
next step is released sooner — hidden T_set lands directly on
``tokens_per_kcycle``, which no open-loop replay can show. Each
:class:`StepRecord` carries the step's exposed-vs-hidden config cycles so
the bridge report can say how much of the win was overlap.

The feedback edge also prices the **device→host sync** the engine blocks
on before it can schedule its next step (``TenantEngine.sync_bytes``):
under host-side sampling that is the full ``(B, vocab)`` logits tensor
crossing the boundary every decode step just to be argmaxed; under the
fused sampling kernel it is ``B`` int32 token ids. The readback crosses
the home host's link (burst DMA when the link supports it, an ordered
write otherwise; a core-local ``csr`` link prices it to ~0), so the
fused-sampling win lands where the paper says it must — on the closed
loop's tokens/kcycle, not just on descriptor byte counts."""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Mapping, Sequence

from ..cluster.router import Cluster
from ..obs.monitor import feed_step
from .report import BridgeReport, build_bridge_report
from .tenant import TenantEngine


@dataclass(frozen=True)
class StepRecord:
    """One engine step's closed-loop life on the cluster."""

    tenant: str
    step: int  # this tenant's step index
    arrival: float  # cycle the step's first launch entered the cluster
    completion: float  # cycle its last launch retired
    tokens: int  # tokens the step produced
    launches: int  # launches the step issued (prefill chains > 1)
    prefill_launches: int = 0  # ... of which were chunked-prefill launches
    bytes_sent: int = 0  # config bytes that crossed the boundary
    bytes_elided: int = 0  # config bytes resident state kept off the wire
    config_cycles: float = 0.0  # T_set of the step's descriptors
    exposed_config: float = 0.0  # ... the part the engine failed to hide
    readback_cycles: float = 0.0  # device→host sampling sync on the link
    compute_cycles: float = 0.0  # device cycles the step's macro-ops ran

    @property
    def latency(self) -> float:
        """Step latency — what a decode-latency SLO is written against."""
        return self.completion - self.arrival

    @property
    def hidden_config(self) -> float:
        """Descriptor config cycles the overlapped engine streamed behind
        compute — cycles that no longer delay this tenant's next token."""
        return self.config_cycles - self.exposed_config


class ClosedLoopDriver:
    """Drives bridged tenant engines to completion against one cluster."""

    def __init__(self, tenants: Sequence[TenantEngine], cluster: Cluster,
                 *, start_offsets: Mapping[str, float] | None = None,
                 tracer=None, monitor=None):
        assert tenants, "need at least one tenant engine"
        names = [t.tenant for t in tenants]
        assert len(set(names)) == len(names), f"duplicate tenants in {names}"
        self.tenants = {t.tenant: t for t in tenants}
        self.cluster = cluster
        self.steps: list[StepRecord] = []
        self._offsets = dict(start_offsets or {})
        # reuse the cluster's tracer by default so step spans and the
        # launch spans the hosts already emit land in one trace
        self.tracer = tracer if tracer is not None \
            else getattr(cluster, "tracer", None)
        # streaming observer (obs.monitor.StreamMonitor): fed one sample
        # batch per step under the canonical ``bridge.*`` names, so
        # windowed signals (SLO burn rate, exposed-config ratio, token
        # rate) are live *during* the run. Observation-only: feeding it
        # never moves a clock.
        self.monitor = monitor

    def _dispatch(self, te: TenantEngine, desc: dict, now: float):
        """Route + dispatch one mirrored launch; returns its
        :class:`~repro.sched.telemetry.LaunchRecord` — its ``end`` is the
        feedback edge of the closed loop. The record is matched by
        (tenant, arrival), not taken as ``launch_log[-1]``: a
        priority-carrying tenant's dispatch can preempt a staged launch,
        whose victim is re-dispatched *after* the preemptor and would
        otherwise be misread as this launch's record. A tenant never has
        two launches with one arrival time — the closed loop serializes
        its stream."""
        req = te.request(desc, arrival_time=now)
        router = self.cluster.router
        host = router.route(req, now=now)
        dev = host.dispatch(req)
        if dev is None:
            # the tenant's config-bandwidth quota window was exhausted and
            # the launch parked (``Host.dispatch`` deferred it). The closed
            # loop must observe its completion before releasing the next
            # step, so force it through at its window release edge — the
            # deferral still lands in this tenant's own step latency
            host.flush_deferred()
            devices = host.devices
        else:
            devices = [dev]
        if router.home(te.tenant) is None:
            # first launch anywhere: the KV cache materializes here
            host.adopt_context(te.tenant)
        for d in devices:
            for rec in reversed(d.telemetry.launch_log):
                if rec.tenant == req.tenant and rec.arrival == req.arrival_time:
                    return rec, host
        raise AssertionError(
            f"dispatched launch for {req.tenant!r} left no record on {host.id}")

    @staticmethod
    def _readback_cycles(te: TenantEngine, link) -> float:
        """Cycles the step's device→host sampling sync occupies on the
        serving host's link — the payload the engine *blocks on* before it
        can schedule the next step, so it lands on the feedback edge."""
        nbytes = te.sync_bytes
        if not nbytes or link is None:
            return 0.0
        if link.supports_dma:
            return link.burst_cycles(nbytes)
        return link.write_cycles(nbytes)

    def run(self, max_steps: int = 100_000) -> BridgeReport:
        """Drain every tenant engine; returns the bridged report."""
        ready = [(self._offsets.get(name, 0.0), name)
                 for name in sorted(self.tenants)]
        heapq.heapify(ready)
        total = 0
        while ready:
            now, name = heapq.heappop(ready)
            te = self.tenants[name]
            if te.done:
                continue
            produced, descs = te.step()
            total += 1
            assert total <= max_steps, f"closed loop exceeded {max_steps} steps"
            if not descs:
                # a step that launched nothing means the engine drained
                # (live slots and queue both empty) — retire the tenant
                assert te.done, f"{name} stepped without launching or draining"
                continue
            t = now
            sent = elided = 0
            cfg = exposed = comp = 0.0
            host = None
            for desc in descs:
                rec, host = self._dispatch(te, desc, t)
                t = rec.end
                sent += rec.bytes_sent
                elided += rec.bytes_elided
                cfg += rec.config_cycles
                exposed += rec.exposed_config
                comp += rec.end - rec.start
            # feedback edge: the host blocks on the step's sampling sync
            # before it can release this tenant's next step
            rb = self._readback_cycles(te, host.link if host else None)
            t += rb
            prefills = sum(1 for d in descs if "prefill_tokens" in d)
            self.steps.append(StepRecord(
                tenant=name,
                step=te.steps,
                arrival=now,
                completion=t,
                tokens=produced,
                launches=len(descs),
                prefill_launches=prefills,
                bytes_sent=sent,
                bytes_elided=elided,
                config_cycles=cfg,
                exposed_config=exposed,
                readback_cycles=rb,
                compute_cycles=comp,
            ))
            if self.monitor is not None:
                feed_step(self.monitor, tenant=name, completion=t,
                          tokens=produced, latency=t - now,
                          config_cycles=cfg, exposed_config=exposed,
                          slo_cycles=te.slo_cycles)
            if self.tracer is not None:
                self.tracer.span("step", "step", now, t,
                                 lane=f"step[{name}]", tenant=name,
                                 step=te.steps, tokens=produced,
                                 launches=len(descs),
                                 prefill_launches=prefills,
                                 bytes_sent=sent)
                self.tracer.counter("tokens", t, float(te.tokens),
                                    lane=f"tokens[{name}]", tenant=name)
            heapq.heappush(ready, (t, name))
        for te in self.tenants.values():
            te.drain()
        return build_bridge_report(self.cluster, self.steps,
                                   list(self.tenants.values()))
