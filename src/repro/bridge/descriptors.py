"""Launch-descriptor → register-field translation.

The serving engine's launch descriptor is a pytree of numpy leaves —
``{tokens, positions, live_mask}`` plus invariant sampling/shape scalars
(``serving.engine._launch_descriptor``). The cluster scheduler speaks
register files: flat ``{field name: int}`` maps whose per-field bytes are
the accelerator model's ``bytes_per_field`` and whose redundancy a
per-device :class:`~repro.sched.state_cache.ConfigStateCache` elides.

This module is the adapter between the two vocabularies, built so the two
caches — the engine executor's leaf-granular descriptor cache and the
cluster device's field-granular register cache — make **identical elision
decisions** on the same stream:

* each leaf becomes ``ceil(nbytes / bytes_per_field)`` register fields
  (``"['tokens']#0"``, ``"['tokens']#1"``, ...), so the device-side byte
  accounting prices the leaf at its true wire size (exactly, whenever the
  leaf's size divides the field width — e.g. int32 leaves on a 4-byte-field
  device);
* every field of a leaf carries the **same value**: a digest of the leaf's
  raw bytes. A leaf therefore changes *atomically* — all of its fields
  re-send together or elide together, mirroring the executor cache's
  whole-leaf comparison (`ScheduledExecutor` elides a leaf only when it is
  bit-identical to the previous launch's).

The digest is CRC-32 over the leaf's contiguous bytes — deterministic
across runs and platforms. A collision would under-count one leaf's resend
in the *cost model* (the real JAX launch always carries the full
descriptor), which is an acceptable 2^-32 accounting hazard, not a
correctness one.

Field names reuse ``jax.tree_util.keystr`` so a bridged launch's register
names line up with the executor cache's keys — one vocabulary end to end.
"""

from __future__ import annotations

import zlib

import jax
import numpy as np

from ..core.accelerators import AcceleratorModel
from ..sched.scheduler import LaunchRequest


def leaf_digest(value) -> int:
    """CRC-32 of a descriptor leaf's raw bytes (bit-exact comparison by
    proxy: equal leaves always digest equal)."""
    arr = np.ascontiguousarray(np.asarray(value))
    return zlib.crc32(arr.tobytes())


def descriptor_leaves(desc) -> list[tuple[str, np.ndarray]]:
    """``(keystr, host array)`` pairs of a launch-descriptor pytree, in the
    same flatten order the engine executor's cache sees."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(desc)
    return [(jax.tree_util.keystr(k), np.asarray(v)) for k, v in leaves]


def descriptor_nbytes(desc) -> int:
    """Wire bytes of the full descriptor (the engine cache's pricing)."""
    return sum(v.nbytes for _, v in descriptor_leaves(desc))


def padded_nbytes(desc, model: AcceleratorModel) -> int:
    """Wire bytes of the full descriptor as the cluster device prices it:
    each leaf rounded up to whole ``bytes_per_field`` registers. Equal to
    :func:`descriptor_nbytes` when every leaf divides the field width."""
    bpf = model.bytes_per_field
    return sum(-(-v.nbytes // bpf) * bpf for _, v in descriptor_leaves(desc))


def descriptor_fields(desc, model: AcceleratorModel) -> dict[str, int]:
    """Flatten a launch descriptor into the register-field map a cluster
    device caches: per-leaf word fields, all carrying the leaf's digest so
    the leaf elides or re-sends atomically."""
    bpf = model.bytes_per_field
    fields: dict[str, int] = {}
    for name, arr in descriptor_leaves(desc):
        digest = leaf_digest(arr)
        for word in range(max(1, -(-arr.nbytes // bpf))):
            fields[f"{name}#{word}"] = digest
    return fields


def descriptor_request(
    tenant: str,
    desc,
    model: AcceleratorModel,
    dims: tuple[int, int, int],
    *,
    arrival_time: float = 0.0,
    priority: int = 0,
    deadline: float | None = None,
    kernel: str = "matmul",
) -> LaunchRequest:
    """One engine launch as a cluster :class:`LaunchRequest`: the config
    payload is the *real* descriptor (as digest fields), ``dims`` sizes the
    decode macro-op (the tenant's per-step GEMM tile), ``accel`` pins the
    request to the device kind modelling the engine's accelerator, and
    ``kernel`` names the analytical cost-model shape class
    (``engine.costmodel``) — ``"decode"``/``"prefill"`` price GEMM-shaped
    launches, a calibrated scheduler ignores unknown names and falls back
    to the flat per-launch constant."""
    return LaunchRequest(
        tenant=tenant,
        dims=dims,
        extra=descriptor_fields(desc, model),
        accel=model.name,
        arrival_time=arrival_time,
        priority=priority,
        deadline=deadline,
        kernel=kernel,
    )
