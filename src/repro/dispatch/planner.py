"""Step-descriptor planner — the paper's configuration deduplication at the
XLA dispatch layer.

Every device launch is configured by a *descriptor*: the host-produced
scalars and small arrays that parameterize the step (batch offsets, KV-cache
slots, RNG seeds, MoE capacity, temperature, ...). The planner traces
descriptors across steps and splits fields into:

* **static** — provably identical on every step: hoisted out of the
  per-launch traffic (baked into the jitted closure or donated
  device-resident buffers). These are the "redundant setup writes" of §5.4.
* **dynamic** — actually changing: the only bytes that must cross the
  host→device boundary per launch.

The observed ``I_OC`` (accelerator ops per configuration byte, §4.2) rises by
``total_bytes / dynamic_bytes`` — the dispatch-layer analogue of Figure 12's
rightward movement.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class StepDescriptor:
    """One launch's configuration: a flat dict of scalars / small arrays."""

    fields: dict

    def nbytes(self, names=None) -> int:
        names = self.fields.keys() if names is None else names
        total = 0
        for n in names:
            v = self.fields[n]
            total += np.asarray(v).nbytes
        return total


@dataclass
class ConfigPlan:
    static: dict = field(default_factory=dict)
    dynamic: list = field(default_factory=list)
    total_fields: int = 0

    @classmethod
    def trace(cls, descriptors: list[StepDescriptor]) -> "ConfigPlan":
        """SSA-style equivalence across launches: a field is static iff its
        value is bit-identical in every traced descriptor (cf. §5.4's
        SSA-value equivalence proxy)."""
        assert descriptors, "need at least one traced descriptor"
        first = descriptors[0].fields
        static, dynamic = {}, []
        for name, value in first.items():
            v0 = np.asarray(value)
            same = all(
                np.array_equal(v0, np.asarray(d.fields[name])) for d in descriptors[1:]
            )
            if same:
                static[name] = value
            else:
                dynamic.append(name)
        plan = cls(static=static, dynamic=dynamic, total_fields=len(first))
        return plan

    def dynamic_descriptor(self, desc: StepDescriptor) -> dict:
        return {n: desc.fields[n] for n in self.dynamic}

    # -- roofline accounting -------------------------------------------------

    def bytes_baseline(self, desc: StepDescriptor) -> int:
        return desc.nbytes()

    def bytes_deduped(self, desc: StepDescriptor) -> int:
        return desc.nbytes(self.dynamic)

    def i_oc_gain(self, desc: StepDescriptor) -> float:
        dyn = self.bytes_deduped(desc)
        return self.bytes_baseline(desc) / dyn if dyn else float("inf")
