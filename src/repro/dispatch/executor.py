"""Sequential vs concurrent launch executors — §2.2 on a real JAX runtime.

* :class:`SequentialExecutor` is the paper's *sequential configuration*
  timeline: prepare the step's configuration on the host, launch, then
  ``block_until_ready`` before preparing the next one. Host and device take
  turns; configuration time adds to the critical path.

* :class:`ConcurrentExecutor` is *concurrent configuration*: JAX's async
  dispatch queue plays the role of OpenGeMM's staging registers. Up to
  ``depth`` launches stay in flight while the host prepares the next
  configuration, hiding host time behind device time (§5.5 overlap).

* :class:`ScheduledExecutor` is the scheduler-backed path: concurrent
  staging *plus* a :class:`~repro.sched.state_cache.ConfigStateCache` in
  front of the launch descriptors, so only fields whose values changed
  since the previous launch are counted as host→device traffic — runtime
  deduplication stacked on runtime overlap, the full `repro.sched` story
  on the real JAX runtime.

All report a timeline breakdown so benchmarks can place the measurement on
the configuration roofline (host prep time ⇒ T_calc of Eq. 4).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax
import numpy as np


@dataclass
class ExecReport:
    wall_s: float
    host_prep_s: float
    steps: int
    bytes_per_step: float
    bytes_elided_per_step: float = 0.0  # descriptor bytes the cache kept off the wire

    @property
    def steps_per_s(self) -> float:
        return self.steps / self.wall_s if self.wall_s else 0.0

    @property
    def elision_ratio(self) -> float:
        from repro.sched.state_cache import elision_ratio

        return elision_ratio(self.bytes_per_step, self.bytes_elided_per_step)


class SequentialExecutor:
    def __init__(self, device_fn, host_prep):
        self.device_fn = device_fn
        self.host_prep = host_prep

    def run(self, state, n_steps: int) -> tuple[object, ExecReport]:
        t0 = time.perf_counter()
        prep_s = 0.0
        nbytes = 0
        for step in range(n_steps):
            tp = time.perf_counter()
            args = self.host_prep(step)
            prep_s += time.perf_counter() - tp
            nbytes += sum(getattr(a, "nbytes", 0) for a in jax.tree.leaves(args))
            state = self.device_fn(state, args)
            jax.block_until_ready(state)  # sequential: host stalls per launch
        wall = time.perf_counter() - t0
        return state, ExecReport(wall, prep_s, n_steps, nbytes / max(n_steps, 1))


class ConcurrentExecutor:
    def __init__(self, device_fn, host_prep, depth: int = 2):
        self.device_fn = device_fn
        self.host_prep = host_prep
        self.depth = depth

    def run(self, state, n_steps: int) -> tuple[object, ExecReport]:
        t0 = time.perf_counter()
        prep_s = 0.0
        nbytes = 0
        inflight: deque = deque()
        for step in range(n_steps):
            tp = time.perf_counter()
            args = self.host_prep(step)  # overlaps the in-flight device work
            prep_s += time.perf_counter() - tp
            nbytes += sum(getattr(a, "nbytes", 0) for a in jax.tree.leaves(args))
            state = self.device_fn(state, args)  # async dispatch: returns early
            inflight.append(state)
            if len(inflight) > self.depth:  # bounded staging queue (§2.2)
                jax.block_until_ready(inflight.popleft())
        jax.block_until_ready(state)
        wall = time.perf_counter() - t0
        return state, ExecReport(wall, prep_s, n_steps, nbytes / max(n_steps, 1))


class _UnreadyLeaf:
    """Placeholder for a descriptor leaf still being computed on-device:
    carries its wire size but never compares equal, so accounting stays
    conservative (counted as sent) without ever forcing a sync."""

    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int):
        self.nbytes = nbytes


def _host_view(v):
    """Host-side bit-stable view of a descriptor leaf. A device array that
    is not yet ready is left opaque — the cache comparison must never block
    the pipeline it is measuring."""
    if isinstance(v, np.ndarray) or np.isscalar(v):
        return v
    is_ready = getattr(v, "is_ready", None)
    if is_ready is not None and not is_ready():
        return _UnreadyLeaf(int(getattr(v, "nbytes", 0)))
    return np.asarray(v)


def _leaf_bytes(name, v) -> int:
    from repro.sched.state_cache import nbytes_of

    return v.nbytes if isinstance(v, _UnreadyLeaf) else nbytes_of(v)


class ScheduledExecutor:
    """Concurrent staging + runtime descriptor deduplication.

    Each launch descriptor (a pytree) flows through a
    :class:`~repro.sched.state_cache.ConfigStateCache`: fields bit-identical
    to the previous launch are elided from the traffic accounting — they are
    device-resident state, exactly like an unwritten configuration register
    (§3.2/§5.4 at the runtime layer). The device still sees the full
    argument tree; what the report splits out is how many descriptor bytes
    actually needed to cross the boundary.

    Two entry points: the batch :meth:`run` loop (``host_prep`` builds each
    step's descriptor), and the incremental :meth:`launch` API that stateful
    callers — ``serving.ServingEngine``'s decode loop — drive one launch at
    a time while the executor keeps the staging ring and the traffic
    accounting. ``host_prep`` may be ``None`` for incremental use.
    """

    def __init__(self, device_fn, host_prep=None, depth: int = 2,
                 tenant: str = "exec", sync_fn=None):
        from repro.sched.state_cache import ConfigStateCache

        self.device_fn = device_fn
        self.host_prep = host_prep
        self.depth = depth
        self.tenant = tenant
        # what the staging ring blocks on: a sub-tree of device_fn's return
        # that is never donated to a later launch (callers whose device_fn
        # donates buffers — the serving engine's KV cache — pick the
        # per-launch output, e.g. the logits)
        self.sync_fn = sync_fn or (lambda out: out)
        self.cache = ConfigStateCache(max_contexts=1, bytes_of=_leaf_bytes)
        self._inflight: deque = deque()
        self._steps = 0
        self._prep_s = 0.0
        self._sent = 0
        self._elided = 0

    @property
    def launches(self) -> int:
        return self._steps

    def launch(self, state, args):
        """One staged launch: route ``args`` through the descriptor cache,
        dispatch asynchronously, and block only when the staging ring
        exceeds ``depth`` — returns whatever ``device_fn`` returned, still
        in flight.

        No-aliasing contract: numpy leaves of ``args`` are cached by
        reference, so callers must not mutate a leaf in place between
        launches (pass a fresh array or a copy, as the serving engine's
        descriptors do) — otherwise the changed field compares equal to
        itself and is misreported as elided."""
        tp = time.perf_counter()
        # the cache comparison is host descriptor work: count it as prep
        # (T_calc), and compare host-side views so accounting never forces
        # a device sync mid-pipeline
        leaves, _ = jax.tree_util.tree_flatten_with_path(args)
        plan = self.cache.dispatch(
            self.tenant,
            {jax.tree_util.keystr(k): _host_view(v) for k, v in leaves},
        )
        self._prep_s += time.perf_counter() - tp
        self._sent += plan.bytes_sent
        self._elided += plan.bytes_elided
        state = self.device_fn(state, args)  # async dispatch: returns early
        self._inflight.append(self.sync_fn(state))
        if len(self._inflight) > self.depth:
            jax.block_until_ready(self._inflight.popleft())
        self._steps += 1
        return state

    def drain(self) -> None:
        """Retire every staged launch (end-of-run / engine idle barrier)."""
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())

    def report(self, wall_s: float) -> ExecReport:
        """Cumulative traffic split over every launch so far."""
        n = max(self._steps, 1)
        return ExecReport(wall_s, self._prep_s, self._steps,
                          self._sent / n, self._elided / n)

    def run(self, state, n_steps: int) -> tuple[object, ExecReport]:
        t0 = time.perf_counter()
        steps0, sent0, elided0, prep0 = (self._steps, self._sent,
                                         self._elided, self._prep_s)
        for step in range(n_steps):
            tp = time.perf_counter()
            args = self.host_prep(step)
            self._prep_s += time.perf_counter() - tp
            state = self.launch(state, args)
        jax.block_until_ready(state)
        wall = time.perf_counter() - t0
        n = max(n_steps, 1)
        return state, ExecReport(
            wall, self._prep_s - prep0, self._steps - steps0,
            (self._sent - sent0) / n, (self._elided - elided0) / n,
        )
