"""Sequential vs concurrent launch executors — §2.2 on a real JAX runtime.

* :class:`SequentialExecutor` is the paper's *sequential configuration*
  timeline: prepare the step's configuration on the host, launch, then
  ``block_until_ready`` before preparing the next one. Host and device take
  turns; configuration time adds to the critical path.

* :class:`ConcurrentExecutor` is *concurrent configuration*: JAX's async
  dispatch queue plays the role of OpenGeMM's staging registers. Up to
  ``depth`` launches stay in flight while the host prepares the next
  configuration, hiding host time behind device time (§5.5 overlap).

Both report a timeline breakdown so benchmarks can place the measurement on
the configuration roofline (host prep time ⇒ T_calc of Eq. 4).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax


@dataclass
class ExecReport:
    wall_s: float
    host_prep_s: float
    steps: int
    bytes_per_step: float

    @property
    def steps_per_s(self) -> float:
        return self.steps / self.wall_s if self.wall_s else 0.0


class SequentialExecutor:
    def __init__(self, device_fn, host_prep):
        self.device_fn = device_fn
        self.host_prep = host_prep

    def run(self, state, n_steps: int) -> tuple[object, ExecReport]:
        t0 = time.perf_counter()
        prep_s = 0.0
        nbytes = 0
        for step in range(n_steps):
            tp = time.perf_counter()
            args = self.host_prep(step)
            prep_s += time.perf_counter() - tp
            nbytes += sum(getattr(a, "nbytes", 0) for a in jax.tree.leaves(args))
            state = self.device_fn(state, args)
            jax.block_until_ready(state)  # sequential: host stalls per launch
        wall = time.perf_counter() - t0
        return state, ExecReport(wall, prep_s, n_steps, nbytes / max(n_steps, 1))


class ConcurrentExecutor:
    def __init__(self, device_fn, host_prep, depth: int = 2):
        self.device_fn = device_fn
        self.host_prep = host_prep
        self.depth = depth

    def run(self, state, n_steps: int) -> tuple[object, ExecReport]:
        t0 = time.perf_counter()
        prep_s = 0.0
        nbytes = 0
        inflight: deque = deque()
        for step in range(n_steps):
            tp = time.perf_counter()
            args = self.host_prep(step)  # overlaps the in-flight device work
            prep_s += time.perf_counter() - tp
            nbytes += sum(getattr(a, "nbytes", 0) for a in jax.tree.leaves(args))
            state = self.device_fn(state, args)  # async dispatch: returns early
            inflight.append(state)
            if len(inflight) > self.depth:  # bounded staging queue (§2.2)
                jax.block_until_ready(inflight.popleft())
        jax.block_until_ready(state)
        wall = time.perf_counter() - t0
        return state, ExecReport(wall, prep_s, n_steps, nbytes / max(n_steps, 1))
