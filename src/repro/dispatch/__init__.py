from .executor import ConcurrentExecutor, SequentialExecutor
from .planner import ConfigPlan, StepDescriptor

__all__ = ["ConcurrentExecutor", "ConfigPlan", "SequentialExecutor", "StepDescriptor"]
