from .executor import (
    ConcurrentExecutor,
    ExecReport,
    ScheduledExecutor,
    SequentialExecutor,
)
from .planner import ConfigPlan, StepDescriptor

__all__ = [
    "ConcurrentExecutor",
    "ConfigPlan",
    "ExecReport",
    "ScheduledExecutor",
    "SequentialExecutor",
    "StepDescriptor",
]
