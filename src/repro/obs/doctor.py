"""``python -m repro.obs.doctor TRACE.json [--against OTHER.json]``

The command-line face of the diagnosis layer: feed it a trace a benchmark
wrote with ``--trace-out`` and it prints the config-wall doctor's
transcript (regime, lane table, ranked recommendations). With
``--against`` it also renders the differential decomposition of the two
runs — the triage view a CI floor failure ships as a ``DIAG_*.json``
artifact. ``--json`` writes the machine-readable version alongside.
"""

from __future__ import annotations

import argparse
import json
import sys

from . import diff as _diff
from .diagnose import diagnose_doc


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and "attribution" in doc, (
        f"{path} is not a trace with an attribution block — re-export it "
        f"with --trace-out (obs.export.write_trace embeds attribution)")
    return doc


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.doctor",
        description="Diagnose a config-wall trace: regime classification, "
                    "per-lane breakdown, ranked mitigations.")
    ap.add_argument("trace", help="TRACE_*.json written via --trace-out")
    ap.add_argument("--against", metavar="OTHER",
                    help="second trace to diff this one against "
                         "(deltas are TRACE − OTHER)")
    ap.add_argument("--json", metavar="OUT", dest="json_out",
                    help="also write the diagnosis (and diff) as JSON")
    args = ap.parse_args(argv)

    doc = load_trace(args.trace)
    diag = diagnose_doc(doc)
    print(diag.render())

    payload: dict = {"diagnosis": diag.to_dict()}
    if args.against:
        other = load_trace(args.against)
        d = _diff.diff(other, doc)  # deltas read as "this trace − baseline"
        print()
        print(_diff.render(d))
        payload["diff"] = d
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"\nwrote {args.json_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
