"""Sliding-window streaming metrics + hysteresis alerts over live runs.

The :class:`~repro.obs.metrics.MetricsRegistry` answers *whole-run*
questions (totals, exact percentiles) after the fact; a closed loop needs
the *recent-past* view while the run is still going — "what is the
exposed-config ratio over the last 5k cycles", "how fast is this tenant
burning its SLO budget", "has this host's port pressure stayed high long
enough to act on". This module is that substrate:

* :class:`WindowSeries` — one (time, value) sample stream with a fixed
  lookback window; trims lazily on read, so writers stay O(1).
* :class:`StreamMonitor` — windows keyed by ``(name, label set)`` (the
  registry's naming discipline), with derived serving signals the bridge
  feeds per step: :meth:`exposed_config_ratio`, :meth:`slo_burn_rate`,
  :meth:`token_rate`.
* :class:`SustainedThreshold` — the debounced alert primitive: a keyed
  condition must hold for ``sustain`` consecutive updates before the alert
  fires, and stays fired until the condition breaks or the subscriber
  acknowledges (:meth:`SustainedThreshold.reset`). This is the exact rule
  ``cluster.shed.ShedTrigger`` used to keep privately ("a host above k×
  the median wait for N epochs sheds"); it now *subscribes* to this
  primitive instead of owning bespoke streak bookkeeping, so any other
  policy (autoscaler, power cap) debounces identically.

Everything here is observation-only and deterministic: feeding a monitor
never changes a run's timing, mirroring the tracer's bit-identity rule.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping

from .metrics import LabelSet, labelset


class WindowSeries:
    """(t, value) samples over a fixed trailing window.

    Samples must arrive in non-decreasing time order (simulated clocks
    only move forward). Reads take ``now`` explicitly — the monitor has no
    clock of its own — and lazily drop samples older than
    ``now - window``; a sample exactly at the window edge survives
    (half-open ``(now - window, now]``, matching the engine's half-open
    interval discipline)."""

    def __init__(self, window: float):
        assert window > 0.0, window
        self.window = window
        self._t: list[float] = []
        self._v: list[float] = []

    def observe(self, t: float, value: float) -> None:
        assert not self._t or t >= self._t[-1], (
            f"samples must be time-ordered: {t} after {self._t[-1]}")
        self._t.append(float(t))
        self._v.append(float(value))

    def trim(self, now: float) -> None:
        """Drop samples at or before ``now - window``."""
        cut = now - self.window
        i = 0
        while i < len(self._t) and self._t[i] <= cut:
            i += 1
        if i:
            del self._t[:i]
            del self._v[:i]

    # -- windowed queries -----------------------------------------------------

    def count(self, now: float) -> int:
        self.trim(now)
        return len(self._v)

    def sum(self, now: float) -> float:
        self.trim(now)
        return sum(self._v)

    def mean(self, now: float) -> float:
        self.trim(now)
        return sum(self._v) / len(self._v) if self._v else 0.0

    def last(self) -> float | None:
        return self._v[-1] if self._v else None

    def rate(self, now: float) -> float:
        """Sum over the window span — e.g. tokens/cycle when fed token
        counts. The denominator is the full window width, so a sparse
        stream reads as a low rate rather than a bursty one."""
        return self.sum(now) / self.window


class SustainedThreshold:
    """Keyed debounced alert: a key's condition must hold ``sustain``
    consecutive updates before :meth:`update` reports it as fired, and it
    keeps firing every update until the condition breaks or the subscriber
    calls :meth:`reset` (acknowledging the alert — e.g. after acting on
    it). ``on_alert(key, streak)`` is invoked on the False→True firing
    edge, the hook a dashboard or log sink subscribes to."""

    def __init__(self, sustain: int = 2,
                 on_alert: Callable[[str, int], None] | None = None):
        assert sustain >= 1, sustain
        self.sustain = sustain
        self.on_alert = on_alert
        self._streak: dict[str, int] = {}

    def streak(self, key: str) -> int:
        return self._streak.get(key, 0)

    def update(self, key: str, condition: bool) -> bool:
        """Feed one observation; returns whether the alert is fired."""
        if not condition:
            self._streak[key] = 0
            return False
        streak = self._streak.get(key, 0) + 1
        self._streak[key] = streak
        fired = streak >= self.sustain
        if fired and streak == self.sustain and self.on_alert is not None:
            self.on_alert(key, streak)
        return fired

    def reset(self, key: str) -> None:
        """Acknowledge: the subscriber acted, the key must re-sustain."""
        self._streak[key] = 0


@dataclass
class Alert:
    """One registered windowed threshold (see :meth:`StreamMonitor.alert`)."""

    name: str
    labels: LabelSet
    threshold: float
    above: bool
    trigger: SustainedThreshold

    def check(self, monitor: "StreamMonitor", now: float) -> bool:
        series = monitor.window(self.name, **dict(self.labels))
        value = series.mean(now)
        hot = value > self.threshold if self.above else value < self.threshold
        return self.trigger.update(f"{self.name}{dict(self.labels)}", hot)


class StreamMonitor:
    """Sliding windows keyed ``(name, label set)`` plus derived serving
    signals. The closed-loop bridge feeds one per step
    (``ClosedLoopDriver(..., monitor=...)``): ``bridge.tokens``,
    ``bridge.config_cycles``, ``bridge.exposed_config``,
    ``bridge.latency`` and ``bridge.slo_miss`` per tenant — the canonical
    names the ratio helpers below read."""

    def __init__(self, window: float = 10_000.0):
        self.default_window = window
        self._series: dict[tuple[str, LabelSet], WindowSeries] = {}
        self._alerts: list[Alert] = []

    # -- feeding --------------------------------------------------------------

    def window(self, name: str, **labels) -> WindowSeries:
        key = (name, labelset(labels))
        series = self._series.get(key)
        if series is None:
            series = self._series[key] = WindowSeries(self.default_window)
        return series

    def observe(self, name: str, t: float, value: float, **labels) -> None:
        self.window(name, **labels).observe(t, value)

    def series(self, name: str, **match) -> list[WindowSeries]:
        want = labelset(match)
        return [s for (n, ls), s in sorted(self._series.items())
                if n == name and all(pair in ls for pair in want)]

    def windowed_sum(self, name: str, now: float, **match) -> float:
        return sum(s.sum(now) for s in self.series(name, **match))

    # -- derived serving signals ----------------------------------------------

    def exposed_config_ratio(self, now: float, **match) -> float:
        """Exposed / total config cycles over the window — 1.0 means the
        engine hid nothing recently (the run-level ``hidden_fraction``'s
        streaming twin)."""
        cfg = self.windowed_sum("bridge.config_cycles", now, **match)
        if cfg <= 0.0:
            return 0.0
        return self.windowed_sum("bridge.exposed_config", now, **match) / cfg

    def slo_burn_rate(self, now: float, **match) -> float:
        """Fraction of recent steps that missed their SLO — the budget
        burn a shedding/autoscaling policy thresholds on."""
        total = sum(s.count(now) for s in self.series("bridge.slo_miss",
                                                      **match))
        if total == 0:
            return 0.0
        return self.windowed_sum("bridge.slo_miss", now, **match) / total

    def token_rate(self, now: float, **match) -> float:
        """Tokens per kilocycle over the window (per tenant with
        ``tenant=...``, cluster-wide without)."""
        tokens = self.windowed_sum("bridge.tokens", now, **match)
        return tokens / self.default_window * 1_000.0

    def power_draw(self, now: float, **match) -> float:
        """Mean draw over the window, pJ/cycle (≡ mW at 1 GHz): windowed
        joules under the canonical ``power.energy`` name (per host with
        ``host=...``, pool-wide without) over the window length. The
        cluster power cap (``cluster.powercap``) feeds this signal and
        thresholds a :class:`SustainedThreshold` on it."""
        joules = self.windowed_sum("power.energy", now, **match)
        return joules / self.default_window

    # -- alerts ---------------------------------------------------------------

    def alert(self, name: str, *, threshold: float, above: bool = True,
              sustain: int = 2,
              on_alert: Callable[[str, int], None] | None = None,
              **labels) -> Alert:
        """Register a debounced threshold over one windowed series: fires
        when the series' window mean stays past ``threshold`` for
        ``sustain`` consecutive :meth:`check_alerts` epochs."""
        alert = Alert(name=name, labels=labelset(labels),
                      threshold=threshold, above=above,
                      trigger=SustainedThreshold(sustain, on_alert=on_alert))
        self._alerts.append(alert)
        return alert

    def check_alerts(self, now: float) -> list[Alert]:
        """One alert epoch; returns the alerts currently fired."""
        return [a for a in self._alerts if a.check(self, now)]


def feed_step(monitor: StreamMonitor, *, tenant: str, completion: float,
              tokens: int, latency: float, config_cycles: float,
              exposed_config: float, slo_cycles: float | None) -> None:
    """Record one closed-loop step into the monitor under the canonical
    ``bridge.*`` names (the bridge driver's per-step hook)."""
    monitor.observe("bridge.tokens", completion, float(tokens), tenant=tenant)
    monitor.observe("bridge.latency", completion, latency, tenant=tenant)
    monitor.observe("bridge.config_cycles", completion, config_cycles,
                    tenant=tenant)
    monitor.observe("bridge.exposed_config", completion, exposed_config,
                    tenant=tenant)
    if slo_cycles is not None:
        monitor.observe("bridge.slo_miss", completion,
                        1.0 if latency > slo_cycles else 0.0, tenant=tenant)
