"""Span-based tracer — the one event stream every runtime layer feeds.

A :class:`Tracer` is a passive sink: the scheduler, overlap policy, fabric
port, cluster host, and closed-loop driver each emit **spans** (an interval
of occupancy on a named lane), **instants** (zero-width markers like the
config-complete edge), and **counter samples** (cumulative tokens), all in
the same cycle clock their resource model already runs on. Hooks are
observation-only — a run with a tracer attached produces bit-identical
timing to one without (the tracer never touches a clock), which is the
property that lets the golden-trace test pin exact timestamps.

Lanes use the engine's resource vocabulary so the exported trace reads
like the three-resource model: ``host`` (control thread), ``cfg[noc]`` /
``cfg[pcie]:shared`` (the wire — the fabric port's own name), and
``compute[<device>]`` lanes, plus per-tenant ``tenant[<t>]`` lanes
(queued → launch) and per-tenant ``step[<t>]`` lanes from the closed-loop
bridge. The span taxonomy per launch:

    queued        tenant lane   arrival → issue (admission wait)
    config-issue  host lane     host instruction time (T_calc + issue)
    wire-captive  host lane     serialized wait for the wire (Eq. 4 worst case)
    launch-stall  host lane     blocked on the device (ring full / sequential)
    mmio | burst  wire lane     the transfer occupying the link
    config-done   instant       register image fully on-device
    compute       compute lane  macro-op start → retire
    launch        tenant lane   issue → retire, tagged with exposed_config

:meth:`Tracer.bind` returns a :class:`BoundTracer` sharing the same sink
with default tags merged into every event — ``cluster.Host`` binds
``host=<id>`` so one cluster-wide tracer still attributes every span. The
shared fabric port deliberately receives the *unbound* root (a wire shared
by several hosts belongs to no one host's process group).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Span:
    """One interval of occupancy on a lane."""

    name: str
    cat: str  # taxonomy category: queueing|config|wire|stall|compute|launch|step
    start: float
    end: float
    lane: str
    tags: dict = field(default_factory=dict)

    @property
    def cycles(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class Instant:
    """A zero-width marker (e.g. the config-complete edge)."""

    name: str
    ts: float
    lane: str
    tags: dict = field(default_factory=dict)


@dataclass(frozen=True)
class CounterSample:
    """One sample of a cumulative series (e.g. tokens produced)."""

    name: str
    ts: float
    value: float
    lane: str
    tags: dict = field(default_factory=dict)


class Tracer:
    """The event sink. All emission methods are O(1) appends."""

    def __init__(self):
        self.spans: list[Span] = []
        self.instants: list[Instant] = []
        self.counters: list[CounterSample] = []

    @property
    def root(self) -> "Tracer":
        return self

    # -- emission -------------------------------------------------------------

    def span(self, name: str, cat: str, start: float, end: float, *,
             lane: str, **tags) -> None:
        assert end >= start, (name, start, end)
        self.spans.append(Span(name, cat, start, end, lane, tags))

    def instant(self, name: str, ts: float, *, lane: str, **tags) -> None:
        self.instants.append(Instant(name, ts, lane, tags))

    def counter(self, name: str, ts: float, value: float, *,
                lane: str, **tags) -> None:
        self.counters.append(CounterSample(name, ts, float(value), lane, tags))

    # -- derived --------------------------------------------------------------

    def bind(self, **tags) -> "BoundTracer":
        """A view of this sink with ``tags`` merged into every event."""
        return BoundTracer(self, tags)

    def lanes(self) -> list[str]:
        """Every lane that received an event, first-appearance order."""
        seen: dict[str, None] = {}
        for ev in (*self.spans, *self.instants, *self.counters):
            seen.setdefault(ev.lane, None)
        return list(seen)

    def spans_on(self, lane: str) -> list[Span]:
        return [s for s in self.spans if s.lane == lane]

    def __len__(self) -> int:
        return len(self.spans) + len(self.instants) + len(self.counters)


class BoundTracer:
    """Shares a root sink; merges default tags into every event. Explicit
    per-event tags win over the bound defaults; ``bind`` nests."""

    def __init__(self, root: Tracer, tags: dict):
        self.root = root
        self.tags = dict(tags)

    def _merge(self, tags: dict) -> dict:
        merged = dict(self.tags)
        merged.update(tags)
        return merged

    def span(self, name: str, cat: str, start: float, end: float, *,
             lane: str, **tags) -> None:
        self.root.span(name, cat, start, end, lane=lane, **self._merge(tags))

    def instant(self, name: str, ts: float, *, lane: str, **tags) -> None:
        self.root.instant(name, ts, lane=lane, **self._merge(tags))

    def counter(self, name: str, ts: float, value: float, *,
                lane: str, **tags) -> None:
        self.root.counter(name, ts, value, lane=lane, **self._merge(tags))

    def bind(self, **tags) -> "BoundTracer":
        return BoundTracer(self.root, self._merge(tags))
