"""The config-wall doctor: regime classification + ranked recommendations.

The paper's instrument is the configuration roofline (Eq. 4): a system
whose operational configuration intensity sits left of the ridge
``I_OC = P_peak / BW_cfg`` is *configuration-bound* — adding FLOPs is
pointless until T_set shrinks. This module turns that classification into
an automated diagnosis over the repo's own telemetry:

* :func:`classify` — pure rule over run-level numbers. Precedence:

  1. **arrival-limited** — no resource lane is busy even half the run;
     the stream, not the system, is the bottleneck (queueing theory's
     underloaded regime; knobs won't move makespan).
  2. **config-bound** — host-visible (exposed) configuration is ≥ 10% of
     makespan. The threshold is deliberately low: the paper's Fig. 4
     shows double-digit config shares already flatten the roofline, and
     every serialized fabric cell of ``BENCH_config_overlap.json`` sits
     far above it while compute-dominated overlapped cells fall under.
  3. **wire-bound** — the config wire out-busies compute: transfers are
     hidden (not exposed) but the link itself saturates.
  4. **compute-bound** — the datapath dominates; the system is right of
     the ridge.

* :func:`diagnose` — classify a live run (scheduler / cluster / bridge
  report, via :mod:`~repro.obs.attribution`), per-lane regimes, and
  ranked quantified recommendations priced by :mod:`~repro.obs.whatif`
  replays (enable overlap, MMIO→burst DMA, more staging buffers) plus
  structural heuristics (cache resize, warm-migrate) the replay cannot
  price.
* :func:`diagnose_doc` — the same over a serialized ``TRACE_*.json``
  (attribution + metrics only — no launch log), so recommendations carry
  *upper bounds* instead of replayed predictions.

``python -m repro.obs.doctor`` renders all of this as a transcript.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .attribution import attribute
from . import whatif as _whatif

__all__ = [
    "CONFIG_BOUND_SHARE", "ARRIVAL_BUSY_SHARE",
    "Regime", "Recommendation", "Diagnosis",
    "classify", "classify_cell", "diagnose", "diagnose_doc",
]

# exposed-config share of makespan at which a run is called config-bound
CONFIG_BOUND_SHARE = 0.10
# if no lane is busy this fraction of the run, the stream is the bottleneck
ARRIVAL_BUSY_SHARE = 0.50

LABELS = ("arrival_limited", "config_bound", "wire_bound", "compute_bound")


@dataclass(frozen=True)
class Regime:
    """One classification: where this run sits relative to the ridge."""

    label: str  # one of LABELS
    exposed_share: float  # exposed config / makespan
    exposed_fraction: float  # exposed / total config (1.0 = nothing hidden)
    shares: dict  # lane kind -> max busy share across that kind's lanes
    reason: str

    def to_dict(self) -> dict:
        return {
            "label": self.label,
            "exposed_share": self.exposed_share,
            "exposed_fraction": self.exposed_fraction,
            "shares": dict(self.shares),
            "reason": self.reason,
        }


@dataclass(frozen=True)
class Recommendation:
    """One ranked mitigation, quantified when a replay could price it.

    Priced on both axes where the replay allows: ``predicted_savings``
    (cycles) and ``predicted_joule_savings`` (configuration pJ —
    negative means the knob *costs* energy, e.g. burst-DMA descriptor
    setup below the link's joule crossover). ``axes_disagree`` marks a
    knob that wins one axis while losing the other; the doctor's
    transcript calls these out so a perf-per-Watt deployment doesn't
    apply a cycle win that regresses tokens/J."""

    action: str
    why: str
    predicted_savings: float | None  # cycles; None = unquantified heuristic
    knob: dict = field(default_factory=dict)
    whatif: object | None = None  # the backing obs.whatif.WhatIf, if any
    bound: bool = False  # savings is an upper bound, not a replay
    predicted_joule_savings: float | None = None  # config pJ; None = unpriced
    axes_disagree: bool = False

    def to_dict(self) -> dict:
        d = {
            "action": self.action,
            "why": self.why,
            "predicted_savings": self.predicted_savings,
            "knob": dict(self.knob),
            "bound": self.bound,
            "predicted_joule_savings": self.predicted_joule_savings,
            "axes_disagree": self.axes_disagree,
        }
        if self.whatif is not None:
            d["whatif"] = self.whatif.to_dict()
        return d


@dataclass(frozen=True)
class Diagnosis:
    """The doctor's full answer for one run."""

    regime: Regime
    lanes: dict  # lane name -> {"kind", "busy_share", "dominant", "label"}
    recommendations: list  # Recommendation, ranked by predicted savings
    stats: dict  # the numbers classify() saw
    notes: list = field(default_factory=list)  # cross-axis caveats

    def to_dict(self) -> dict:
        return {
            "regime": self.regime.to_dict(),
            "lanes": {k: dict(v) for k, v in self.lanes.items()},
            "recommendations": [r.to_dict() for r in self.recommendations],
            "stats": dict(self.stats),
            "notes": list(self.notes),
        }

    def render(self) -> str:
        """The doctor transcript (what the CLI prints)."""
        r = self.regime
        out = [
            f"config-wall doctor — makespan "
            f"{self.stats['makespan']:.1f} cycles",
            f"regime: {r.label.upper().replace('_', '-')} — {r.reason}",
            f"  exposed config {self.stats['exposed_config']:.1f} cycles "
            f"({r.exposed_share:.1%} of makespan, "
            f"{r.exposed_fraction:.1%} of T_set host-visible)",
            "lanes:",
        ]
        for name, lane in sorted(self.lanes.items()):
            out.append(f"  {name:<34s} {lane['kind']:<7s} "
                       f"busy {lane['busy_share']:>6.1%}  "
                       f"dominant: {lane['dominant']}")
        if self.recommendations:
            out.append("recommendations:")
            for i, rec in enumerate(self.recommendations, 1):
                if rec.predicted_savings is None:
                    quant = "(unquantified)"
                else:
                    kind = "≤" if rec.bound else "≈"
                    quant = f"{kind} {rec.predicted_savings:.1f} cycles"
                if rec.predicted_joule_savings is not None:
                    quant += f", {rec.predicted_joule_savings:+.1f} pJ config"
                flag = "  [!] axes disagree" if rec.axes_disagree else ""
                out.append(f"  {i}. {rec.action}: {quant} — {rec.why}{flag}")
        else:
            out.append("recommendations: none — nothing left to hide")
        for note in self.notes:
            out.append(f"note: {note}")
        return "\n".join(out)


# -- classification -----------------------------------------------------------


def classify(*, makespan: float, exposed_config: float, config_cycles: float,
             host_busy: float, wire_busy: float,
             compute_busy: float) -> Regime:
    """The pure rule. Inputs are cycles (busy values are per-kind maxima
    when several lanes of a kind exist — a single saturated resource is
    what binds)."""
    mk = makespan if makespan > 0.0 else 1.0
    shares = {
        "host": host_busy / mk,
        "wire": wire_busy / mk,
        "compute": compute_busy / mk,
    }
    exposed_share = exposed_config / mk
    exposed_fraction = (exposed_config / config_cycles
                        if config_cycles > 0.0 else 0.0)
    if max(shares.values()) < ARRIVAL_BUSY_SHARE:
        label, reason = "arrival_limited", (
            f"no lane is busy ≥ {ARRIVAL_BUSY_SHARE:.0%} of the run "
            f"(max {max(shares.values()):.1%}); the arrival stream is "
            f"the bottleneck")
    elif exposed_share >= CONFIG_BOUND_SHARE:
        label, reason = "config_bound", (
            f"host-visible configuration is {exposed_share:.1%} of "
            f"makespan (≥ {CONFIG_BOUND_SHARE:.0%}); the run sits left "
            f"of the Eq. 4 ridge")
    elif shares["wire"] > shares["compute"]:
        label, reason = "wire_bound", (
            f"the config wire ({shares['wire']:.1%} busy) out-busies "
            f"compute ({shares['compute']:.1%}); transfers hide but the "
            f"link saturates")
    else:
        label, reason = "compute_bound", (
            f"compute dominates ({shares['compute']:.1%} busy, exposed "
            f"config only {exposed_share:.1%}); the run sits right of "
            f"the ridge")
    return Regime(label=label, exposed_share=exposed_share,
                  exposed_fraction=exposed_fraction, shares=shares,
                  reason=reason)


def classify_cell(cell: dict) -> Regime:
    """Classify one ``BENCH_config_overlap.json`` mode cell (the dict with
    ``makespan`` / ``exposed`` / ``config_cycles`` / per-lane busy keys) —
    what ``benchmarks/doctor_gate.py`` sweeps."""
    return classify(
        makespan=cell["makespan"],
        exposed_config=cell["exposed_config_cycles"],
        config_cycles=cell["config_cycles"],
        host_busy=cell["host_busy"],
        wire_busy=cell["wire_busy"],
        compute_busy=cell["compute_busy"],
    )


# -- lane-level view ----------------------------------------------------------

_LANE_LABEL = {"host": "config_bound", "wire": "wire_bound",
               "compute": "compute_bound"}


def _lane_views(att) -> dict:
    """Per-lane summaries out of an attribution (object or dict)."""
    lanes = att["lanes"] if isinstance(att, dict) else {
        name: {"kind": l.kind, "components": l.components}
        for name, l in att.lanes.items()}
    makespan = att["makespan"] if isinstance(att, dict) else att.makespan
    mk = makespan if makespan > 0.0 else 1.0
    views = {}
    for name, lane in lanes.items():
        comps = {k: v for k, v in lane["components"].items() if k != "idle"}
        busy = sum(comps.values())
        dominant = max(comps, key=comps.get) if comps else "idle"
        views[name] = {
            "kind": lane["kind"],
            "busy_share": busy / mk,
            "dominant": dominant,
            "label": (_LANE_LABEL[lane["kind"]]
                      if busy / mk >= ARRIVAL_BUSY_SHARE else "idle"),
        }
    return views


def _kind_maxima(views: dict, makespan: float) -> dict:
    mk = makespan if makespan > 0.0 else 1.0
    out = {"host": 0.0, "wire": 0.0, "compute": 0.0}
    for lane in views.values():
        out[lane["kind"]] = max(out[lane["kind"]], lane["busy_share"] * mk)
    return out


# -- live diagnosis -----------------------------------------------------------


def _scheduler_reports(report) -> list:
    """The underlying SchedulerReports of any run report (duck-typed the
    same way attribution is): a SchedulerReport is itself, a cluster's are
    its hosts', a bridge's are its cluster's."""
    if hasattr(report, "cluster"):
        report = report.cluster
    if hasattr(report, "hosts"):
        return [rep for _, rep in sorted(report.hosts.items())]
    return [report]


def _quantified(report) -> list[Recommendation]:
    """Replay-priced recommendations, summed across the run's schedulers
    (savings on different hosts accrue independently — each host's makespan
    contribution shrinks by its own replay delta)."""
    per_action: dict[str, dict] = {}
    for rep in _scheduler_reports(report):
        buffers = getattr(rep, "staging_buffers", 2)
        candidates = [
            _whatif.predict_overlap(rep),
            _whatif.predict_burst(rep),
            _whatif.predict_staging(rep, buffers=buffers + 1),
        ]
        for wi in candidates:
            if wi is None or wi.predicted_savings <= 0.0:
                continue
            slot = per_action.setdefault(
                wi.action, {"savings": 0.0, "joules": 0.0, "priced": True,
                            "knob": wi.knob, "whatif": wi})
            slot["savings"] += wi.predicted_savings
            joules = wi.predicted_joule_savings
            if joules is None:
                slot["priced"] = False  # one unpriceable wire poisons the sum
            else:
                slot["joules"] += joules
            if wi.predicted_savings > slot["whatif"].predicted_savings:
                slot["whatif"] = wi
    why = {
        "enable_overlap": "stage async burst DMA behind compute "
                          "(runtime §5.5 overlap)",
        "burst_dma": "coalesce ≥8-field MMIO write plans into one DMA "
                     "burst descriptor",
        "staging_buffers": "one more configuration bank deepens the "
                           "config/compute pipeline",
    }
    out = []
    for action, slot in per_action.items():
        joules = slot["joules"] if slot["priced"] else None
        out.append(Recommendation(
            action=action, why=why.get(action, action),
            predicted_savings=slot["savings"],
            knob=slot["knob"], whatif=slot["whatif"],
            predicted_joule_savings=joules,
            axes_disagree=(joules is not None
                           and (slot["savings"] > 0.0 > joules
                                or joules > 0.0 > slot["savings"]))))
    return out


def _heuristics(report) -> list[Recommendation]:
    recs = []
    sched_reps = _scheduler_reports(report)
    evictions = 0
    for rep in sched_reps:
        for stats in rep.cache_stats.values():
            evictions += getattr(stats, "evictions", 0)
    if evictions:
        recs.append(Recommendation(
            action="resize_cache",
            why=f"{evictions} context evictions re-sent register state a "
                f"resident context would have elided; raise max_contexts",
            predicted_savings=None, knob={"max_contexts": "+1"}))
    if len(sched_reps) > 1:
        busiest = [(sum(d.busy_cycles for d in rep.devices.values()), i)
                   for i, rep in enumerate(sched_reps)]
        hi = max(busiest)[0]
        lo = min(busiest)[0]
        if hi > 0.0 and lo < 0.5 * hi:
            recs.append(Recommendation(
                action="warm_migrate",
                why=f"host load imbalance (busiest {hi:.0f} vs idlest "
                    f"{lo:.0f} compute cycles); warm-migrate a resident "
                    f"tenant over the fabric (register-snapshot hand-off)",
                predicted_savings=None, knob={"shed": True}))
    return recs


def diagnose(report) -> Diagnosis:
    """Classify a live run report and rank its mitigations. Accepts a
    ``SchedulerReport``, ``ClusterReport`` or ``BridgeReport`` — anything
    :func:`~repro.obs.attribution.attribute` takes."""
    att = attribute(report)
    views = _lane_views(att)
    busy = _kind_maxima(views, att.makespan)
    exposed = att.summary["exposed_config"]
    config = exposed + att.summary["overlapped_config"]
    regime = classify(
        makespan=att.makespan, exposed_config=exposed, config_cycles=config,
        host_busy=busy["host"], wire_busy=busy["wire"],
        compute_busy=busy["compute"])
    recs = _quantified(report) + _heuristics(report)
    recs.sort(key=lambda r: -(r.predicted_savings or 0.0))
    return Diagnosis(
        regime=regime, lanes=views, recommendations=recs,
        stats={
            "makespan": att.makespan,
            "exposed_config": exposed,
            "config_cycles": config,
            **{f"{k}_busy": v for k, v in busy.items()},
        },
        notes=_axis_notes(recs))


def _axis_notes(recs: list) -> list[str]:
    """Cross-axis caveats: per-knob disagreements, plus a ranking flip
    when the best cycle saver is not the best joule saver — the exact
    case where 'make it faster' and 'make it cheaper per token' pick
    different knobs."""
    notes = []
    for rec in recs:
        if rec.axes_disagree:
            notes.append(
                f"{rec.action} saves {rec.predicted_savings:.1f} cycles but "
                f"changes config energy by "
                f"{-rec.predicted_joule_savings:+.1f} pJ — a cycle win that "
                f"costs joules; rank by objective='joules' before applying "
                f"on a power-capped pool")
    priced = [r for r in recs if r.predicted_savings is not None
              and r.predicted_joule_savings is not None]
    if len(priced) > 1:
        by_cycles = max(priced, key=lambda r: r.predicted_savings)
        by_joules = max(priced, key=lambda r: r.predicted_joule_savings)
        if by_cycles.action != by_joules.action:
            notes.append(
                f"ranking depends on the axis: {by_cycles.action} saves the "
                f"most cycles ({by_cycles.predicted_savings:.1f}) but "
                f"{by_joules.action} saves the most configuration energy "
                f"({by_joules.predicted_joule_savings:.1f} pJ)")
    return notes


# -- diagnosis from a serialized trace ----------------------------------------


def diagnose_doc(doc: dict) -> Diagnosis:
    """Diagnose a ``TRACE_*.json`` document (as ``obs.export.write_trace``
    wrote it). The launch log is gone, so recommendations are *bounds*:
    the wire share of exposed configuration is the most overlap could
    hide; ``bound=True`` marks them."""
    att = doc.get("attribution")
    assert att, "trace document carries no attribution block"
    makespan = att["makespan"]
    views = _lane_views(att)
    busy = _kind_maxima(views, makespan)
    summary = att["summary"]
    exposed = summary["exposed_config"]
    config = exposed + summary["overlapped_config"]
    regime = classify(
        makespan=makespan, exposed_config=exposed, config_cycles=config,
        host_busy=busy["host"], wire_busy=busy["wire"],
        compute_busy=busy["compute"])
    recs = []
    hideable = max(0.0, exposed - summary.get("host_occupancy", 0.0))
    if summary.get("overlapped_config", 0.0) == 0.0 and hideable > 0.0:
        recs.append(Recommendation(
            action="enable_overlap",
            why="nothing overlapped this run; the wire share of exposed "
                "T_set is the most async staging could hide",
            predicted_savings=hideable, knob={"overlap": "overlapped"},
            bound=True))
    queueing = summary.get("queueing", 0.0)
    if regime.label == "config_bound" and queueing > 0.0:
        recs.append(Recommendation(
            action="reduce_queueing",
            why=f"launches queued {queueing:.0f} cycles behind a "
                f"config-bound host; shrinking T_set drains the backlog",
            predicted_savings=None, knob={}))
    recs.sort(key=lambda r: -(r.predicted_savings or 0.0))
    return Diagnosis(
        regime=regime, lanes=views, recommendations=recs,
        stats={
            "makespan": makespan,
            "exposed_config": exposed,
            "config_cycles": config,
            **{f"{k}_busy": v for k, v in busy.items()},
        })
