"""Differential trace comparison: decompose a regression into components.

When a CI geomean floor trips, the interesting question is never "did it
get slower" (the gate already said so) but *where the cycles went*. This
module compares two runs — trace documents (``TRACE_*.json`` as
``obs.export.write_trace`` wrote them), attribution dicts, or live
:class:`~repro.obs.attribution.AttributionReport` objects — and
decomposes the makespan delta into per-lane, per-component deltas plus
metric-total deltas, ranked by magnitude.

**Stable lane matching.** Lanes match by name first. Lanes left unmatched
are then paired by kind when each side has exactly one of that kind —
a run that moved from ``cfg[noc]`` to ``cfg[noc2]`` still diffs its wire
against the other run's wire (reported as ``renamed``). Anything still
unmatched is ``added``/``removed`` with its full busy time as the delta.

Deliberately stdlib-only with **no package-relative imports**:
``benchmarks/obs_gate.py`` loads this file by path (no ``PYTHONPATH``) to
pre-triage floor failures in CI, so it must import standalone.
"""

from __future__ import annotations

__all__ = ["diff", "render"]


def _attribution(x) -> dict:
    """Coerce any accepted input to an attribution dict."""
    if hasattr(x, "to_dict"):
        x = x.to_dict()
    assert isinstance(x, dict), f"cannot diff a {type(x).__name__}"
    if "attribution" in x:  # a full trace document
        return x["attribution"]
    assert "lanes" in x, "not an attribution: no 'lanes' key"
    return x


def _metrics(x) -> dict:
    """name+labels -> scalar value, for inputs that carry a metrics block
    (counters/gauges use their value; histograms their total)."""
    rows = x.get("metrics", []) if isinstance(x, dict) else []
    out = {}
    for row in rows:
        key = row["name"] + "".join(
            f"{{{k}={v}}}" for k, v in sorted(row.get("labels", {}).items()))
        out[key] = row.get("value", row.get("total", 0.0))
    return out


def _busy(lane: dict) -> float:
    return sum(v for k, v in lane["components"].items() if k != "idle")


def _match_lanes(base: dict, other: dict) -> list:
    """[(base_name, other_name, status)] — by name, then kind-singleton."""
    pairs = [(n, n, "matched") for n in base if n in other]
    left = {n: l for n, l in base.items() if n not in other}
    right = {n: l for n, l in other.items() if n not in base}
    for kind in ("host", "wire", "compute"):
        lk = [n for n, l in sorted(left.items()) if l["kind"] == kind]
        rk = [n for n, l in sorted(right.items()) if l["kind"] == kind]
        if len(lk) == 1 and len(rk) == 1:
            pairs.append((lk[0], rk[0], "renamed"))
            del left[lk[0]]
            del right[rk[0]]
    pairs.extend((n, None, "removed") for n in sorted(left))
    pairs.extend((None, n, "added") for n in sorted(right))
    return pairs


def diff(base, other) -> dict:
    """Compare two runs; deltas are ``other − base`` (positive = the
    second run spent more). Returns a JSON-ready dict whose ``ranked``
    list names the largest per-lane component movements first — the
    triage order."""
    base_doc = base if isinstance(base, dict) else {}
    other_doc = other if isinstance(other, dict) else {}
    a = _attribution(base)
    b = _attribution(other)
    out: dict = {
        "makespan": {
            "base": a["makespan"], "other": b["makespan"],
            "delta": b["makespan"] - a["makespan"],
        },
        "exposed_config": {
            "base": a["exposed_config"], "other": b["exposed_config"],
            "delta": b["exposed_config"] - a["exposed_config"],
        },
    }
    summary = {}
    for key in sorted(set(a["summary"]) | set(b["summary"])):
        av = a["summary"].get(key, 0.0)
        bv = b["summary"].get(key, 0.0)
        summary[key] = {"base": av, "other": bv, "delta": bv - av}
    out["summary"] = summary

    lanes: dict = {}
    ranked: list = []
    for base_name, other_name, status in _match_lanes(a["lanes"], b["lanes"]):
        name = other_name or base_name
        la = a["lanes"].get(base_name, {"components": {}}) if base_name else \
            {"components": {}}
        lb = b["lanes"].get(other_name, {"components": {}}) if other_name \
            else {"components": {}}
        comps = {}
        for key in sorted(set(la["components"]) | set(lb["components"])):
            av = la["components"].get(key, 0.0)
            bv = lb["components"].get(key, 0.0)
            comps[key] = {"base": av, "other": bv, "delta": bv - av}
            if key != "idle" and bv != av:
                ranked.append({"lane": name, "component": key,
                               "delta": bv - av})
        entry: dict = {"status": status, "components": comps}
        if status == "renamed":
            entry["base_lane"] = base_name
        lanes[name] = entry
    ranked.sort(key=lambda r: (-abs(r["delta"]), r["lane"], r["component"]))
    out["lanes"] = lanes
    out["ranked"] = ranked

    ma, mb = _metrics(base_doc), _metrics(other_doc)
    if ma or mb:
        out["metrics"] = {
            key: {"base": ma.get(key, 0.0), "other": mb.get(key, 0.0),
                  "delta": mb.get(key, 0.0) - ma.get(key, 0.0)}
            for key in sorted(set(ma) | set(mb))
            if mb.get(key, 0.0) != ma.get(key, 0.0)
        }
    return out


def render(d: dict, top: int = 8) -> str:
    """Human triage view of a :func:`diff` result."""
    mk = d["makespan"]
    sign = "+" if mk["delta"] >= 0 else ""
    out = [
        f"trace diff — makespan {mk['base']:.1f} → {mk['other']:.1f} "
        f"({sign}{mk['delta']:.1f} cycles)",
        f"exposed config {d['exposed_config']['base']:.1f} → "
        f"{d['exposed_config']['other']:.1f}",
        "largest component movements (other − base):",
    ]
    for row in d["ranked"][:top]:
        out.append(f"  {row['delta']:>+10.1f}  {row['lane']} / "
                   f"{row['component']}")
    if not d["ranked"]:
        out.append("  (no component moved)")
    extra = [name for name, lane in sorted(d["lanes"].items())
             if lane["status"] in ("added", "removed", "renamed")]
    if extra:
        out.append("lane matching: " + ", ".join(
            f"{n} [{d['lanes'][n]['status']}]" for n in extra))
    return "\n".join(out)
