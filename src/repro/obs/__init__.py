"""repro.obs — unified observability across every runtime layer.

The paper's core claim is that config-bound systems are invisible to
conventional profiling: the wall only appears once setup cycles are
attributed separately from compute, exposed separately from hidden. The
five runtime layers (sched, cluster, fabric, bridge, engine) each grew
bespoke counters and no shared event stream; this package is the
calibration substrate they now share:

* :mod:`~repro.obs.trace` — a span-based :class:`Tracer`: every launch
  emits nested spans (queued → config-issue → wire transfer →
  config-done → compute → retire) on resource lanes matching the
  engine's three-resource model, via observation-only hooks in
  ``sched.Scheduler``, ``engine.OverlapPolicy``, ``fabric.LinkPort``,
  ``cluster.Host`` / ``Cluster``, and ``bridge.ClosedLoopDriver``. A run
  with a tracer attached is bit-identical to one without.
* :mod:`~repro.obs.export` — the Chrome-trace / Perfetto exporter:
  :func:`write_trace` dumps any scheduler, cluster, or closed-loop bridge
  run as a ``trace.json`` loadable in ``chrome://tracing`` or
  https://ui.perfetto.dev, with the attribution report and metrics
  registry embedded as extra top-level keys for the CI gate.
* :mod:`~repro.obs.attribution` — :func:`attribute` decomposes every
  run's makespan into {exposed config, overlapped config, compute, host
  occupancy, wire contention, queueing, idle} per resource lane, with a
  hard conservation invariant (components sum to makespan on every lane)
  — the first-class generalization of ``exposed_config_cycles``.
* :mod:`~repro.obs.metrics` — :class:`MetricsRegistry`
  (counters/gauges/histograms with label sets): the one place a number
  lives. ``sched.telemetry`` / ``cluster.slo`` / ``bridge.report`` keep
  their public APIs as thin views over it.

The diagnosis layer turns that telemetry into answers:

* :mod:`~repro.obs.diagnose` — the config-wall doctor: :func:`classify`
  a run into config-bound / wire-bound / compute-bound / arrival-limited
  (the Eq. 4 ridge as a rule), with ranked quantified recommendations.
* :mod:`~repro.obs.whatif` — replay-based what-if estimators behind each
  recommendation (enable overlap, MMIO→burst, staging buffers), validated
  against actual re-simulation in ``tests/test_doctor.py``.
* :mod:`~repro.obs.diff` — differential comparison of two traces with
  stable lane matching; the CI floor-failure triage tool.
* :mod:`~repro.obs.monitor` — sliding-window streaming metrics +
  hysteresis alerts over the closed loop (``ShedTrigger`` subscribes to
  :class:`SustainedThreshold` instead of keeping private streak counters).
* :mod:`~repro.obs.doctor` — ``python -m repro.obs.doctor TRACE.json
  [--against OTHER.json]``.
"""

from . import attribution, diagnose, diff, export, metrics, monitor, trace
from . import whatif
from .attribution import AttributionReport, LaneAttribution, attribute
from .diagnose import Diagnosis, Recommendation, Regime, classify
from .diagnose import classify_cell, diagnose_doc
from .diagnose import diagnose as diagnose_report
from .export import chrome_trace, trace_power, validate_trace, write_trace
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from .monitor import (
    Alert,
    StreamMonitor,
    SustainedThreshold,
    WindowSeries,
    feed_step,
)
from .trace import BoundTracer, CounterSample, Instant, Span, Tracer
from .whatif import WhatIf, predict_burst, predict_overlap, predict_staging

__all__ = [
    "Alert",
    "AttributionReport",
    "BoundTracer",
    "Counter",
    "CounterSample",
    "Diagnosis",
    "Gauge",
    "Histogram",
    "Instant",
    "LaneAttribution",
    "MetricsRegistry",
    "Recommendation",
    "Regime",
    "Span",
    "StreamMonitor",
    "SustainedThreshold",
    "Tracer",
    "WhatIf",
    "WindowSeries",
    "attribute",
    "attribution",
    "chrome_trace",
    "classify",
    "classify_cell",
    "diagnose",
    "diagnose_doc",
    "diagnose_report",
    "diff",
    "export",
    "feed_step",
    "metrics",
    "monitor",
    "percentile",
    "predict_burst",
    "predict_overlap",
    "predict_staging",
    "trace",
    "validate_trace",
    "whatif",
    "write_trace",
]
