"""repro.obs — unified observability across every runtime layer.

The paper's core claim is that config-bound systems are invisible to
conventional profiling: the wall only appears once setup cycles are
attributed separately from compute, exposed separately from hidden. The
five runtime layers (sched, cluster, fabric, bridge, engine) each grew
bespoke counters and no shared event stream; this package is the
calibration substrate they now share:

* :mod:`~repro.obs.trace` — a span-based :class:`Tracer`: every launch
  emits nested spans (queued → config-issue → wire transfer →
  config-done → compute → retire) on resource lanes matching the
  engine's three-resource model, via observation-only hooks in
  ``sched.Scheduler``, ``engine.OverlapPolicy``, ``fabric.LinkPort``,
  ``cluster.Host`` / ``Cluster``, and ``bridge.ClosedLoopDriver``. A run
  with a tracer attached is bit-identical to one without.
* :mod:`~repro.obs.export` — the Chrome-trace / Perfetto exporter:
  :func:`write_trace` dumps any scheduler, cluster, or closed-loop bridge
  run as a ``trace.json`` loadable in ``chrome://tracing`` or
  https://ui.perfetto.dev, with the attribution report and metrics
  registry embedded as extra top-level keys for the CI gate.
* :mod:`~repro.obs.attribution` — :func:`attribute` decomposes every
  run's makespan into {exposed config, overlapped config, compute, host
  occupancy, wire contention, queueing, idle} per resource lane, with a
  hard conservation invariant (components sum to makespan on every lane)
  — the first-class generalization of ``exposed_config_cycles``.
* :mod:`~repro.obs.metrics` — :class:`MetricsRegistry`
  (counters/gauges/histograms with label sets): the one place a number
  lives. ``sched.telemetry`` / ``cluster.slo`` / ``bridge.report`` keep
  their public APIs as thin views over it.
"""

from . import attribution, export, metrics, trace
from .attribution import AttributionReport, LaneAttribution, attribute
from .export import chrome_trace, validate_trace, write_trace
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from .trace import BoundTracer, CounterSample, Instant, Span, Tracer

__all__ = [
    "AttributionReport",
    "BoundTracer",
    "Counter",
    "CounterSample",
    "Gauge",
    "Histogram",
    "Instant",
    "LaneAttribution",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "attribute",
    "attribution",
    "chrome_trace",
    "export",
    "metrics",
    "percentile",
    "trace",
    "validate_trace",
    "write_trace",
]
