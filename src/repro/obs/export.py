"""Chrome-trace / Perfetto exporter for :class:`~repro.obs.trace.Tracer`.

Produces the Trace Event Format (the ``chrome://tracing`` / Perfetto JSON
dialect): spans become complete (``"X"``) events, instants ``"i"``,
counter samples ``"C"``, plus ``"M"`` metadata naming every process and
thread. Timestamps are emitted in the runtime's own cycle clock (the
nominal unit is µs — one cycle reads as one microsecond, which is
irrelevant for inspection and keeps the numbers exact).

Grouping mirrors the runtime topology: each **process** is a host (the
``host=`` tag a :class:`~repro.obs.trace.BoundTracer` stamps), with the
shared fabric wire under a ``fabric`` process (a wire shared by several
hosts belongs to none of them) and closed-loop step lanes under
``bridge``; each **thread** is a lane — ``host``, ``cfg[<link>]``,
``compute[<device>]``, ``tenant[<t>]`` — sorted so the resource lanes of
the engine's three-resource model sit on top.

``write_trace`` embeds two structured side-channels next to
``traceEvents`` (Chrome and Perfetto ignore unknown top-level keys): the
cycle-attribution report (``"attribution"`` — the CI conservation gate
reads it straight out of the artifact) and the metrics registry
(``"metrics"``).
"""

from __future__ import annotations

import json

from .trace import Tracer

# thread ordering inside a process: engine resources first, then power
# counter lanes, then tenants
_LANE_ORDER = (("host", 0), ("cfg[", 1), ("compute[", 2), ("power[", 30),
               ("tenant[", 40), ("step[", 50), ("tokens[", 60))


def _lane_sort_index(lane: str) -> int:
    for prefix, base in _LANE_ORDER:
        if lane.startswith(prefix):
            return base
    return 80


def _process_for(lane: str, tags: dict) -> str:
    if lane.startswith("cfg["):
        return "fabric"
    if lane.startswith(("step[", "tokens[")):
        return "bridge"
    return str(tags.get("host", "run"))


def _json_tags(tags: dict) -> dict:
    return {k: v for k, v in tags.items() if k != "host"}


def chrome_trace(tracer: Tracer) -> dict:
    """The tracer's events as a Trace Event Format document (a dict)."""
    pids: dict[str, int] = {}
    tids: dict[tuple[int, str], int] = {}
    meta: list[dict] = []

    def _pid(name: str) -> int:
        if name not in pids:
            pids[name] = len(pids) + 1
            meta.append({"ph": "M", "name": "process_name", "pid": pids[name],
                         "args": {"name": name}})
        return pids[name]

    def _tid(pid: int, lane: str) -> int:
        key = (pid, lane)
        if key not in tids:
            tids[key] = len(tids) + 1
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tids[key], "args": {"name": lane}})
            meta.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                         "tid": tids[key],
                         "args": {"sort_index": _lane_sort_index(lane)}})
        return tids[key]

    events: list[dict] = []
    for s in tracer.spans:
        pid = _pid(_process_for(s.lane, s.tags))
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X",
            "ts": s.start, "dur": s.end - s.start,
            "pid": pid, "tid": _tid(pid, s.lane),
            "args": _json_tags(s.tags),
        })
    for i in tracer.instants:
        pid = _pid(_process_for(i.lane, i.tags))
        events.append({
            "name": i.name, "cat": "instant", "ph": "i", "s": "t",
            "ts": i.ts, "pid": pid, "tid": _tid(pid, i.lane),
            "args": _json_tags(i.tags),
        })
    for c in tracer.counters:
        pid = _pid(_process_for(c.lane, c.tags))
        events.append({
            "name": c.name, "ph": "C", "ts": c.ts,
            "pid": pid, "tid": _tid(pid, c.lane),
            "args": {"value": c.value},
        })
    # metadata first so viewers name tracks before populating them; events
    # in timestamp order (stable on ties, preserving emission order)
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def validate_trace(doc: dict) -> list[str]:
    """Schema problems of a Trace Event document (empty list = loadable).
    The checks mirror what ``chrome://tracing`` / Perfetto require of the
    JSON object format; the CI gate and the golden-trace test share them."""
    problems: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for idx, ev in enumerate(events):
        where = f"traceEvents[{idx}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "M"):
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name",
                                      "thread_sort_index"):
                problems.append(f"{where}: unknown metadata {ev.get('name')!r}")
            continue
        for field in ("name", "ts", "pid", "tid"):
            if field not in ev:
                problems.append(f"{where}: missing {field!r}")
        if ph == "X":
            if "dur" not in ev:
                problems.append(f"{where}: X event missing dur")
            elif ev["dur"] < 0:
                problems.append(f"{where}: negative dur {ev['dur']}")
    return problems


def trace_power(tracer: Tracer, report) -> None:
    """Emit per-lane ``power[<lane>]`` counter samples onto ``tracer``
    from a finished report's resource telemetry: each lane steps to its
    active draw at every busy-interval edge (pJ/cycle — reads as mW at
    1 GHz in the viewer). No-op for runs without an attached PowerSpec."""
    from ..power.meter import power_counter_series
    for lane, points in power_counter_series(report).items():
        host, _, res = lane.rpartition("/")
        for ts, watts in points:
            if host:
                tracer.counter(f"power[{res}]", ts, watts, lane=f"power[{res}]",
                               host=host)
            else:
                tracer.counter(f"power[{res}]", ts, watts, lane=f"power[{res}]")


def write_trace(tracer: Tracer, path: str, *, attribution=None,
                metrics=None, energy=None) -> dict:
    """Export ``tracer`` to ``path`` as Perfetto-loadable JSON; returns the
    written document. ``attribution`` (an
    :class:`~repro.obs.attribution.AttributionReport`), ``metrics`` (a
    :class:`~repro.obs.metrics.MetricsRegistry`), and ``energy`` (a
    :class:`~repro.power.meter.EnergyReport`) are embedded as extra
    top-level keys — trace viewers ignore them, the CI gate reads them."""
    doc = chrome_trace(tracer)
    if attribution is not None:
        doc["attribution"] = attribution.to_dict()
    if metrics is not None:
        doc["metrics"] = metrics.collect()
    if energy is not None:
        doc["energy"] = energy.to_dict()
    problems = validate_trace(doc)
    assert not problems, problems
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc
