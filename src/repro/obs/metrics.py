"""Label-set metrics registry — counters, gauges, histograms.

Every runtime layer used to grow its own ad-hoc counter fields
(``sched.telemetry.DeviceTelemetry``'s nine scalars, ``cluster.slo``'s
percentile recomputations, ``bridge.report``'s step sums). This module is
the one place a number lives: a metric is identified by a **name** plus a
sorted **label set** (``counter("sched.bytes_sent", device="opengemm:0")``),
registries fold across hosts (:meth:`MetricsRegistry.absorb` re-labels on
the way in), and the layer reports stay thin views — their public fields
read the registry instead of owning private accumulators.

Three metric kinds, all deterministic and dependency-free:

* :class:`Counter` — monotone by convention, but ``add`` accepts negative
  deltas: a preempted staged launch *un-happens* on the device (busy
  cycles, ops, and the launch count roll back — exactly what
  ``DeviceTelemetry.record_preemption`` has always done), and the registry
  must be able to express that without a parallel correction metric.
* :class:`Gauge` — last-write-wins scalar (makespans, port waits).
* :class:`Histogram` — stores raw samples so percentiles are *exact*
  (:func:`percentile`, the same deterministic linear interpolation
  ``cluster.slo`` has always used — it now lives here and is re-exported
  from there), not bucket approximations; sample counts at this repo's
  scale make that the right trade.
"""

from __future__ import annotations

from typing import Iterable, Mapping, Sequence

LabelSet = tuple  # tuple[tuple[str, str], ...] — sorted, hashable


def percentile(values: Sequence[float], q: float) -> float:
    """The q-th percentile (0 ≤ q ≤ 100) by linear interpolation between
    order statistics — numpy's default method, implemented deterministically."""
    assert 0.0 <= q <= 100.0
    if not values:
        return 0.0
    vals = sorted(values)
    if len(vals) == 1:
        return vals[0]
    pos = (q / 100.0) * (len(vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def labelset(labels: Mapping[str, object]) -> LabelSet:
    """Canonical hashable form of a label mapping (values stringified)."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Metric:
    """Common identity: a name plus a sorted label set."""

    kind = "metric"

    def __init__(self, name: str, labels: LabelSet):
        self.name = name
        self.labels = labels

    @property
    def label_dict(self) -> dict[str, str]:
        return dict(self.labels)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        pairs = ",".join(f"{k}={v}" for k, v in self.labels)
        return f"<{self.kind} {self.name}{{{pairs}}}>"


class Counter(Metric):
    """Accumulating scalar. ``add`` accepts negative deltas so preemption
    rollback (a staged launch that never ran) stays a first-class event."""

    kind = "counter"

    def __init__(self, name: str, labels: LabelSet):
        super().__init__(name, labels)
        self.value = 0.0

    def inc(self, delta: float = 1.0) -> None:
        self.value += delta

    def add(self, delta: float) -> None:
        self.value += delta


class Gauge(Metric):
    """Last-write-wins scalar (a makespan, a port-wait estimate)."""

    kind = "gauge"

    def __init__(self, name: str, labels: LabelSet):
        super().__init__(name, labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram(Metric):
    """Raw-sample histogram: exact deterministic percentiles, no buckets."""

    kind = "histogram"

    def __init__(self, name: str, labels: LabelSet):
        super().__init__(name, labels)
        self.samples: list[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    def extend(self, values: Iterable[float]) -> None:
        self.samples.extend(float(v) for v in values)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def total(self) -> float:
        return sum(self.samples)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.samples else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """All metrics of one run, keyed ``(name, label set)``.

    ``counter``/``gauge``/``histogram`` are get-or-create (the hot path
    caches the returned object and mutates it directly); ``total`` /
    ``samples`` / ``series`` are the read side the layer reports use as
    views; ``absorb`` folds a child registry in under extra labels (how a
    cluster report merges its hosts' scheduler registries)."""

    def __init__(self):
        self._metrics: dict[tuple[str, LabelSet], Metric] = {}

    # -- get-or-create --------------------------------------------------------

    def _get_or_create(self, kind: str, name: str,
                       labels: Mapping[str, object]) -> Metric:
        key = (name, labelset(labels))
        metric = self._metrics.get(key)
        if metric is None:
            metric = _KINDS[kind](name, key[1])
            self._metrics[key] = metric
        assert metric.kind == kind, (
            f"{name} already registered as {metric.kind}, requested {kind}")
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get_or_create("histogram", name, labels)

    # -- queries --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._metrics)

    def get(self, name: str, **labels) -> Metric | None:
        return self._metrics.get((name, labelset(labels)))

    def has(self, name: str) -> bool:
        return any(n == name for n, _ in self._metrics)

    def names(self) -> list[str]:
        return sorted({n for n, _ in self._metrics})

    def series(self, name: str, **match) -> list[Metric]:
        """Every metric of ``name`` whose labels contain ``match``, in
        deterministic (label set) order."""
        want = labelset(match)
        out = [m for (n, ls), m in sorted(self._metrics.items())
               if n == name and all(pair in ls for pair in want)]
        return out

    def total(self, name: str, **match) -> float:
        """Sum of matching counter/gauge values (histograms sum their
        samples) — the aggregate the report properties are views of."""
        acc = 0.0
        for m in self.series(name, **match):
            acc += m.total if isinstance(m, Histogram) else m.value
        return acc

    def samples(self, name: str, **match) -> list[float]:
        """Concatenated raw samples of matching histograms."""
        out: list[float] = []
        for m in self.series(name, **match):
            assert isinstance(m, Histogram), f"{name} is a {m.kind}"
            out.extend(m.samples)
        return out

    # -- folding / export -----------------------------------------------------

    def absorb(self, other: "MetricsRegistry", **extra_labels) -> None:
        """Fold ``other`` in, extending every absorbed metric's label set
        with ``extra_labels`` (counters sum, gauges last-write-win,
        histograms concatenate) — the cluster's host-merge primitive."""
        for (name, ls), m in sorted(other._metrics.items()):
            merged = dict(ls)
            merged.update({k: str(v) for k, v in extra_labels.items()})
            if isinstance(m, Counter):
                self.counter(name, **merged).add(m.value)
            elif isinstance(m, Gauge):
                self.gauge(name, **merged).set(m.value)
            else:
                self.histogram(name, **merged).extend(m.samples)

    def collect(self) -> list[dict]:
        """Every metric as a plain dict, deterministically ordered — the
        JSON-exportable flat view (`trace.json` embeds this)."""
        out = []
        for (name, ls), m in sorted(self._metrics.items()):
            row: dict = {"name": name, "kind": m.kind, "labels": dict(ls)}
            if isinstance(m, Histogram):
                row.update(count=m.count, total=m.total, mean=m.mean,
                           p50=m.percentile(50), p95=m.percentile(95),
                           p99=m.percentile(99))
            else:
                row["value"] = m.value
            out.append(row)
        return out
