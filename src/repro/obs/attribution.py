"""Cycle attribution — "where did the wall go", with conservation.

The paper's thesis is that configuration cost is invisible to profilers
that only know busy/idle: the wall appears only once setup cycles are
attributed *separately* from compute, and exposed separately from hidden.
This module decomposes a run's makespan per **resource lane** — the host
control thread, the config wire(s), each device's compute datapath — into
named components, under a hard **conservation invariant**: on every lane,

    sum(components) == makespan          (idle included), equivalently
    sum(non-idle components) == union-length of the lane's occupancy

so a component can neither be dropped (the residual shows a gap) nor
counted twice (the residual shows double-booking — idle is computed from
the *union* of occupancy intervals, not from the component sum, precisely
so overlap between two classified intervals cannot hide). The residual is
the single number the CI gate thresholds.

Lane components:

* ``host`` — ``config_issue`` (instruction time, the T_calc side of Eq. 4;
  includes instruction time wasted on later-preempted launches, which the
  separate ``preempted_config_cycles`` counter still reports in full),
  ``wire_captive`` (a serialized host held through its transfer's wire
  time — Eq. 4's worst case), ``device_stall`` (blocked on a full staging
  ring or a sequential macro-op), ``preempted_config`` (captive/stall
  cycles of launches that were cancelled), ``idle``.
* ``wire`` — ``exposed_transfer`` vs ``overlapped_transfer`` (the split of
  each transfer by the launch's recorded ``hidden_config`` — wire time
  that streamed behind its own device's compute), ``preempted_transfer``
  (a cancelled launch's transfer: the bytes crossed, the macro-op never
  ran), ``other_transfer`` (wire traffic not tied to a launch, e.g. a
  migration's register-snapshot burst), ``idle``.
* ``compute`` — ``compute``, ``idle``.

The run-level ``summary`` generalizes ``exposed_config_cycles`` into the
seven-way split {exposed_config, overlapped_config, compute,
host_occupancy, wire_contention, queueing, idle}. These are *per-launch /
per-lane* totals on different denominators (queueing sums over launches,
idle over lanes) — the conservation invariant lives on the lanes, the
summary is the scoreboard. ``exposed_config`` is recomputed from the
per-launch records and must reproduce the telemetry counter
(``DeviceTelemetry.exposed_config_cycles``) — bit-exactly on runs without
preemption, where both sides sum the same floats in the same order.

Everything here is duck-typed over the report objects (a
``SchedulerReport``, a ``ClusterReport``'s ``hosts``, or a
``BridgeReport``'s ``cluster``) so the obs layer imports nothing from the
runtime layers it observes.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..engine.resources import merge_intervals


@dataclass(frozen=True)
class LaneAttribution:
    """One resource lane's makespan decomposition."""

    lane: str  # e.g. "host", "h0/compute[h0/opengemm:0]", "cfg[pcie]:shared"
    kind: str  # "host" | "wire" | "compute"
    makespan: float
    components: dict  # category -> cycles; includes "idle"
    residual: float  # |sum(components) - makespan|: gap or double-booking

    @property
    def busy_cycles(self) -> float:
        return sum(v for k, v in self.components.items() if k != "idle")

    @property
    def residual_fraction(self) -> float:
        return self.residual / self.makespan if self.makespan else 0.0


@dataclass(frozen=True)
class AttributionReport:
    """The full decomposition of one run."""

    makespan: float
    lanes: dict  # lane name -> LaneAttribution
    summary: dict  # the seven-way run-level split
    exposed_config: float  # reproduced from per-launch records
    reported_exposed_config: float  # the telemetry counters' aggregate

    @property
    def max_residual(self) -> float:
        """Worst lane residual as a fraction of makespan — the CI gate's
        conservation number."""
        return max((l.residual_fraction for l in self.lanes.values()),
                   default=0.0)

    def check(self, tolerance: float = 1e-3) -> "AttributionReport":
        """Enforce the conservation invariant (components sum to makespan
        on every lane, within ``tolerance`` of makespan) and the
        exposed-config reproduction. Returns self so call sites can chain
        ``attribute(report).check()``."""
        for lane in self.lanes.values():
            assert lane.residual <= max(tolerance * lane.makespan, 1e-9), (
                f"lane {lane.lane}: residual {lane.residual} over makespan "
                f"{lane.makespan} — components {lane.components}")
            assert lane.components["idle"] >= -1e-9, (
                f"lane {lane.lane}: negative idle — occupancy exceeds "
                f"makespan: {lane.components}")
        drift = abs(self.exposed_config - self.reported_exposed_config)
        assert drift <= 1e-6 * max(1.0, abs(self.reported_exposed_config)), (
            f"exposed-config reproduction drifted: records say "
            f"{self.exposed_config}, counters say {self.reported_exposed_config}")
        return self

    def to_dict(self) -> dict:
        return {
            "makespan": self.makespan,
            "max_residual": self.max_residual,
            "exposed_config": self.exposed_config,
            "reported_exposed_config": self.reported_exposed_config,
            "summary": dict(self.summary),
            "lanes": {
                name: {
                    "kind": lane.kind,
                    "residual": lane.residual,
                    "residual_fraction": lane.residual_fraction,
                    "components": dict(lane.components),
                }
                for name, lane in sorted(self.lanes.items())
            },
        }


# -- lane builders ------------------------------------------------------------


def _lane(name: str, kind: str, makespan: float, components: dict,
          intervals: list) -> LaneAttribution:
    union = sum(e - s for s, e in merge_intervals(intervals))
    comps = dict(components)
    comps["idle"] = makespan - union
    classified = sum(v for k, v in comps.items() if k != "idle")
    return LaneAttribution(lane=name, kind=kind, makespan=makespan,
                           components=comps,
                           residual=abs(classified - union))


def _launch_records(rep) -> list:
    """(record, alive) for every launch the report's devices saw —
    retired launches plus the preempted ones whose side effects (host
    instruction time, wire transfer) still occupy the lanes."""
    out = [(r, True) for d in rep.devices.values() for r in d.launch_log]
    out += [(r, False) for d in rep.devices.values()
            for r in getattr(d, "preempted_log", ())]
    return out


# public alias: repro.power.meter classifies wire-transfer *energy* with
# the same launch-record matching this module uses for wire cycles
launch_records = _launch_records


def _host_lane(rep, makespan: float, records: list,
               lane_name: str) -> LaneAttribution:
    tel = next(t for t in rep.resources.values() if t.kind == "host")
    intervals = [(s, e) for s, e, _ in tel.intervals]
    issue_cycles = sum(e - s for s, e in intervals)
    captive = stall = preempted = 0.0
    for rec, alive in records:
        h_end = rec.issue + rec.host_cycles
        cap = max(0.0, rec.host_release - h_end)
        if cap > 0.0:
            intervals.append((h_end, rec.host_release))
        if rec.stall > 0.0:
            intervals.append((rec.host_release, rec.host_release + rec.stall))
        if alive:
            captive += cap
            stall += rec.stall
        else:
            preempted += cap + rec.stall
    return _lane(lane_name, "host", makespan, {
        "config_issue": issue_cycles,
        "wire_captive": captive,
        "device_stall": stall,
        "preempted_config": preempted,
    }, intervals)


def _wire_lane(link_tel, makespan: float, records: list,
               lane_name: str) -> LaneAttribution:
    # classify each logged transfer by matching the launch that reserved it
    # — (wire_start, config_done) are the transfer's own floats, so the
    # lookup is exact; the wire is FIFO, so positive-length keys are unique
    pending: dict[tuple, list] = {}
    for rec, alive in records:
        if rec.config_done > rec.wire_start:
            pending.setdefault((rec.wire_start, rec.config_done),
                               []).append((rec, alive))
    exposed = overlapped = preempted = other = 0.0
    intervals = []
    for start, end, *_rest in link_tel.log:
        length = end - start
        if length <= 0.0:
            continue  # zero-cost CSR "transfers" occupy nothing
        intervals.append((start, end))
        matches = pending.get((start, end))
        if matches:
            rec, alive = matches.pop(0)
            if not alive:
                preempted += length
            else:
                hidden = min(max(rec.hidden_config, 0.0), length)
                overlapped += hidden
                exposed += length - hidden
        else:
            other += length
    return _lane(lane_name, "wire", makespan, {
        "exposed_transfer": exposed,
        "overlapped_transfer": overlapped,
        "preempted_transfer": preempted,
        "other_transfer": other,
    }, intervals)


def _compute_lanes(rep, makespan: float, prefix: str = "") -> list:
    lanes = []
    for name, tel in rep.resources.items():
        if tel.kind != "compute":
            continue
        intervals = [(s, e) for s, e, _ in tel.intervals]
        busy = sum(e - s for s, e in intervals)
        lanes.append(_lane(prefix + name, "compute", makespan,
                           {"compute": busy}, intervals))
    return lanes


def _summary(lanes: dict, records: list) -> dict:
    return {
        "exposed_config": sum(r.exposed_config for r, _ in records),
        "overlapped_config": sum(r.hidden_config for r, _ in records),
        "compute": sum(l.components["compute"] for l in lanes.values()
                       if l.kind == "compute"),
        "host_occupancy": sum(l.components["config_issue"]
                              for l in lanes.values() if l.kind == "host"),
        "wire_contention": sum(
            max(0.0, r.wire_start - (r.issue + r.host_cycles))
            for r, _ in records),
        "queueing": sum(max(0.0, r.issue - r.arrival) for r, _ in records),
        "idle": sum(l.components["idle"] for l in lanes.values()),
    }


def _reported_exposed(reps) -> float:
    return sum(d.exposed_config_cycles
               for rep in reps for d in rep.devices.values())


# -- entry points -------------------------------------------------------------


def _attribute_scheduler(rep) -> AttributionReport:
    makespan = rep.makespan
    records = _launch_records(rep)
    lanes: dict[str, LaneAttribution] = {}
    host = _host_lane(rep, makespan, records, "host")
    lanes[host.lane] = host
    for name, ltel in rep.links.items():
        lanes[name] = _wire_lane(ltel, makespan, records, name)
    for lane in _compute_lanes(rep, makespan):
        lanes[lane.lane] = lane
    return AttributionReport(
        makespan=makespan,
        lanes=lanes,
        summary=_summary(lanes, records),
        exposed_config=sum(r.exposed_config for r, _ in records),
        reported_exposed_config=_reported_exposed([rep]),
    )


def _attribute_cluster(rep) -> AttributionReport:
    makespan = rep.makespan
    lanes: dict[str, LaneAttribution] = {}
    all_records: list = []
    # a shared cluster port appears once per host report with the *same*
    # full transfer log; fold it into one cluster-wide lane matched against
    # every sharer's launches, while private ports stay host-prefixed
    shared: dict[str, list] = {}
    for host_id, hrep in sorted(rep.hosts.items()):
        records = _launch_records(hrep)
        all_records.extend(records)
        host = _host_lane(hrep, makespan, records, f"{host_id}/host")
        lanes[host.lane] = host
        for lane in _compute_lanes(hrep, makespan, prefix=f"{host_id}/"):
            lanes[lane.lane] = lane
        for name, ltel in hrep.links.items():
            if name.endswith(":shared"):
                entry = shared.setdefault(name, [ltel, []])
                entry[1].extend(records)
            else:
                lanes[f"{host_id}/{name}"] = _wire_lane(
                    ltel, makespan, records, f"{host_id}/{name}")
    for name, (ltel, records) in shared.items():
        lanes[name] = _wire_lane(ltel, makespan, records, name)
    return AttributionReport(
        makespan=makespan,
        lanes=lanes,
        summary=_summary(lanes, all_records),
        exposed_config=sum(r.exposed_config for r, _ in all_records),
        reported_exposed_config=_reported_exposed(rep.hosts.values()),
    )


def attribute(report) -> AttributionReport:
    """Decompose a run's makespan per resource lane. Accepts a
    ``SchedulerReport``, a ``ClusterReport``, or a ``BridgeReport`` (which
    delegates to its cluster view) — all duck-typed."""
    cluster = getattr(report, "cluster", None)
    if cluster is not None and hasattr(cluster, "hosts"):
        report = cluster
    if hasattr(report, "hosts"):
        return _attribute_cluster(report)
    return _attribute_scheduler(report)
