"""What-if estimation: predicted cycle savings behind each doctor knob.

The doctor's recommendations ("enable overlap", "switch MMIO to burst
DMA", "raise ``staging_buffers``") are only useful quantified. This module
prices them by *replaying* a finished run's launch log through a faithful
model of the engine's dispatch recurrence — the same host-reserve /
wire-acquire / bank-wait / ring-full arithmetic ``OverlapPolicy.stage`` and
``LaunchQueue.submit`` perform — once with the run's recorded knobs and
once with the suggested knob flipped. The predicted saving is the
difference between the two replays, so any residual model bias cancels.

What stays fixed across a replay: the request stream, its per-launch cache
write-plans (field counts are a function of the stream, not of timing),
placement, and macro-op durations. What the knob changes: transfer pricing
(MMIO vs burst), whether a transfer may stream asynchronously behind
compute, and how many configuration banks bound the stream's pipelining.
Preempted launches are not replayed — their cycles were already refunded
by the scheduler — so predictions on priority-preemption runs are
approximate; the replay fidelity is reported per estimate
(``detail["replay_error"]``) and pinned ≤ 15% against actual re-simulated
savings in ``tests/test_doctor.py``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from ..fabric.link import LinkModel, resolve_link
from ..fabric.transport import burst_schedule, mmio_schedule

__all__ = [
    "LaunchRow", "Replay", "WhatIf",
    "extract_rows", "replay", "rows_config_energy",
    "predict_overlap", "predict_burst", "predict_staging",
]


@dataclass(frozen=True)
class LaunchRow:
    """One recorded launch, reduced to what the dispatch recurrence needs."""

    arrival: float  # open-loop arrival (host idles forward to it)
    dev: str  # device id (placement is held fixed across replays)
    concurrent: bool  # device configuration discipline
    host_cycles: float  # T_calc + issue (host instruction time)
    wire_cycles: float  # time on the wire (0 on a core-local CSR port)
    compute_cycles: float  # macro-op duration
    xfer_mode: str  # "mmio" | "burst" — as the transport layer priced it
    n_fields: int  # fields actually sent (cache delta; launch excluded)


@dataclass(frozen=True)
class Replay:
    """One pass of the dispatch recurrence over a row list."""

    makespan: float
    exposed_config: float  # host-visible config cycles, summed
    config_cycles: float  # total T_set, summed


@dataclass(frozen=True)
class WhatIf:
    """One quantified recommendation: knob → predicted effect.

    Mitigations are priced on *two* axes. Cycles: the replay difference.
    Joules: the change in configuration energy (host issue + wire
    handshakes/descriptors, re-priced per launch through the link's
    energy rates). The axes can disagree — runtime overlap hides T_set
    without saving a single transfer joule, and burst DMA can win cycles
    while its descriptor-setup energy loses joules below the link's
    joule-crossover — and :attr:`axes_disagree` is how the doctor says
    so."""

    action: str  # "enable_overlap" | "burst_dma" | "staging_buffers"
    knob: dict  # scheduler kwargs realizing the suggestion
    baseline_makespan: float  # the run's actual makespan
    predicted_makespan: float
    predicted_savings: float  # baseline replay − modified replay
    baseline_config_energy: float | None = None  # pJ, None = unpriceable
    predicted_config_energy: float | None = None
    detail: dict = field(default_factory=dict)

    @property
    def predicted_speedup(self) -> float:
        if self.predicted_makespan <= 0.0:
            return 1.0
        return self.baseline_makespan / self.predicted_makespan

    @property
    def predicted_joule_savings(self) -> float | None:
        if self.baseline_config_energy is None:
            return None
        return self.baseline_config_energy - self.predicted_config_energy

    @property
    def axes_disagree(self) -> bool:
        """Does this knob save cycles while *costing* configuration
        joules (or vice versa)? Zero joule delta (overlap, staging) is
        agreement — nothing was spent to buy the cycles."""
        joules = self.predicted_joule_savings
        if joules is None:
            return False
        return (self.predicted_savings > 0.0 > joules
                or joules > 0.0 > self.predicted_savings)

    def to_dict(self) -> dict:
        return {
            "action": self.action,
            "knob": dict(self.knob),
            "baseline_makespan": self.baseline_makespan,
            "predicted_makespan": self.predicted_makespan,
            "predicted_savings": self.predicted_savings,
            "predicted_speedup": self.predicted_speedup,
            "baseline_config_energy": self.baseline_config_energy,
            "predicted_config_energy": self.predicted_config_energy,
            "predicted_joule_savings": self.predicted_joule_savings,
            "axes_disagree": self.axes_disagree,
            "detail": dict(self.detail),
        }


# -- row extraction ----------------------------------------------------------


def report_link(rep) -> LinkModel | None:
    """The link class a scheduler report's transfers crossed. ``None``
    when the report has no link telemetry (or mixes link classes — the
    replay prices one wire, matching the scheduler's single port)."""
    kinds = {lt.kind for lt in getattr(rep, "links", {}).values()}
    if len(kinds) != 1:
        return None
    return resolve_link(kinds.pop())


def extract_rows(rep) -> list[LaunchRow]:
    """Reduce a :class:`~repro.sched.telemetry.SchedulerReport` to replay
    rows, in dispatch order (the host clock is global and strictly
    increasing across launches, so ``issue`` orders them totally)."""
    link = report_link(rep)
    transport = getattr(rep, "transport", "auto")
    recs = []
    for dev_id, tel in rep.devices.items():
        for rec in tel.launch_log:
            recs.append((rec.issue, dev_id, tel.model, rec))
    recs.sort(key=lambda r: r[0])
    rows = []
    for _, dev_id, model, rec in recs:
        wire = rec.config_done - rec.wire_start
        n_fields = max(0, round(rec.bytes_sent / model.bytes_per_field) - 1)
        rows.append(LaunchRow(
            arrival=rec.arrival,
            dev=dev_id,
            concurrent=model.concurrent,
            host_cycles=rec.host_cycles,
            wire_cycles=wire,
            compute_cycles=rec.end - rec.start,
            xfer_mode=_infer_mode(n_fields, model, link,
                                  rec.host_cycles, wire, transport),
            n_fields=n_fields,
        ))
    return rows


def _infer_mode(n_fields: int, model, link: LinkModel | None,
                host_cycles: float, wire_cycles: float,
                transport: str = "auto") -> str:
    """Which transport discipline priced this launch. A forced transport
    knob answers directly; under ``auto`` the recorded
    ``(host_cycles, wire_cycles)`` pair is the pricing function's exact
    output, so matching it against the two candidate schedules recovers
    the choice without a separate log field."""
    if link is None or not link.supports_dma or transport == "mmio":
        return "mmio"
    if transport == "burst":
        return "burst"
    burst = burst_schedule(n_fields, model, link)
    if (burst is not None and burst.host_cycles == host_cycles
            and burst.link_cycles == wire_cycles):
        return "burst"
    return "mmio"


# -- the dispatch recurrence -------------------------------------------------


def replay(rows: list[LaunchRow], *, mode: str, buffers: int = 2,
           depth: int = 2) -> Replay:
    """Run the engine's dispatch recurrence over ``rows``.

    Mirrors ``Scheduler._dispatch_on`` exactly: host reservation at the
    scalar clock, FIFO wire acquisition (async transfers additionally wait
    for a free configuration bank), captive vs released host, depth-k
    staging-ring admission, and per-device FIFO compute. Returns the
    replayed makespan plus the exposed/total config split the roofline
    reads."""
    host = 0.0  # the host resource's committed time (the scalar clock)
    wire_free = 0.0
    compute: dict[str, list] = {}  # per-device (start, end), dispatch order
    inflight: dict[str, deque] = {}
    exposed = 0.0
    config = 0.0

    for row in rows:
        host = max(host, row.arrival)  # open-loop admission idle
        h_end = host + row.host_cycles
        is_async = (mode == "overlapped" and row.concurrent
                    and row.xfer_mode == "burst" and row.wire_cycles > 0.0)
        done = compute.setdefault(row.dev, [])
        earliest = h_end
        if is_async and len(done) >= buffers:
            # the shadow bank frees at launch k-buffers' retirement
            earliest = max(earliest, done[len(done) - buffers][1])
        w_start = max(earliest, wire_free)
        w_end = w_start + row.wire_cycles
        wire_free = w_end
        config_done = w_end
        host = h_end if is_async else max(h_end, w_end)
        # exposed T_set: instruction time plus wire cycles that *earlier*
        # compute on this device failed to cover (for a captive transfer,
        # everything) — mirrors Scheduler._dispatch_on's hidden term
        cfg = row.host_cycles + row.wire_cycles
        config += cfg
        hidden = 0.0
        if is_async:
            for s, e in done:
                hidden += max(0.0, min(w_end, e) - max(w_start, s))
        exposed += cfg - hidden
        # -- LaunchQueue.submit --
        ring = inflight.setdefault(row.dev, deque())
        if row.concurrent:
            while ring and ring[0] <= host:
                ring.popleft()
            while len(ring) >= depth:  # staging ring full: host blocks
                host = max(host, ring.popleft())
        free = done[-1][1] if done else 0.0
        start = max(host, config_done, free)
        end = start + row.compute_cycles
        done.append((start, end))
        if row.concurrent:
            ring.append(end)
        else:
            host = end

    frees = [iv[-1][1] for iv in compute.values() if iv]
    makespan = max([host, *frees]) if rows else 0.0
    return Replay(makespan=makespan, exposed_config=exposed,
                  config_cycles=config)


def rows_config_energy(rows, models, link: LinkModel | None) -> float | None:
    """Total configuration energy (pJ) of a row list under ``link``'s
    energy rates: each launch re-priced through the schedule its
    ``xfer_mode`` names (host issue energy + wire handshake/descriptor +
    streamed bytes). This is the joule axis of every what-if: replay
    timing never enters — moving a transfer in time (overlap, staging)
    leaves its energy untouched, while re-pricing it (burst) does not.
    ``None`` when the report's wire is unpriceable (no/mixed links)."""
    if link is None:
        return None
    total = 0.0
    for r in rows:
        model = models[r.dev]
        xfer = None
        if r.xfer_mode == "burst":
            xfer = burst_schedule(r.n_fields, model, link)
        if xfer is None:
            xfer = mmio_schedule(r.n_fields, model, link)
        total += xfer.energy
    return total


# -- estimators --------------------------------------------------------------


def _estimate(rep, action: str, knob: dict, base_rows, base_kw: dict,
              mod_rows, mod_kw: dict, detail: dict | None = None) -> WhatIf:
    base = replay(base_rows, **base_kw)
    mod = replay(mod_rows, **mod_kw)
    savings = base.makespan - mod.makespan
    actual = rep.makespan
    err = abs(base.makespan - actual) / actual if actual else 0.0
    d = dict(detail or {})
    d.update({
        "replayed_baseline": base.makespan,
        "replayed_modified": mod.makespan,
        "replay_error": err,
        "exposed_config_after": mod.exposed_config,
    })
    link = report_link(rep)
    models = {dev_id: tel.model for dev_id, tel in rep.devices.items()}
    base_e = rows_config_energy(base_rows, models, link)
    mod_e = rows_config_energy(mod_rows, models, link)
    return WhatIf(
        action=action,
        knob=knob,
        baseline_makespan=actual,
        predicted_makespan=actual - savings,
        predicted_savings=savings,
        baseline_config_energy=base_e,
        predicted_config_energy=mod_e,
        detail=d,
    )


def predict_overlap(rep, *, buffers: int | None = None,
                    depth: int = 2) -> WhatIf | None:
    """What would runtime overlap buy this serialized run? ``None`` when
    the run is already overlapped or nothing could stream (no async-eligible
    burst transfer onto a concurrent device)."""
    if getattr(rep, "overlap_mode", "serialized") == "overlapped":
        return None
    rows = extract_rows(rep)
    eligible = sum(1 for r in rows
                   if r.concurrent and r.xfer_mode == "burst"
                   and r.wire_cycles > 0.0)
    if not eligible:
        return None
    buffers = buffers if buffers is not None else getattr(
        rep, "staging_buffers", 2)
    return _estimate(
        rep, "enable_overlap", {"overlap": "overlapped"},
        rows, dict(mode="serialized", buffers=buffers, depth=depth),
        rows, dict(mode="overlapped", buffers=buffers, depth=depth),
        detail={"async_eligible_launches": eligible},
    )


def predict_burst(rep, *, depth: int = 2) -> WhatIf | None:
    """What would coalescing per-register MMIO into burst DMA buy? Reprices
    every MMIO transfer of ≥ 8 fields through the link's DMA engine (the
    crossover region the paper measures) and replays. ``None`` when the
    link has no DMA engine or no transfer qualifies."""
    link = report_link(rep)
    if link is None or not link.supports_dma:
        return None
    models = {dev_id: tel.model for dev_id, tel in rep.devices.items()}
    rows = extract_rows(rep)
    from dataclasses import replace
    mod_rows, repriced = [], 0
    for r in rows:
        if r.xfer_mode == "mmio" and r.n_fields >= 8:
            xfer = burst_schedule(r.n_fields, models[r.dev], link)
            if xfer is not None:
                mod_rows.append(replace(
                    r, xfer_mode="burst", host_cycles=xfer.host_cycles,
                    wire_cycles=xfer.link_cycles))
                repriced += 1
                continue
        mod_rows.append(r)
    if not repriced:
        return None
    mode = getattr(rep, "overlap_mode", "serialized")
    buffers = getattr(rep, "staging_buffers", 2)
    kw = dict(mode=mode, buffers=buffers, depth=depth)
    return _estimate(
        rep, "burst_dma", {"transport": "burst"},
        rows, kw, mod_rows, kw,
        detail={"repriced_launches": repriced},
    )


def predict_staging(rep, *, buffers: int = 2, depth: int = 2) -> WhatIf | None:
    """What would ``staging_buffers=buffers`` buy an overlapped run whose
    async transfers wait on configuration banks? ``None`` for serialized
    runs (banks never bound a captive transfer) or when the run already
    has that many banks."""
    if getattr(rep, "overlap_mode", "serialized") != "overlapped":
        return None
    current = getattr(rep, "staging_buffers", 2)
    if buffers == current:
        return None
    rows = extract_rows(rep)
    return _estimate(
        rep, "staging_buffers", {"staging_buffers": buffers},
        rows, dict(mode="overlapped", buffers=current, depth=depth),
        rows, dict(mode="overlapped", buffers=buffers, depth=depth),
        detail={"buffers_before": current, "buffers_after": buffers},
    )
