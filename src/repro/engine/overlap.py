"""Runtime configuration–computation overlap (the §5.5 pass, at dispatch).

The compiler half of the system (``core.passes.overlap``) hides T_set
*statically*: for concurrent-configuration targets it pipelines loops so
iteration ``i+1``'s setup runs while iteration ``i`` computes. This module
is the runtime twin. A scheduler dispatching launch N+1 while launch N's
macro-op is still running faces the same opportunity — and, without this
policy, wastes it: the serialized discipline keeps the host captive for the
wire time of its own config transfers.

Two modes, selected per scheduler:

* **serialized** — the pre-engine behavior, reproduced bit-exactly: the
  host reserves its instruction time, the transfer follows on the wire, and
  the host stays captive until the wire completes (``T_set`` is fully
  host-visible, Eq. 4's worst case).
* **overlapped** — double-buffered staging: when the transfer is an async
  **burst DMA** (the link has a DMA engine and the transport layer picked
  burst) onto a **concurrent-configuration** device, the host is released
  the moment the descriptor is enqueued (its instruction time only); the
  DMA engine streams the register image behind the accelerator's compute.
  Per-register MMIO stays captive even in overlapped mode — ordered device
  stores complete synchronously on the host — and sequential-configuration
  devices (Gemmini) cannot overlap at all (§2.2: the host stalls through
  the macro-op), exactly the asymmetry the paper measures.

**Double buffering.** The device holds ``buffers`` configuration banks
(default 2: active + shadow). A launch's bank is occupied from its
transfer's start until its macro-op *retires* — the active image drives the
datapath — so the async transfer for launch *k* may start no earlier than
the retirement of launch *k − buffers*. With two banks, launch N+1's write
plan streams while launch N computes (the §5.5 picture), and launch N+2's
must wait for N to retire. The config-complete edge is an invariant the
scheduler enforces: a launch's compute may not start before its transfer
ends (``StagePlan.config_done``), the runtime equivalent of the pass's
"staged fields are never observed by an earlier launch" soundness rule.
"""

from __future__ import annotations

from dataclasses import dataclass

from .resources import Resource

OVERLAP_MODES = ("serialized", "overlapped")

# transfer disciplines that release the host before the wire completes:
# burst DMA (descriptor enqueue, engine streams) and write-combined MMIO
# (posted writes land in the link's write buffer and drain behind the
# host). Plain MMIO is never here — ordered device stores complete
# synchronously.
ASYNC_XFER_MODES = ("burst", "wc")


@dataclass(frozen=True)
class StagePlan:
    """One launch's configuration placed onto the engine resources."""

    host_start: float  # control thread begins this launch's config work
    host_busy: float  # host instruction cycles (T_calc + issue)
    wire_start: float  # transfer begins on the wire
    config_done: float  # every register on-device; compute may not start earlier
    host_release: float  # host clock after config (captive through the wire
    #                      when synchronous; descriptor-enqueue when async)
    asynchronous: bool  # wire streamed behind the host (burst DMA) or captive


class OverlapPolicy:
    """Places each launch's config transfer: captive (serialized) or
    double-buffered async staging (overlapped)."""

    def __init__(self, mode: str = "serialized", buffers: int = 2):
        assert mode in OVERLAP_MODES, mode
        assert buffers >= 1, buffers
        self.mode = mode
        self.buffers = buffers
        # observation-only hook (repro.obs.trace): the owning scheduler
        # attaches its tracer so async staging decisions leave markers
        self.tracer = None
        # per device: (total launches committed, trailing retirement times
        # in dispatch order). Transfer k's bank wait is bounded by the
        # retirement of launch k-buffers, so only the trailing window is
        # kept — `buffers + 1` entries, one of slack because a preemption
        # (`preempted`) pops the newest entry between two commits
        self._committed: dict[str, int] = {}
        self._retired: dict[str, list[float]] = {}

    # -- queries --------------------------------------------------------------

    def is_async(self, concurrent: bool, xfer) -> bool:
        """Would this transfer stream behind the host? Burst DMA or posted
        write-combining onto a concurrent-configuration device with actual
        wire time to hide (:data:`ASYNC_XFER_MODES`)."""
        return (self.mode == "overlapped" and concurrent
                and xfer.mode in ASYNC_XFER_MODES and xfer.link_cycles > 0.0)

    def exposed_cost(self, concurrent: bool, xfer) -> float:
        """Host-visible cycles of this transfer — the placement-probe term.
        Async staging exposes only the host's instruction time; a captive
        transfer exposes the full ``T_set`` (host + wire)."""
        return xfer.host_cycles if self.is_async(concurrent, xfer) else xfer.t_set

    def bank_free(self, dev_id: str) -> float:
        """Earliest time a configuration bank frees on this device: the
        retirement of the launch ``buffers`` dispatches back."""
        total = self._committed.get(dev_id, 0)
        if total < self.buffers:
            return 0.0
        retired = self._retired[dev_id]
        # the trailing window holds launches [total - len(retired), total)
        return retired[len(retired) - self.buffers]

    # -- staging --------------------------------------------------------------

    def stage(self, *, dev_id: str, concurrent: bool, xfer, host: Resource,
              port, issue: float, tag: str = "") -> StagePlan:
        """Reserve the host and the wire for one launch's configuration.

        ``xfer`` is the fabric :class:`~repro.fabric.transport.TransferSchedule`
        (mode already chosen by cost); ``port`` the (possibly shared)
        :class:`~repro.fabric.link.LinkPort` whose wire resource the
        transfer occupies. Returns where everything landed; the caller
        submits compute no earlier than ``config_done`` and advances the
        host clock to ``host_release``.
        """
        h = host.reserve(issue, xfer.host_cycles, tag=tag)
        asynchronous = self.is_async(concurrent, xfer)
        earliest = h.end
        if asynchronous:
            # the shadow bank must be free before the DMA may fill it
            earliest = max(earliest, self.bank_free(dev_id))
        w = port.acquire(earliest, xfer.link_cycles, nbytes=xfer.nbytes,
                         tag=tag, mode=xfer.mode,
                         energy=getattr(xfer, "wire_energy", None))
        release = h.end if asynchronous else max(h.end, w.end)
        if self.tracer is not None and asynchronous:
            # the host was released at descriptor enqueue; note how long
            # the DMA then waited for a free shadow bank (double buffering)
            self.tracer.instant("async-stage", h.end, lane="host",
                                device=dev_id, tenant=tag,
                                bank_wait=max(0.0, earliest - h.end))
        return StagePlan(
            host_start=h.start,
            host_busy=xfer.host_cycles,
            wire_start=w.start,
            config_done=w.end,
            host_release=release,
            asynchronous=asynchronous,
        )

    def committed(self, dev_id: str, retire: float) -> None:
        """Record a staged launch's retirement time (frees its bank for
        the launch ``buffers`` dispatches ahead)."""
        retired = self._retired.setdefault(dev_id, [])
        retired.append(retire)
        self._committed[dev_id] = self._committed.get(dev_id, 0) + 1
        if len(retired) > self.buffers + 1:
            del retired[0]  # older entries can never bound a future transfer

    def preempted(self, dev_id: str) -> None:
        """Forget the newest commitment on a device — its staged launch
        was cancelled before starting, so its bank frees immediately."""
        retired = self._retired.get(dev_id)
        if retired:
            retired.pop()
            self._committed[dev_id] -= 1
