"""The three-resource occupancy model of a configuration engine.

Until now the runtime smeared one *implicit* timeline across four layers:
``sched.Scheduler`` bumped a scalar host clock, ``fabric.LinkPort`` kept its
own ``busy_until``, ``sched.LaunchQueue`` its own ``device_free``, and
``cluster.Host`` re-derived a backlog estimate from all three with a bespoke
max/half-open formula. This module makes the model explicit: a launch's
configuration occupies **three distinct, serially-contended resources** —

* the **host** control thread (parameter calculation, descriptor build,
  write/launch instruction issue — the T_calc side of Eq. 4),
* the **wire** (the config DMA engine / interconnect transaction path —
  the transfer side of T_set that `repro.fabric` prices), and
* the accelerator's **compute** datapath (macro-op execution).

Colagrande & Benini's offload-overhead analysis makes the same cut at the
MPSoC level: issue, transfer, and execution are separate contended
resources, and setup only streams behind execution once they are modeled
separately. Each :class:`Resource` is FIFO — a reservation starts at
``max(earliest, free)`` — which is exactly the discipline every layer
already assumed; the refactor changes *where the intervals live* (one
queryable log per resource), not what they cost. The serialized engine mode
therefore reproduces the pre-refactor cycle counts bit-exactly, while the
overlapped mode (``engine.overlap``) gets the vocabulary it needs to place
a wire transfer *behind* compute instead of inside the host's captive time.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

RESOURCE_KINDS = ("host", "wire", "compute", "resource")


@dataclass(frozen=True)
class Interval:
    """One busy occupancy of a resource."""

    start: float
    end: float
    tag: str = ""  # tenant / purpose

    @property
    def cycles(self) -> float:
        return self.end - self.start


class Resource:
    """One serially-occupied engine resource with a busy-interval log.

    Reservations are FIFO: a request placed with ``earliest`` starts at
    ``max(earliest, free)`` and pushes ``free`` to its end — the same
    discipline the scalar host clock, ``LinkPort.busy_until`` and
    ``LaunchQueue.device_free`` each implemented privately before. The log
    keeps every interval (zero-length ones included, so transfer *counts*
    survive on zero-cost links), which is what telemetry, the overlap
    accounting, and ``port_wait`` queries read.

    Two mutations besides :meth:`reserve`:

    * :meth:`advance` — move ``free`` forward *without* logging busy time:
      captive waiting (a host stalled on a wire or a macro-op) and open-loop
      idling are occupancy of nothing; they must not inflate busy cycles, or
      the serialized↔overlapped conservation invariant breaks.
    * :meth:`pop_last` — un-log the newest interval (a preempted staged
      launch whose macro-op never ran); the caller restores ``free``.
    """

    def __init__(self, name: str, kind: str = "resource"):
        assert kind in RESOURCE_KINDS, kind
        self.name = name
        self.kind = kind
        self.free = 0.0  # committed time: the clock of this resource
        self.log: list[Interval] = []
        # optional repro.power.EnergyModel attached by the scheduler when a
        # PowerSpec is in play — observation-only (never consulted by any
        # reserve/when/backlog path), read by the energy meter and the
        # windowed power monitor
        self.energy = None

    # -- queries (side-effect free) ------------------------------------------

    def when(self, earliest: float, duration: float) -> Interval:
        """Where a reservation *would* land, without taking it — the probe
        primitive placement scoring uses."""
        start = max(earliest, self.free)
        return Interval(start, start + duration)

    def backlog(self, now: float) -> float:
        """Cycles this resource is already committed beyond ``now``. The
        interval is half-open ``[start, end)``: work completing at exactly
        ``now`` holds the resource for zero further cycles."""
        return max(0.0, self.free - now)

    @property
    def busy_cycles(self) -> float:
        return sum(iv.cycles for iv in self.log)

    def overlap_with(self, start: float, end: float) -> float:
        """Cycles of ``[start, end)`` already covered by this resource's
        busy intervals — the quantum of *hiding*: a wire transfer's overlap
        with its device's compute intervals is exactly the config time the
        runtime kept off the critical path.

        FIFO reservations make both starts and ends non-decreasing in log
        order, so the scan walks backward and stops at the first interval
        ending at or before the window — O(overlapping intervals), not
        O(log length), keeping the per-dispatch query cheap on long runs."""
        total = 0.0
        for iv in reversed(self.log):
            if iv.end <= start:
                break  # every earlier interval ends no later
            if iv.start < end:
                covered = min(end, iv.end) - max(start, iv.start)
                if covered > 0.0:
                    total += covered
        return total

    def intervals(self) -> list[tuple[float, float, str]]:
        """(start, end, tag) in reservation order — renderable beside
        device gantts on one time axis."""
        return [(iv.start, iv.end, iv.tag) for iv in self.log]

    # -- mutations ------------------------------------------------------------

    def reserve(self, earliest: float, duration: float, tag: str = "") -> Interval:
        """Occupy the resource FIFO starting no earlier than ``earliest``."""
        assert duration >= 0.0, duration
        iv = self.when(earliest, duration)
        iv = Interval(iv.start, iv.end, tag)
        self.free = iv.end
        self.log.append(iv)
        return iv

    def advance(self, to: float) -> None:
        """Commit the resource's clock forward without logging busy time
        (captive stall or open-loop idle — occupancy of nothing)."""
        self.free = max(self.free, to)

    def pop_last(self) -> Interval | None:
        """Un-log the newest interval (preemption); the caller is
        responsible for restoring ``free`` to the machine's real state."""
        return self.log.pop() if self.log else None


def merge_intervals(intervals: Iterable[tuple]) -> list[tuple[float, float]]:
    """Union of ``(start, end, ...)`` intervals as disjoint sorted spans."""
    spans = sorted((iv[0], iv[1]) for iv in intervals if iv[1] > iv[0])
    merged: list[tuple[float, float]] = []
    for lo, hi in spans:
        if merged and lo <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
        else:
            merged.append((lo, hi))
    return merged


def overlap_cycles(a: Iterable[tuple], b: Iterable[tuple]) -> float:
    """Cycles covered by both ``(start, end, ...)`` interval sequences —
    e.g. wire∩compute is the config time that hid. Each side is unioned
    first, so overlapping members (two devices computing at once) never
    double-count the same wall-clock cycle; the merged spans are sorted
    and disjoint, so one two-pointer sweep covers both lists."""
    sa, sb = merge_intervals(a), merge_intervals(b)
    total, i, j = 0.0, 0, 0
    while i < len(sa) and j < len(sb):
        lo = max(sa[i][0], sb[j][0])
        hi = min(sa[i][1], sb[j][1])
        if hi > lo:
            total += hi - lo
        # advance whichever span ends first — the other may still overlap
        if sa[i][1] <= sb[j][1]:
            i += 1
        else:
            j += 1
    return total


class EngineResources:
    """The three resources one scheduler (one host shard) dispatches onto.

    ``host`` and ``wire`` are single instances; ``compute`` is per device.
    The wire resource is *shared with* the fabric :class:`~repro.fabric.link.LinkPort`
    (the port reserves through it), so a cluster-level port shared by
    several hosts makes every sharer's config transfers contend on one
    timeline — the PCIe-switch model.
    """

    def __init__(self, host: Resource, wire: Resource,
                 compute: dict[str, Resource]):
        assert host.kind == "host" and wire.kind == "wire"
        self.host = host
        self.wire = wire
        self.compute = dict(compute)

    def all(self) -> dict[str, Resource]:
        out = {self.host.name: self.host, self.wire.name: self.wire}
        for res in self.compute.values():
            out[res.name] = res
        return out

    def port_wait(self, now: float) -> float:
        """Cycles a request arriving at ``now`` waits before its first
        config write can start on this engine — the later of the host
        control thread's and the wire's committed time. The two combine by
        ``max()``, never ``+``: a serialized host is captive for its own
        transfers, so the in-flight transfer is already inside the host
        clock and summing would double-count it; under overlap the wire can
        outrun the host and the wire term bites on its own. Both backlogs
        are half-open ``[start, end)`` queries (:meth:`Resource.backlog`).

        Note: *hidden* config accounting deliberately does **not** live
        here — a wire transfer only hides behind its own target device's
        compute, and only when asynchronous, so the authoritative numbers
        are the per-launch ``exposed_config`` the scheduler computes at
        dispatch (``DeviceTelemetry.exposed_config_cycles``)."""
        return max(0.0, self.host.backlog(now), self.wire.backlog(now))
