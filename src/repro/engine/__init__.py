"""repro.engine — explicit three-resource occupancy + runtime config overlap.

The configuration wall has three walls, not one: a launch's setup occupies
the **host** control thread (parameter calculation + issue), the **wire**
(the config DMA / interconnect path), and gates the accelerator's
**compute**. The layers below used to account all three on one implicit
timeline — the scheduler's scalar host clock — which made the host
conservatively captive for the wire time of its own transfers and left the
§5.5 overlap win compile-time-only.

* :mod:`~repro.engine.resources` — :class:`Resource` (FIFO reservations
  over a busy-interval log, pure ``when``/``backlog``/``overlap_with``
  queries) and :class:`EngineResources` (the host/wire/compute triple one
  scheduler dispatches onto, including the single ``port_wait`` query the
  router and SLO report share).
* :mod:`~repro.engine.overlap` — :class:`OverlapPolicy`: serialized
  (pre-engine behavior, bit-exact) vs. overlapped (double-buffered async
  burst-DMA staging that releases the host at descriptor enqueue and hides
  the wire behind compute — the runtime twin of ``core.passes.overlap``).
* :mod:`~repro.engine.costmodel` — :class:`ComputeModel`: calibrated
  per-kernel-shape cycle prediction (issue + measured-overhead × work,
  fitted against the real Pallas kernels; flat mode reproduces the old
  per-launch constant bit-exactly).
* :mod:`~repro.engine.autotune` — :func:`tune`: picks ``overlap`` and
  ``staging_buffers`` from the predicted wire/compute ratio instead of
  hand-tuning them per deployment.

``sched`` reserves through this layer, ``fabric.LinkPort`` exposes the wire
as a :class:`Resource`, and ``cluster``/``bridge`` read the per-resource
timelines back out as telemetry.
"""

from . import autotune, costmodel, overlap, resources
from .autotune import TunedKnobs, tune, tune_from_ratio
from .costmodel import (
    COMPUTE_MODES,
    ComputeModel,
    KernelFit,
    fit_overhead,
    load_fits,
    resolve_compute_model,
)
from .overlap import ASYNC_XFER_MODES, OVERLAP_MODES, OverlapPolicy, StagePlan
from .resources import (
    EngineResources,
    Interval,
    Resource,
    merge_intervals,
    overlap_cycles,
)

__all__ = [
    "ASYNC_XFER_MODES",
    "COMPUTE_MODES",
    "ComputeModel",
    "EngineResources",
    "Interval",
    "KernelFit",
    "OVERLAP_MODES",
    "OverlapPolicy",
    "Resource",
    "StagePlan",
    "TunedKnobs",
    "autotune",
    "costmodel",
    "fit_overhead",
    "load_fits",
    "merge_intervals",
    "overlap",
    "overlap_cycles",
    "resolve_compute_model",
    "resources",
    "tune",
    "tune_from_ratio",
]
