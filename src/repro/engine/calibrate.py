"""Calibration harness — fit overhead factors against real Pallas kernels.

Times the actual kernels in ``repro.kernels`` (matmul, flash_attention,
sampling) over a ladder of shapes, then least-squares-fits each kernel's
measured wall-clock against the analytical ``issue + work`` terms of
``engine.costmodel`` — the csl-experiments workflow: the model's *form* is
analytical, its *overhead factor* is measured, never guessed.

Run it where the kernels run::

    PYTHONPATH=src python -m repro.engine.calibrate \
        --backend pallas_interpret --out src/repro/engine/calibration.json

and commit the JSON. CI and tests only ever *load* the committed fits
(``costmodel.load_fits``) — timing happens here, once, not per test run,
so the repo's numbers are deterministic on any machine.

Interpret-mode wall-clock is a CPU emulation of the kernel's grid walk, so
the fitted ``seconds_per_cycle`` is not a TPU cycle time — but the
*overhead factor* (measured/ideal work ratio) is exactly the quantity the
model form wants: how much the real grid loop, block fetches, and epilogue
inflate the ideal datapath count. On real hardware the same harness
re-fits with ``--backend pallas``."""

from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from ..core.accelerators import REGISTRY, AcceleratorModel
from ..kernels import ops
from .costmodel import (CALIBRATION_PATH, KERNELS, KernelFit, fit_overhead,
                        save_fits)

# shape ladders: (M, K, N) logical dims per costmodel.KERNELS semantics.
# matmul blocks are 128-multiples (the kernel asserts divisibility);
# flash_attention dims are (seq, head_dim, seq); sampling (batch, -, vocab).
# Ladders deliberately stop before the CPU emulation's cache-spill cliff
# (512³ matmul, 16×32k sampling go superlinear in wall-clock): a linear
# cycle model should be calibrated in the regime it covers — the spill is
# a property of the *emulator's* memory hierarchy, not of the kernels.
SHAPES: dict[str, list[tuple[int, int, int]]] = {
    "matmul": [
        (128, 128, 128),
        (256, 128, 128),
        (128, 256, 128),
        (128, 128, 256),
        (256, 256, 256),
        (384, 256, 384),
    ],
    "flash_attention": [
        (128, 64, 128),
        (256, 64, 256),
        (384, 64, 384),
        (512, 64, 512),
        (256, 128, 256),
    ],
    "sampling": [
        (4, 0, 1024),
        (4, 0, 4096),
        (8, 0, 4096),
        (4, 0, 8192),
        (8, 0, 8192),
    ],
}

SMOKE_SHAPES = {k: v[:3] for k, v in SHAPES.items()}


def _run_kernel(kernel: str, dims, backend: str):
    """Build inputs for one logical shape and return a thunk running the
    real kernel (jit-compiled; caller blocks on the result)."""
    m, k, n = dims
    key = jax.random.PRNGKey(m * 7 + k * 13 + n * 29)
    if kernel == "matmul":
        a = jax.random.normal(key, (m, k), jnp.float32)
        b = jax.random.normal(key, (k, n), jnp.float32)
        return lambda: ops.matmul_op(a, b, backend=backend)
    if kernel == "flash_attention":
        q, kk, v = (jax.random.normal(jax.random.fold_in(key, i),
                                      (1, 1, m, k), jnp.float32)
                    for i in range(3))
        return lambda: ops.attention_op(q, kk, v, causal=False,
                                        backend=backend)
    if kernel == "sampling":
        logits = jax.random.normal(key, (m, n), jnp.float32)
        return lambda: ops.sample_op(logits, backend=backend)
    raise ValueError(f"unknown kernel {kernel!r}")


def time_kernel(kernel: str, dims, *, backend: str = "pallas_interpret",
                repeats: int = 3) -> float:
    """Median wall-clock seconds of one kernel execution at ``dims``.

    One untimed warmup run absorbs jit tracing/compilation; each timed run
    blocks on the result so device/async dispatch cannot hide."""
    thunk = _run_kernel(kernel, dims, backend)
    jax.block_until_ready(thunk())  # warmup: compile + first grid walk
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def calibrate_kernel(kernel: str, shapes, model: AcceleratorModel,
                     *, backend: str = "pallas_interpret",
                     repeats: int = 3) -> tuple[KernelFit, list]:
    """Fit one kernel's overhead factor over its shape ladder; returns the
    fit and the raw (dims, seconds) samples for the audit trail."""
    spec = KERNELS[kernel]
    issues, works, seconds, samples = [], [], [], []
    for dims in shapes:
        secs = time_kernel(kernel, dims, backend=backend, repeats=repeats)
        issues.append(model.launch_latency + spec.steps(dims, model.tile))
        works.append(spec.ops(dims) / model.p_peak)
        seconds.append(secs)
        samples.append({"dims": list(dims), "seconds": secs})
    fit = fit_overhead(issues, works, seconds)
    return KernelFit(kernel=kernel, overhead_factor=fit.overhead_factor,
                     seconds_per_cycle=fit.seconds_per_cycle, r2=fit.r2,
                     n_samples=fit.n_samples), samples


def run_calibration(*, backend: str = "pallas_interpret",
                    accel: str = "opengemm", repeats: int = 3,
                    smoke: bool = False, verbose: bool = True):
    """Time every kernel's ladder and fit its overhead factor."""
    model = REGISTRY[accel]
    shapes = SMOKE_SHAPES if smoke else SHAPES
    fits, samples = {}, {}
    for kernel in sorted(KERNELS):
        fit, raw = calibrate_kernel(kernel, shapes[kernel], model,
                                    backend=backend, repeats=repeats)
        fits[kernel] = fit
        samples[kernel] = raw
        if verbose:
            print(f"{kernel:>16}: overhead_factor={fit.overhead_factor:.4g} "
                  f"sec/cycle={fit.seconds_per_cycle:.3g} "
                  f"r2={fit.r2:.4f} n={fit.n_samples}")
    return fits, samples


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="pallas_interpret",
                    choices=ops.BACKENDS)
    ap.add_argument("--accel", default="opengemm", choices=sorted(REGISTRY))
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="short shape ladders (CI sanity, not for committing)")
    ap.add_argument("--out", default=CALIBRATION_PATH)
    args = ap.parse_args(argv)
    fits, samples = run_calibration(backend=args.backend, accel=args.accel,
                                    repeats=args.repeats, smoke=args.smoke)
    save_fits(fits, args.out, backend=args.backend, samples=samples)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
