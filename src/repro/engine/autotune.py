"""Overlap autotuner — pick engine knobs from the predicted wire/compute ratio.

The overlap engine has two knobs a caller previously hand-picked per
deployment: ``overlap`` (serialized vs double-buffered async staging) and
``staging_buffers`` (configuration banks). Both are pure functions of one
quantity the calibrated compute model (``engine.costmodel``) can now
predict instead of guess: the **wire/compute ratio** — cycles a launch's
config transfer occupies the wire over cycles its macro-op occupies the
datapath.

Decision table (:func:`tune`):

====================================  ============  ====================
predicted regime                      overlap       staging_buffers
====================================  ============  ====================
nothing can hide (sequential device,  serialized    2 (idle default)
zero wire time, or a captive
transport — plain MMIO)
wire ≤ compute (config-bound side     overlapped    2 — the shadow bank
of the launch roofline's ridge,                     fully hides transfer
compute long enough to hide behind)                 k+1 behind compute k
wire > compute (transfer outlives     overlapped    1 + ⌈wire/compute⌉,
each macro-op: banks must cover the                 capped at ``max_buffers``
backlog for the wire to stream
gap-free)
====================================  ============  ====================

In steady state a transfer may start only after launch ``k − buffers``
retires, so hiding a transfer of ``w`` cycles behind computes of ``c``
cycles needs ``(buffers − 1) · c ≥ w``, i.e. ``buffers ≥ 1 + w/c`` — the
table's third row; with ``w ≤ c`` two banks suffice, the classic double
buffer. More banks than needed never hurt makespan (staging-buffer
monotonicity, pinned in ``tests/test_engine.py``), so the autotuned pick
matches or beats the hand-picked default by construction; it *wins*
whenever the default left overlap off on a link that could hide.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from ..core.accelerators import AcceleratorModel
from .costmodel import ComputeModel, resolve_compute_model
from .overlap import ASYNC_XFER_MODES

DEFAULT_BUFFERS = 2
MAX_BUFFERS = 8


@dataclass(frozen=True)
class TunedKnobs:
    """One (device, link, workload) point's autotuned engine knobs."""

    overlap: str  # "serialized" | "overlapped"
    staging_buffers: int
    transport: str  # the transport spec to run with (usually "auto")
    xfer_mode: str  # discipline the transport layer picked at this point
    wire_cycles: float  # predicted config transfer time per launch
    compute_cycles: float  # predicted macro-op time per launch
    reason: str  # which decision-table row fired

    @property
    def ratio(self) -> float:
        """Predicted wire/compute ratio — the decision axis."""
        if self.compute_cycles <= 0.0:
            return math.inf if self.wire_cycles > 0.0 else 0.0
        return self.wire_cycles / self.compute_cycles

    def scheduler_kwargs(self) -> dict:
        """Keyword arguments for ``Scheduler``/``Cluster.uniform``."""
        return {"overlap": self.overlap,
                "staging_buffers": self.staging_buffers,
                "transport": self.transport}


def tune_from_ratio(wire_cycles: float, compute_cycles: float, *,
                    can_hide: bool, transport: str = "auto",
                    xfer_mode: str = "mmio",
                    max_buffers: int = MAX_BUFFERS) -> TunedKnobs:
    """Decision table over an already-known (wire, compute) pair —
    :func:`tune` predicts the pair, monitors can feed observed ones."""
    if not can_hide or wire_cycles <= 0.0:
        reason = ("no wire time to hide" if wire_cycles <= 0.0
                  else "transfer cannot stream behind compute")
        return TunedKnobs(overlap="serialized",
                          staging_buffers=DEFAULT_BUFFERS,
                          transport=transport, xfer_mode=xfer_mode,
                          wire_cycles=wire_cycles,
                          compute_cycles=compute_cycles, reason=reason)
    if compute_cycles <= 0.0 or wire_cycles <= compute_cycles:
        reason = "wire fits behind one macro-op: double buffer"
        buffers = DEFAULT_BUFFERS
    else:
        reason = "wire outlives each macro-op: deepen the staging ring"
        buffers = min(1 + math.ceil(wire_cycles / compute_cycles),
                      max_buffers)
    return TunedKnobs(overlap="overlapped", staging_buffers=buffers,
                      transport=transport, xfer_mode=xfer_mode,
                      wire_cycles=wire_cycles, compute_cycles=compute_cycles,
                      reason=reason)


def tune(model: AcceleratorModel, link, dims,
         n_fields: int, *, kernel: str = "matmul",
         compute_model: "ComputeModel | str | None" = None,
         transport: str = "auto", objective: str = "cycles",
         max_buffers: int = MAX_BUFFERS) -> TunedKnobs:
    """Autotune the overlap knobs for launches of ``kernel`` at ``dims``
    (logical M, K, N) writing ``n_fields`` registers per launch over
    ``link``.

    Wire cycles come from the transport layer's own plan (the discipline
    ``transport``/``objective`` would pick at dispatch); compute cycles
    from ``compute_model`` (a :class:`~repro.engine.costmodel.ComputeModel`,
    a mode string, or ``None`` for the flat constant). A transfer can only
    stream behind compute on a concurrent-configuration device via an
    async-capable discipline (:data:`~repro.engine.overlap.ASYNC_XFER_MODES`)
    — otherwise the table's serialized row fires."""
    # deferred: fabric.link's LinkPort builds on engine.resources, so a
    # module-level import here would make repro.engine ↔ repro.fabric
    # circular
    from ..fabric.link import resolve_link
    from ..fabric.transport import plan_fields

    link = resolve_link(link)
    cm = resolve_compute_model(compute_model) or ComputeModel.flat()
    xfer = plan_fields(n_fields, model, link, mode=transport,
                       objective=objective)
    compute = cm.predict(kernel, dims, model)
    can_hide = (model.concurrent and xfer.mode in ASYNC_XFER_MODES
                and xfer.link_cycles > 0.0)
    return tune_from_ratio(xfer.link_cycles, compute, can_hide=can_hide,
                           transport=transport, xfer_mode=xfer.mode,
                           max_buffers=max_buffers)
