"""Calibrated analytical compute model — per-kernel-shape cycle prediction.

Until now every launch's compute duration was a flat constant per macro-op:
``AcceleratorModel.macro_cycles`` prices ``launch_latency + ops/p_peak``,
the ideal datapath time, as if loop control, tile fetches, and pipeline
stalls were free. That undersells the configuration wall's other side — the
overlap engine's wire/compute ratio, the router's placement probes, and the
doctor's what-if replays are all anchored to a made-up number.

The fix is the standard analytical form (Prajapati et al., arXiv:1802.01957;
the csl-experiments GEMM model): per kernel shape,

    cycles = issue(shape)  +  overhead_factor × work(shape)

where

* ``issue(shape)`` — launch setup plus one issue cycle per grid step
  (``depth × (launch_latency + steps(M, K, N))``, steps from the device's
  tile): the loop-control floor no datapath width removes;
* ``work(shape)`` — the ideal datapath term (``ops(M, K, N) / p_peak``);
* ``overhead_factor`` — a **measured** dimensionless factor folding in
  everything the analytical minimum omits (loop control, memory ops, task
  switching, pipeline stalls), fitted per kernel against wall-clock timings
  of the real Pallas kernels (``engine.calibrate``). On hardware it lands
  ≥ 1 (measured work can't beat the datapath minimum); under interpret-mode
  calibration it can be < 1, because a CPU emulating the grid pays per
  step, not per datapath op.

Fits persist to a committed ``calibration.json`` next to this module, so CI
and tests are deterministic without re-timing; the harness that produced
them can be re-run with ``python -m repro.engine.calibrate``.

:class:`ComputeModel` is the scheduler-facing object. ``mode="flat"``
reproduces ``AcceleratorModel.macro_cycles`` **bit-exactly** (every
committed BENCH number is pinned to it); ``mode="calibrated"`` applies the
fitted per-kernel model, pricing decode and prefill launches by their real
shapes (a chunked prefill's M-scaled GEMM costs more than ``chunk`` decode
steps' ideal time, because its grid issues more steps and its overhead
scales with work).
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass
from typing import Callable, Mapping

from ..core.accelerators import AcceleratorModel

CALIBRATION_PATH = os.path.join(os.path.dirname(__file__), "calibration.json")

COMPUTE_MODES = ("flat", "calibrated")


def _ceil_div(a: float, b: float) -> int:
    a, b = int(a), int(b)
    if a <= 0:
        return 0
    return -(-a // max(b, 1))


@dataclass(frozen=True)
class KernelSpec:
    """Analytical shape terms of one kernel class.

    ``ops(dims)`` is the datapath work (the numerator of the ideal-cycles
    term); ``steps(dims, tile)`` the grid-step count (one issue cycle
    each — the loop-control floor). ``dims`` is the scheduler's logical
    ``(M, K, N)``; kernels that ignore an axis simply don't read it, so
    predictions stay monotone in every axis."""

    name: str
    ops: Callable[[tuple[int, int, int]], float]
    steps: Callable[[tuple[int, int, int], tuple[int, int, int]], int]


KERNELS: dict[str, KernelSpec] = {
    # A(M,K) @ B(K,N): 2·M·K·N ops over a (M/tm)·(K/tk)·(N/tn) grid
    "matmul": KernelSpec(
        "matmul",
        ops=lambda d: 2.0 * d[0] * d[1] * d[2],
        steps=lambda d, t: (_ceil_div(d[0], t[0]) * _ceil_div(d[1], t[1])
                            * _ceil_div(d[2], t[2])),
    ),
    # QKᵀ + PV with M=N=seq, K=head dim: 4·S²·D ops over a (S/tm)·(S/tn)
    # grid (K/V tiles stream per query block; head dim is not tiled)
    "flash_attention": KernelSpec(
        "flash_attention",
        ops=lambda d: 4.0 * d[0] * d[1] * d[2],
        steps=lambda d, t: _ceil_div(d[0], t[0]) * _ceil_div(d[2], t[2]),
    ),
    # blocked argmax scan, M=batch rows, N=vocab: one compare per element
    # over a (N/tn) grid (K unused)
    "sampling": KernelSpec(
        "sampling",
        ops=lambda d: float(d[0] * d[2]),
        steps=lambda d, t: _ceil_div(d[2], t[2]),
    ),
}

# launch-path tags → calibrated kernel classes: the bridge tags decode and
# prefill launches distinctly (both are GEMM-class — the per-shape terms,
# not the alias, price them differently), and unknown tags fall back flat
KERNEL_ALIASES = {
    "decode": "matmul",
    "prefill": "matmul",
    "gemm": "matmul",
    "attention": "flash_attention",
}


def canonical_kernel(kernel: str) -> str:
    return KERNEL_ALIASES.get(kernel, kernel)


@dataclass(frozen=True)
class KernelFit:
    """One kernel's calibration: the measured overhead factor plus the fit's
    provenance (wall-clock scale and quality), so a committed fit is
    auditable without re-timing."""

    kernel: str
    overhead_factor: float  # measured/ideal work-cycle ratio (c_work/c_issue)
    seconds_per_cycle: float  # wall-clock seconds one model cycle mapped to
    r2: float = 0.0  # coefficient of determination of the fit
    n_samples: int = 0  # shapes the fit saw

    def as_dict(self) -> dict:
        return {
            "overhead_factor": self.overhead_factor,
            "seconds_per_cycle": self.seconds_per_cycle,
            "r2": self.r2,
            "n_samples": self.n_samples,
        }


def fit_overhead(issues, works, seconds) -> KernelFit:
    """Fit ``t ≈ c_issue·issue + c_work·work`` (no intercept — a zero-shape
    kernel takes zero time) over measured shapes; the overhead factor is
    ``c_work / c_issue``: how many wall-clock issue-cycle-equivalents one
    ideal work cycle actually took on the measured backend.

    The regression is weighted by 1/t — it minimizes **relative** error,
    not absolute, so a 100 µs shape and a 30 ms shape constrain the fit
    equally (unweighted least squares lets the largest shape's
    cache-pressure tail dominate and overpredicts small shapes several-fold).
    Degenerate solutions (collinear predictors — a balanced GEMM tile makes
    steps ∝ ops — or noise driving a coefficient negative) are projected to
    the boundary: single-scale ``t = c·(issue + work)`` with factor 1.0.
    Interpret-mode factors can be < 1 (a CPU emulating the grid pays per
    *step*, not per datapath op); on real hardware both terms share one
    clock and the factor lands ≥ 1. Deterministic given the measurements:
    CI never re-times, it loads the committed JSON this produced."""
    issues = [float(x) for x in issues]
    works = [float(x) for x in works]
    seconds = [float(x) for x in seconds]
    n = len(seconds)
    assert n == len(issues) == len(works) and n >= 2, "need ≥ 2 shapes"
    assert all(t > 0.0 for t in seconds), "wall-clock samples must be > 0"
    # weighted normal equations: rows scaled by 1/t, target becomes 1
    x_i = [i / t for i, t in zip(issues, seconds)]
    x_w = [w / t for w, t in zip(works, seconds)]
    s_ii = sum(x * x for x in x_i)
    s_iw = sum(a * b for a, b in zip(x_i, x_w))
    s_ww = sum(x * x for x in x_w)
    b_i = sum(x_i)
    b_w = sum(x_w)
    det = s_ii * s_ww - s_iw * s_iw
    c_issue = c_work = 0.0
    if det > 1e-12 * max(s_ii * s_ww, 1e-30):
        c_issue = (b_i * s_ww - b_w * s_iw) / det
        c_work = (s_ii * b_w - s_iw * b_i) / det
    if c_issue <= 0.0 or c_work <= 0.0:
        # boundary projection: t = c·(issue + work), overhead unresolvable
        x_t = [a + b for a, b in zip(x_i, x_w)]
        denom = sum(x * x for x in x_t) or 1.0
        c_issue = c_work = max(sum(x_t) / denom, 1e-30)
    factor = c_work / c_issue
    predicted = [c_issue * i + c_work * w for i, w in zip(issues, works)]
    mean = sum(seconds) / n
    ss_tot = sum((t - mean) ** 2 for t in seconds)
    ss_res = sum((t - p) ** 2 for t, p in zip(seconds, predicted))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0.0 else 1.0
    return KernelFit(kernel="", overhead_factor=factor,
                     seconds_per_cycle=c_issue, r2=r2, n_samples=n)


def load_fits(path: str | None = None) -> dict[str, KernelFit]:
    """Load committed calibration fits (no timing, fully deterministic)."""
    with open(path or CALIBRATION_PATH) as f:
        data = json.load(f)
    fits = {}
    for name, d in data["fits"].items():
        fits[name] = KernelFit(
            kernel=name,
            overhead_factor=float(d["overhead_factor"]),
            seconds_per_cycle=float(d["seconds_per_cycle"]),
            r2=float(d.get("r2", 0.0)),
            n_samples=int(d.get("n_samples", 0)),
        )
    return fits


def save_fits(fits: Mapping[str, KernelFit], path: str,
              *, backend: str = "pallas_interpret",
              samples: Mapping[str, list] | None = None) -> None:
    """Persist fits (plus the raw timing samples, for audit) as the
    committed calibration JSON."""
    data = {
        "version": 1,
        "backend": backend,
        "fits": {name: fit.as_dict() for name, fit in fits.items()},
    }
    if samples:
        data["samples"] = {k: list(v) for k, v in samples.items()}
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


class ComputeModel:
    """Scheduler-facing compute pricing.

    * ``mode="flat"`` — delegates to ``AcceleratorModel.macro_cycles``
      verbatim: the pre-costmodel constant, bit-exact (the compat mode every
      committed BENCH number is pinned against).
    * ``mode="calibrated"`` — the analytical model above with per-kernel
      fitted overhead factors. Kernels without a fit fall back flat, so a
      partial calibration never crashes a run.
    """

    def __init__(self, mode: str = "calibrated",
                 fits: Mapping[str, KernelFit] | None = None,
                 path: str | None = None):
        assert mode in COMPUTE_MODES, mode
        self.mode = mode
        if fits is None:
            fits = load_fits(path) if mode == "calibrated" else {}
        self.fits = dict(fits)

    @classmethod
    def flat(cls) -> "ComputeModel":
        return cls(mode="flat", fits={})

    @classmethod
    def calibrated(cls, path: str | None = None) -> "ComputeModel":
        return cls(mode="calibrated", path=path)

    def fit_for(self, kernel: str) -> KernelFit | None:
        return self.fits.get(canonical_kernel(kernel))

    # -- prediction ----------------------------------------------------------

    def issue_cycles(self, kernel: str, dims, model: AcceleratorModel,
                     depth: int = 1) -> float:
        spec = KERNELS[canonical_kernel(kernel)]
        return depth * (model.launch_latency + spec.steps(dims, model.tile))

    def work_cycles(self, kernel: str, dims, model: AcceleratorModel,
                    depth: int = 1) -> float:
        spec = KERNELS[canonical_kernel(kernel)]
        return depth * spec.ops(dims) / model.p_peak

    def predict(self, kernel: str, dims, model: AcceleratorModel,
                depth: int = 1) -> float:
        """Predicted compute cycles of ``depth`` back-to-back launches of
        ``kernel`` at logical ``dims`` on ``model``'s datapath. Monotone
        nondecreasing in each of M, K, N and depth (ceil-div step counts
        and linear work terms)."""
        dims = tuple(int(x) for x in dims)
        fit = self.fit_for(kernel)
        if self.mode == "flat" or fit is None \
                or canonical_kernel(kernel) not in KERNELS:
            regs = dict(zip(model.dim_fields, dims))
            return depth * model.macro_cycles(regs)
        issue = self.issue_cycles(kernel, dims, model, depth)
        work = self.work_cycles(kernel, dims, model, depth)
        return issue + fit.overhead_factor * work

    def macro_cycles(self, model: AcceleratorModel, regs: Mapping[str, int],
                     kernel: str = "matmul") -> float:
        """Drop-in replacement for ``model.macro_cycles(regs)`` on the
        scheduler's launch path — flat mode IS that call, bit-exactly."""
        if self.mode == "flat":
            return model.macro_cycles(dict(regs))
        dims = tuple(int(regs.get(f, 0)) for f in model.dim_fields)
        return self.predict(kernel, dims, model)

    def wire_compute_ratio(self, kernel: str, dims, model: AcceleratorModel,
                           wire_cycles: float) -> float:
        """Predicted wire/compute ratio — the autotuner's decision axis
        (``engine.autotune``): > 1 means the wire cannot fully hide behind
        one launch's compute."""
        compute = self.predict(kernel, dims, model)
        return wire_cycles / compute if compute > 0.0 else math.inf


def resolve_compute_model(spec) -> "ComputeModel | None":
    """``None`` → flat legacy path (the scheduler calls the accelerator
    model directly — bit-exact); ``"flat"``/``"calibrated"`` → the named
    mode; an instance passes through."""
    if spec is None or isinstance(spec, ComputeModel):
        return spec
    assert spec in COMPUTE_MODES, spec
    return ComputeModel.flat() if spec == "flat" else ComputeModel.calibrated()
