"""Sharding-rule unit tests (divisibility-aware TP/EP/ZeRO specs)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.distributed import opt_state_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import AdamW


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 256:
        pytest.skip("production mesh needs the dry-run's 512 host devices")
    return make_production_mesh()


def _specs(mesh, arch):
    model = Model(get(arch))
    params = model.abstract_params()
    sh = param_shardings(mesh, params)
    flat, _ = jax.tree_util.tree_flatten_with_path(sh)
    out = {}
    for path, s in flat:
        key = "/".join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        out[key] = s.spec
    return out


def test_spec_shapes_divide(mesh=None):
    """Every sharded dim divides the mesh axis (checked without devices)."""
    from repro.distributed.sharding import _spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ("qwen2-0.5b", "qwen2.5-32b", "kimi-k2-1t-a32b", "rwkv6-7b"):
        model = Model(get(arch))
        params = model.abstract_params()
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for path, leaf in flat:
            names = tuple(
                p.key if isinstance(p, jax.tree_util.DictKey) else str(p)
                for p in path
            )
            spec = _spec_for_param(FakeMesh(), names, leaf.shape)
            assert len(spec) == len(leaf.shape), (names, spec, leaf.shape)
            for dim, ax in zip(leaf.shape, spec):
                if ax is not None:
                    assert dim % 16 == 0, (names, leaf.shape, spec)


def test_zero_shards_optimizer_states():
    from repro.distributed.sharding import _spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    model = Model(get("qwen2-0.5b").reduced())
    params = model.abstract_params()
    opt = jax.eval_shape(AdamW().init, params)
    # m/v/master leaves exist for every param leaf
    n_params = len(jax.tree.leaves(params))
    n_opt = len(jax.tree.leaves(opt))
    assert n_opt == 3 * n_params + 1  # master, m, v (+ step)


def test_moe_expert_dim_sharded():
    from repro.distributed.sharding import _spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # kimi: experts (61, 384, 7168, 2048) — expert dim (384) divides 16
    spec = _spec_for_param(
        FakeMesh(), ("layers", "moe", "wi"), (61, 384, 7168, 2048)
    )
    assert spec == P(None, "model", None, None)


def test_embed_vocab_sharded_when_divisible():
    from repro.distributed.sharding import _spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert _spec_for_param(FakeMesh(), ("embed",), (65536, 8192)) == P("model", None)
    # 151936 = 16 × 9496: divisible — vocab sharding applies
    assert _spec_for_param(FakeMesh(), ("embed",), (151936, 896)) == P("model", None)
    # odd vocab: falls back to d_model
    assert _spec_for_param(FakeMesh(), ("embed",), (51865, 1024)) == P(None, "model")


def test_norms_replicated():
    from repro.distributed.sharding import _spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert _spec_for_param(FakeMesh(), ("layers", "attn_norm", "w"), (24, 896)) == P(
        None, None
    )
