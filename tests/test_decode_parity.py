"""Decode-vs-forward parity: stepping token-by-token through the cache path
must reproduce the training forward's logits (the strongest correctness
check on the serving stack)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models.model import Model

ARCHS = ["qwen2-0.5b", "phi3.5-moe-42b-a6.6b", "rwkv6-7b", "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get(arch).reduced(), remat="none")
    if cfg.n_experts:
        # capacity-based MoE drops different tokens at different batch sizes
        # (a train/serve divergence inherent to the formulation); give the
        # parity test drop-free capacity so routing is identical on both paths
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init(key)

    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    full_logits, _ = jax.jit(model.forward)(params, batch)  # (B, S, V)

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    got = []
    for i in range(S):
        lg, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        got.append(lg[:, 0])
    dec_logits = jnp.stack(got, axis=1)

    a = np.asarray(full_logits, np.float32)
    b = np.asarray(dec_logits, np.float32)
    np.testing.assert_allclose(a, b, rtol=0.05, atol=0.15)
    # top-1 agreement (bf16: the associative-scan vs recurrent SSM paths sum
    # in different orders, so an occasional near-tie may flip)
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.9
