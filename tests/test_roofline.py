"""The configuration roofline model (§4) — including the paper's own
worked example numbers (§4.6: 41.49% theoretical, 26.78% effective)."""

import math

import pytest

from repro.core import roofline as rl


def test_processor_roofline_knee():
    assert rl.processor_roofline(100.0, 10.0, 5.0) == 50.0  # memory bound
    assert rl.processor_roofline(100.0, 10.0, 50.0) == 100.0  # compute bound


def test_concurrent_roofline_eq2():
    assert rl.concurrent_config_roofline(512, 1.77, 10.0) == pytest.approx(17.7)
    assert rl.concurrent_config_roofline(512, 1.77, 1e9) == 512


def test_sequential_roofline_eq3_asymptotics():
    # approaches the concurrent roofline from below, never exceeds it
    for i_oc in (1.0, 10.0, 100.0, 1e4, 1e8):
        seq = rl.sequential_config_roofline(512, 1.77, i_oc)
        conc = rl.concurrent_config_roofline(512, 1.77, i_oc)
        assert seq < conc or math.isclose(seq, conc, rel_tol=1e-6)
    assert rl.sequential_config_roofline(512, 1.77, 1e12) == pytest.approx(512, rel=1e-3)


def test_knee_point_equal_time():
    # at the knee, configuration and computation take equal time: seq = peak/2
    knee = rl.knee_point(512, 1.77)
    seq = rl.sequential_config_roofline(512, 1.77, knee)
    assert seq == pytest.approx(256, rel=1e-6)


def test_effective_bandwidth_eq4():
    bw = rl.effective_config_bandwidth(2560, t_calc=775 * 3, t_set=160 * 3)
    assert bw == pytest.approx(0.9127, rel=1e-3)


def test_roofsurface_eq5():
    # configuration can bound a perfectly balanced processor roofline
    p = rl.roofsurface(512, bw_mem=100, i_op=1e6, bw_config=1.77, i_oc=10)
    assert p == pytest.approx(17.7)


def test_gemmini_worked_example_theoretical():
    bw, i_oc, util = rl.gemmini_example_theoretical()
    assert bw == pytest.approx(16 / 9, rel=1e-6)  # ≈ 1.77 B/cycle
    assert i_oc == pytest.approx(204.8, rel=1e-3)
    # paper reports 41.49% (with a rounded I_OC); exact arithmetic gives 41.56%
    assert util == pytest.approx(0.4149, abs=0.005)


def test_gemmini_worked_example_effective():
    bw, _, util = rl.gemmini_example_effective()
    assert bw == pytest.approx(0.913, abs=0.002)
    assert util == pytest.approx(0.2678, abs=0.005)  # paper: 26.78%


def test_config_bound_predicate():
    assert rl.config_bound(512, 1.77, 10.0)
    assert not rl.config_bound(512, 1.77, 1e6)
