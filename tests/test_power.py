"""repro.power: joule attribution (the energy conservation invariant,
property-tested through preemption, shared ports, and both overlap
modes), the zero-power regression pin, joule-objective transport
planning, the energy roofline, the what-if joule axis, windowed pool
power, and the cluster power cap."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import Cluster
from repro.cluster.powercap import (
    CapReport,
    PowerCapTrigger,
    request_energy_bound,
    run_power_capped,
)
from repro.core.accelerators import REGISTRY
from repro.core.roofline import energy_roofline_point
from repro.fabric.link import LINKS
from repro.fabric.migrate import MigrationPlanner
from repro.fabric.transport import crossover_fields, plan_fields
from repro.obs import Tracer, attribute, predict_burst, write_trace
from repro.obs.diagnose import diagnose
from repro.obs.monitor import StreamMonitor
from repro.power import (
    PowerSpec,
    ZERO_ENERGY,
    attribute_energy,
    max_window_energy,
    pool_window_energy,
)
from repro.power.meter import PoolEnergySnapshot
from repro.sched import LaunchRequest, Scheduler

# ---------------------------------------------------- conservation property


def _stream(seed_reqs):
    return [LaunchRequest(t, dims, extra, accel=accel, arrival_time=at)
            for t, dims, extra, accel, at in seed_reqs]


@st.composite
def power_streams(draw):
    """Mixed-pool request streams (test_obs's generator shape): random
    arrivals, tile sizes, and write-plan sizes."""
    reqs, t = [], 0.0
    for i in range(draw(st.integers(2, 14))):
        t += float(draw(st.integers(0, 150)))
        dims = tuple(8 * draw(st.integers(1, 5)) for _ in range(3))
        nfields = draw(st.integers(0, 32))
        extra = {f"p{j}": draw(st.integers(0, 3)) * 64 + j
                 for j in range(nfields)}
        accel = draw(st.sampled_from(["opengemm", "gemmini"]))
        reqs.append((f"t{draw(st.integers(0, 2))}", dims, extra, accel, t))
    return reqs


@settings(max_examples=20, deadline=None)
@given(power_streams(), st.sampled_from(["csr", "noc", "pcie"]),
       st.sampled_from(["serialized", "overlapped"]))
def test_energy_conservation_on_every_lane(seed_reqs, link, mode):
    """The hard invariant (ISSUE 8): per lane, energy components sum to
    the independently metered lane total within 0.1% — on every link
    class and overlap mode, under the default power spec."""
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1}, link=link,
                                overlap=mode, power=PowerSpec.default())
    rep = s.run_open_loop(_stream(seed_reqs))
    er = attribute_energy(rep).check()  # raises above 1e-3
    assert er.max_residual <= 1e-3
    for lane in er.lanes.values():
        for comp, val in lane.components.items():
            assert val >= -1e-9, (lane.name, comp, val)


def test_energy_conservation_covers_shared_port():
    reqs = [LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(16)},
                          arrival_time=25.0 * i) for i in range(12)]
    cl = Cluster.uniform(2, {"opengemm": 1}, link="pcie",
                         overlap="overlapped", shared_port=True,
                         power=PowerSpec.default())
    rep = cl.run(list(reqs))
    er = attribute_energy(rep).check()
    shared = [n for n in er.lanes if n.endswith(":shared")]
    assert len(shared) == 1  # the shared wire meters once, pool-wide


def test_energy_conservation_survives_preemption():
    s = Scheduler.from_registry({"opengemm": 1}, link="noc", depth=2,
                                power=PowerSpec.default())
    big = {"A": 1, "B": 2, "C": 3, "zp": 0}
    s.dispatch(LaunchRequest("bulk", (64, 64, 64), dict(big)))  # running
    s.dispatch(LaunchRequest("bulk", (64, 64, 64), dict(big)))  # staged
    # ring full (depth=2): the priority arrival preempts the staged launch
    s.dispatch(LaunchRequest("vip", (8, 8, 8), {"A": 9}, priority=2))
    rep = s.finish()
    assert rep.preemptions == 1  # the point of the fixture
    attribute_energy(rep).check()


# ------------------------------------------------------- zero-power pin


def _cycle_view(rep):
    att = attribute(rep)
    return (rep.makespan, [r.end for r in rep.launch_log()],
            {n: lane.components for n, lane in att.lanes.items()})


def test_zero_power_spec_reproduces_cycle_reports_unchanged():
    """Attaching energy observability must not perturb a single cycle:
    a PowerSpec.zero() run is bit-identical to an unpowered one on every
    cycle-side report, and meters zero occupancy joules."""
    reqs = [LaunchRequest(f"t{i % 2}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(12)},
                          arrival_time=30.0 * i) for i in range(10)]

    def run(power):
        s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1},
                                    link="noc", overlap="overlapped",
                                    power=power)
        return s.run_open_loop(list(reqs))

    bare, zeroed = run(None), run(PowerSpec.zero())
    assert _cycle_view(bare) == _cycle_view(zeroed)

    er = attribute_energy(zeroed).check()
    for name, lane in er.lanes.items():
        if lane.kind in ("host", "compute"):
            assert lane.total == 0.0, (name, lane.total)
        else:  # wire transfer joules are LinkModel properties, not spec's
            assert lane.components.get("idle", 0.0) == 0.0
            assert lane.components.get("wake", 0.0) == 0.0


# ------------------------------------- transport objective (satellite 2)


def test_default_objective_reproduces_cycle_crossover_bit_exactly():
    """Regression pin: ``objective="cycles"`` is the default, so every
    pre-energy caller sees PR 3's burst-vs-MMIO decision unchanged."""
    for model in (REGISTRY["opengemm"], REGISTRY["gemmini"]):
        for link in (LINKS["noc"], LINKS["pcie"]):
            assert (crossover_fields(model, link)
                    == crossover_fields(model, link, objective="cycles"))
            for n in (0, 1, 2, 4, 8, 16, 64):
                a = plan_fields(n, model, link)
                b = plan_fields(n, model, link, objective="cycles")
                assert (a.mode, a.t_set, a.energy) == (b.mode, b.t_set,
                                                       b.energy)
    x = plan_fields(16, REGISTRY["opengemm"], LINKS["noc"])
    assert (x.mode, x.t_set, x.energy) == ("burst", 61.5, 85.4)


def test_joule_crossover_sits_later_than_the_cycle_one():
    """Burst DMA's descriptor setup costs joules it does not cost cycles
    (the host builds it locally), so the cheaper-mode decision differs
    between the two axes — the pinned crossover tables."""
    pins = {
        ("opengemm", "noc"): (2, 7, 4),
        ("gemmini", "noc"): (3, 9, 5),
        ("opengemm", "pcie"): (1, 2, 1),
        ("gemmini", "pcie"): (1, 3, 1),
    }
    for (mname, lname), expected in pins.items():
        got = tuple(crossover_fields(REGISTRY[mname], LINKS[lname],
                                     objective=o)
                    for o in ("cycles", "joules", "edp"))
        assert got == expected, (mname, lname, got)
        cyc, joule, edp = got
        assert cyc <= edp <= joule  # EDP interpolates the two axes


def test_objective_picks_the_cheaper_mode_per_axis():
    model, link = REGISTRY["opengemm"], LINKS["noc"]
    for n in range(1, 32):
        by_cycles = plan_fields(n, model, link, objective="cycles")
        by_joules = plan_fields(n, model, link, objective="joules")
        forced = [plan_fields(n, model, link, mode=m)
                  for m in ("mmio", "burst")]
        assert by_cycles.t_set == min(f.t_set for f in forced)
        assert by_joules.energy == min(f.energy for f in forced)
    with pytest.raises(AssertionError):
        plan_fields(4, model, link, objective="watts")


# ------------------------------------------------------- energy roofline


def test_energy_roofline_point_ridge_and_attainable():
    pt = energy_roofline_point("demo", total_ops=8192.0, config_bytes=256.0,
                               config_energy=512.0, total_energy=4096.0,
                               compute_power=0.5, p_peak=2.0)
    assert pt.peak_ops_per_joule == 4.0
    assert pt.bw_energy == 0.5  # 256 bytes / 512 pJ
    assert pt.ridge == 8.0  # peak / bw_e, in ops per config byte
    assert pt.i_oc == 32.0
    assert pt.energy_bound == "compute"
    assert pt.efficiency == 2.0
    # harmonic ceiling: 1/(1/4 + 1/(0.5*32))
    assert pt.attainable == pytest.approx(1.0 / (0.25 + 1.0 / 16.0))
    assert pt.utilization == 0.5


# -------------------------------------------------- what-if joule axis


def _joule_stream():
    """gemmini-only on noc under forced MMIO: 5-field extras keep the
    per-launch write plan at 8 fields — inside the window where burst
    DMA wins cycles but *loses* joules (descriptor setup energy)."""
    return [LaunchRequest("t0", (16, 16, 16),
                          {f"f{j}": 96 * i + j for j in range(5)},
                          accel="gemmini", arrival_time=40.0 * i)
            for i in range(10)]


def _joule_run():
    s = Scheduler.from_registry({"gemmini": 1}, link="noc",
                                overlap="serialized", transport="mmio",
                                power=PowerSpec.default())
    return s.run_open_loop(_joule_stream())


def test_whatif_prices_the_burst_counterfactual_in_joules():
    w = predict_burst(_joule_run())
    assert w is not None
    assert w.predicted_savings == pytest.approx(36.0)
    assert w.predicted_joule_savings == pytest.approx(-6.0)
    assert w.axes_disagree  # a cycle win that costs joules
    d = w.to_dict()
    assert d["axes_disagree"] is True
    assert d["predicted_joule_savings"] == pytest.approx(-6.0)


def test_doctor_flags_cycle_joule_axis_disagreement():
    d = diagnose(_joule_run())
    recs = [r for r in d.recommendations if r.axes_disagree]
    assert recs, "the burst recommendation must carry the disagreement flag"
    assert recs[0].predicted_joule_savings == pytest.approx(-6.0)
    assert any("costs joules" in n for n in d.notes)
    assert "[!] axes disagree" in d.render()


# ----------------------------------------- windowed power and snapshot


def _powered_cluster(n=12):
    reqs = [LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(10)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=20.0 * i) for i in range(n)]
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    rep = cl.run(list(reqs))
    return cl, rep


def test_snapshot_window_energy_matches_the_reference_meter():
    import random

    cl, _ = _powered_cluster()
    snap = PoolEnergySnapshot(cl.hosts)
    mk = max(h.clock for h in cl.hosts)
    rng = random.Random(7)
    for _ in range(100):
        t0 = rng.uniform(-200.0, mk)
        t1 = t0 + rng.uniform(0.0, 800.0)
        ref = pool_window_energy(cl.hosts, t0, t1)
        assert snap.window_energy(t0, t1) == pytest.approx(ref, rel=1e-9)


def test_snapshot_extend_equals_fresh_build():
    """The power cap's incremental path: extending a snapshot across
    dispatches lands on the same tracks as rebuilding from the logs."""
    reqs = [LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(10)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=20.0 * i) for i in range(12)]
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    snap = PoolEnergySnapshot(cl.hosts)
    for req in reqs:
        cl.router.route(req, now=req.arrival_time).dispatch(req)
        snap.extend()
    fresh = PoolEnergySnapshot(cl.hosts)
    mk = max(h.clock for h in cl.hosts)
    for k in range(40):
        t0 = -100.0 + k * (mk + 200.0) / 40.0
        assert (snap.window_energy(t0, t0 + 512.0)
                == pytest.approx(fresh.window_energy(t0, t0 + 512.0)))
    assert snap.max_window(512.0) == pytest.approx(fresh.max_window(512.0))


def test_max_window_energy_finds_the_brute_force_worst():
    cl, _ = _powered_cluster()
    window = 512.0
    worst, at = max_window_energy(cl.hosts, window)
    mk = max(h.clock for h in cl.hosts)
    # dense scan can only find windows at most as hot as the edge scan
    step = mk / 400.0
    dense = max(pool_window_energy(cl.hosts, i * step, i * step + window)
                for i in range(400))
    assert worst >= dense - 1e-9
    assert worst == pytest.approx(
        pool_window_energy(cl.hosts, at, at + window))


def test_next_breakpoint_always_advances_past_float_rounding():
    """Regression: an edge barely above admit − window can round back to
    exactly admit when the window is re-added — the admission loop must
    still advance or it spins forever."""
    cl, _ = _powered_cluster(n=2)
    snap = PoolEnergySnapshot(cl.hosts)
    snap.edges = [952.1]
    admit, window = 3000.1, 2048.0
    assert 952.1 + window == admit  # the trap, preserved by the pin
    assert 952.1 > admit - window
    nxt = snap.next_breakpoint(admit, window)
    assert nxt is None or nxt > admit


def test_monitor_power_draw_windows_the_canonical_signal():
    mon = StreamMonitor(window=100.0)
    mon.observe("power.energy", 50.0, 300.0, host="h0")
    mon.observe("power.energy", 90.0, 200.0, host="h1")
    assert mon.power_draw(100.0) == pytest.approx(5.0)  # 500 pJ / 100 cyc
    assert mon.power_draw(100.0, host="h0") == pytest.approx(3.0)


# ------------------------------------------------------- the power cap


def _cap_requests(n=40):
    return [LaunchRequest(f"t{i % 4}", (8, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(8)},
                          accel="opengemm" if i % 2 else "gemmini",
                          arrival_time=12.0 * i) for i in range(n)]


def test_power_cap_holds_the_budget_in_every_window():
    window = 1024.0
    probe = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                            power=PowerSpec.default())
    probe.run(_cap_requests())
    peak, _ = max_window_energy(probe.hosts, window)
    budget = 0.6 * peak / window

    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    rep, cap = run_power_capped(cl, _cap_requests(),
                                budget_power=budget, window=window)
    assert isinstance(cap, CapReport)
    assert cap.held
    assert cap.max_window_power <= budget + 1e-9
    assert cap.delayed > 0 and cap.total_delay > 0.0  # binding budget
    assert cap.p50_delay >= 0.0
    # delay is queueing latency: arrivals unchanged, so queue delay grew
    assert rep.launches == len(_cap_requests())
    d = cap.to_dict()
    assert d["held"] and d["delayed"] == cap.delayed


def test_power_cap_uncapped_budget_never_delays():
    window = 1024.0
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    rep, cap = run_power_capped(cl, _cap_requests(),
                                budget_power=1e9, window=window)
    assert cap.delayed == 0 and cap.total_delay == 0.0
    assert cap.held


def test_power_cap_rejects_infeasible_budgets():
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    bound = request_energy_bound(cl.hosts[0], _cap_requests(1)[0])
    assert bound > 0.0
    with pytest.raises(AssertionError, match="infeasible cap"):
        run_power_capped(cl, _cap_requests(),
                         budget_power=1e-6, window=1024.0)


def test_power_cap_trigger_feeds_monitor_and_sheds_when_hot():
    window = 1024.0
    probe = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                            power=PowerSpec.default())
    probe.run(_cap_requests(60))
    peak, _ = max_window_energy(probe.hosts, window)
    budget = 0.6 * peak / window

    mon = StreamMonitor(window=window)
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.default())
    trigger = PowerCapTrigger(MigrationPlanner(link="noc", policy="warm"),
                              budget_power=budget, window=window,
                              monitor=mon)
    _, cap = run_power_capped(cl, _cap_requests(60), budget_power=budget,
                              window=window, trigger=trigger)
    assert cap.held
    now = max(h.clock for h in cl.hosts)
    assert mon.power_draw(now) >= 0.0  # the canonical signal was fed
    assert mon.windowed_sum("power.energy", now, host=cl.hosts[0].id) >= 0.0


def test_zero_power_pool_rejects_the_cap_cleanly():
    """Without a power spec every window meters ~zero joules on csr-free
    links — the cap must still run (budget trivially held)."""
    cl = Cluster.uniform(2, {"opengemm": 1, "gemmini": 1}, link="noc",
                         power=PowerSpec.zero())
    _, cap = run_power_capped(cl, _cap_requests(10), budget_power=100.0,
                              window=1024.0)
    assert cap.held


# ---------------------------------------------------- trace energy block


def test_trace_embeds_conservation_checked_energy(tmp_path):
    from repro.obs.export import trace_power

    tracer = Tracer()
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1}, link="noc",
                                overlap="overlapped", tracer=tracer,
                                power=PowerSpec.default())
    rep = s.run_open_loop(_joule_stream())
    er = attribute_energy(rep).check()
    trace_power(tracer, rep)
    path = tmp_path / "trace.json"
    doc = write_trace(tracer, str(path), attribution=attribute(rep).check(),
                      metrics=rep.metrics, energy=er)
    assert doc["energy"]["max_residual"] <= 1e-3
    assert doc["energy"]["total_energy"] > 0.0
    counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
    assert counters and all(e["name"].startswith("power[")
                            for e in counters)
