"""repro.bridge: bridged-vs-standalone token parity, descriptor→field
translation, closed-loop feedback, slot-residency routing, and the
engine↔cluster config-byte accounting identity."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.bridge import (
    ClosedLoopDriver,
    TenantEngine,
    descriptor_fields,
    descriptor_nbytes,
    descriptor_request,
    padded_nbytes,
)
from repro.cluster import Cluster
from repro.configs import get
from repro.core.accelerators import REGISTRY
from repro.models.model import Model
from repro.serving import Request, ServingEngine

OPENGEMM = REGISTRY["opengemm"]
GEMMINI = REGISTRY["gemmini"]


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    decode_fn = ServingEngine.compile_decode(model)
    return model, params, decode_fn


PROMPTS = [[5, 9, 2], [7, 1], [3, 3, 3, 3]]


def _engine(small_model, **kw):
    model, params, decode_fn = small_model
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 64)
    eng = ServingEngine(model, params, decode_fn=decode_fn, **kw)
    for uid, prompt in enumerate(PROMPTS):
        eng.submit(Request(uid=uid, prompt=list(prompt), max_new_tokens=5))
    return eng


def _tokens(finished):
    return {r.uid: list(r.generated) for r in finished}


# -------------------------------------------------------- descriptor fields


def _desc(max_slots=4):
    return {
        "tokens": np.arange(max_slots, dtype=np.int32).reshape(max_slots, 1),
        "positions": np.zeros((max_slots,), np.int32),
        "live_mask": np.array([True] * (max_slots - 1) + [False]),
        "max_len": np.int32(64),
    }


def test_descriptor_fields_price_each_leaf_at_wire_size():
    desc = _desc()
    fields = descriptor_fields(desc, OPENGEMM)
    # int32 leaves on a 4-byte-field device: one field per element; the
    # 4-slot bool mask packs into exactly one field
    assert len(fields) == 4 + 4 + 1 + 1
    assert padded_nbytes(desc, OPENGEMM) == descriptor_nbytes(desc) == 4 * 10
    # 8-byte fields (gemmini) pad the 4-byte leaves
    assert padded_nbytes(desc, GEMMINI) > descriptor_nbytes(desc)


def test_leaf_changes_atomically():
    """All words of a leaf share its digest: any element change re-sends
    the whole leaf (matching the engine executor's whole-leaf comparison),
    and an identical leaf elides entirely."""
    from repro.sched import ConfigStateCache

    cache = ConfigStateCache(bytes_of=lambda n, v: OPENGEMM.bytes_per_field)
    cache.dispatch("t", descriptor_fields(_desc(), OPENGEMM))
    changed = _desc()
    changed["tokens"][2, 0] = 99  # one element of one leaf
    plan = cache.dispatch("t", descriptor_fields(changed, OPENGEMM))
    assert {n.split("#")[0] for n in plan.sent} == {"['tokens']"}
    assert len(plan.sent) == 4  # the whole tokens leaf, not one word
    again = cache.dispatch("t", descriptor_fields(changed, OPENGEMM))
    assert not again.sent


def test_descriptor_request_carries_real_fields():
    req = descriptor_request("t0", _desc(), OPENGEMM, dims=(8, 16, 64),
                             arrival_time=42.0)
    assert req.accel == "opengemm" and req.arrival_time == 42.0
    regs = req.regs_for(OPENGEMM)
    assert (regs["M"], regs["K"], regs["N"]) == (8, 16, 64)
    assert any(name.startswith("['tokens']") for name in regs)


# ------------------------------------------------------------- token parity


def test_bridged_tokens_bit_identical_to_standalone(small_model):
    """ISSUE 4 satellite: the bridge may never perturb model output — a
    cluster-bridged engine generates exactly the tokens the same engine
    produces standalone, for the same seeds and submission order."""
    standalone = _engine(small_model)
    want = _tokens(standalone.run_until_done())

    bridged = _engine(small_model)
    tenant = TenantEngine("t0", bridged, accel="opengemm")
    cluster = Cluster.uniform(2, {"opengemm": 1}, policy="affinity",
                              sticky=True, link="noc")
    ClosedLoopDriver([tenant], cluster).run()
    got = _tokens(bridged.finished)
    assert got == want

    # and the routing policy is irrelevant to output: round-robin too
    rr = _engine(small_model)
    ClosedLoopDriver(
        [TenantEngine("t0", rr, accel="opengemm")],
        Cluster.uniform(2, {"opengemm": 1}, policy="round_robin"),
    ).run()
    assert _tokens(rr.finished) == want


# -------------------------------------------------------------- closed loop


def test_closed_loop_feedback_serializes_a_tenants_steps(small_model):
    """A tenant's next step arrives exactly when its previous step
    completed: queueing delay throttles the token clock (closed loop),
    instead of piling into a percentile (open loop)."""
    eng = _engine(small_model)
    tenant = TenantEngine("t0", eng, accel="opengemm")
    cluster = Cluster.uniform(1, {"opengemm": 1}, policy="affinity",
                              sticky=True, link="noc")
    rep = ClosedLoopDriver([tenant], cluster).run()
    steps = [s for s in rep.steps if s.tenant == "t0"]
    assert len(steps) >= 5
    for prev, nxt in zip(steps, steps[1:]):
        assert nxt.arrival == prev.completion
        assert nxt.completion > nxt.arrival
    # token goodput is finite and accounted on the cluster clock
    assert rep.tokens == sum(s.tokens for s in steps) > 0
    assert rep.tokens_per_kcycle > 0.0
    assert rep.serving["t0"].p99_decode >= rep.serving["t0"].p50_decode > 0.0


def test_sticky_router_binds_decode_to_the_kv_home(small_model):
    """Slot residency is binding: every launch of a bridged tenant lands
    on the host that adopted its KV context, even with other hosts idle."""
    eng = _engine(small_model)
    tenant = TenantEngine("t0", eng, accel="opengemm")
    cluster = Cluster.uniform(3, {"opengemm": 1}, policy="affinity",
                              sticky=True)
    rep = ClosedLoopDriver([tenant], cluster).run()
    placements = rep.cluster.placements()["t0"]
    assert len(placements) == 1  # one home host, all launches
    home = next(iter(placements))
    assert cluster.router.home("t0").id == home


def test_round_robin_without_sticky_shuffles_the_tenant(small_model):
    eng = _engine(small_model)
    tenant = TenantEngine("t0", eng, accel="opengemm")
    cluster = Cluster.uniform(3, {"opengemm": 1}, policy="round_robin",
                              sticky=False)
    rep = ClosedLoopDriver([tenant], cluster).run()
    assert len(rep.cluster.placements()["t0"]) == 3  # thrashes every host


# ------------------------------------------------------- accounting parity


def test_config_bytes_match_engine_accounting(small_model):
    """The cluster device's field-granular cache and the engine executor's
    leaf-granular cache are independent implementations fed one stream:
    under sticky routing their byte accounting must agree exactly (modulo
    the documented launch-command and tile-register terms)."""
    engines = [_engine(small_model) for _ in range(2)]
    tenants = [TenantEngine(f"t{i}", e, accel="opengemm")
               for i, e in enumerate(engines)]
    cluster = Cluster.uniform(2, {"opengemm": 1}, policy="affinity",
                              sticky=True, link="noc")
    rep = ClosedLoopDriver(tenants, cluster).run()
    parity = rep.config_parity()
    assert set(parity) == {"t0", "t1"}
    for tenant, p in parity.items():
        assert p["matched"], (tenant, p)
        # elision is real: resident state kept most descriptor bytes off
        # the wire after the first step
        assert p["cluster_bytes_elided"] > 0


def test_step_timeline_shows_first_step_full_send(small_model):
    eng = _engine(small_model)
    tenant = TenantEngine("t0", eng, accel="opengemm")
    cluster = Cluster.uniform(1, {"opengemm": 1}, policy="affinity",
                              sticky=True)
    rep = ClosedLoopDriver([tenant], cluster).run()
    timeline = rep.step_timeline("t0")
    assert len(timeline) == rep.serving["t0"].steps
    (_, first_sent, _), (_, later_sent, later_elided) = timeline[0], timeline[-1]
    assert first_sent > later_sent  # cold full send vs steady-state delta
    assert later_elided > 0  # invariant config rode device-resident state
    # the cluster-wide launch timeline carries the same traffic, unfolded
    launches = rep.cluster.descriptor_timeline("t0")
    assert sum(b for _, b, _ in launches) == sum(b for _, b, _ in timeline)


def test_serving_roofline_points_are_config_bound_here(small_model):
    """Tiny decode tiles against per-step descriptor traffic sit left of
    the knee: the bridged serving points land configuration-bound, on the
    same axes as every other roofline point in the repo."""
    eng = _engine(small_model)
    tenant = TenantEngine("t0", eng, accel="opengemm")
    cluster = Cluster.uniform(1, {"opengemm": 1}, policy="affinity",
                              sticky=True, link="noc")
    rep = ClosedLoopDriver([tenant], cluster).run()
    (pt,) = rep.serving_roofline()
    assert pt.name == "serve[t0]"
    assert pt.i_oc > 0 and pt.performance > 0
    assert pt.bound == "configuration"


def test_overlapped_cluster_raises_token_goodput_bit_identically(small_model):
    """ISSUE 5: runtime config overlap threads through closed-loop decode —
    on an overlapped PCIe cluster each descriptor's burst DMA streams
    behind the previous launch's compute, shortening the feedback edge, so
    tokens/kcycle rises while the generated tokens (and the engine↔cluster
    byte-accounting parity) stay exactly the same."""
    def run(overlap):
        engines = [_engine(small_model) for _ in range(2)]
        tenants = [TenantEngine(f"t{i}", e, accel="opengemm")
                   for i, e in enumerate(engines)]
        cluster = Cluster.uniform(1, {"opengemm": 1}, policy="affinity",
                                  sticky=True, link="pcie", overlap=overlap)
        rep = ClosedLoopDriver(tenants, cluster).run()
        tokens = {t.tenant: _tokens(t.engine.finished) for t in tenants}
        return rep, tokens

    ser, ser_tokens = run("serialized")
    ov, ov_tokens = run("overlapped")
    assert ov_tokens == ser_tokens  # timing moved, semantics did not
    assert ov.cluster.makespan < ser.cluster.makespan
    assert ov.tokens_per_kcycle > ser.tokens_per_kcycle
    # the win is exactly the hidden T_set: cycles streamed behind compute
    assert ser.overlap_summary()["hidden_config_cycles"] == 0.0
    assert ov.overlap_summary()["hidden_config_cycles"] > 0.0
    # byte accounting is untouched by overlap — parity still exact
    assert all(p["matched"] for p in ov.config_parity().values())
    assert ov.cluster.bytes_sent == ser.cluster.bytes_sent
