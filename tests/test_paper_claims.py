"""Reproduction of the paper's headline evaluation claims (§6, Appendix A.6):

* OpenGeMM (concurrent configuration): ≈2× geomean, up to ≈2.7×.
* Gemmini (sequential configuration, WS flow): ≈10.5% geomean.
* Roofline placement (§4.7/Fig. 12): dedup raises I_OC (rightward) and
  performance; overlap raises performance at unchanged I_OC.
"""

import pytest

from repro.core import accelerators, evaluate_levels, geomean, matmul_driver, speedup

OPENGEMM = {"opengemm": accelerators.opengemm_like()}
GEMMINI = {"gemmini": accelerators.gemmini_like()}
SIZES = [16, 32, 64, 128, 256]


@pytest.fixture(scope="module")
def opengemm_results():
    return {
        k: evaluate_levels(lambda k=k: matmul_driver.opengemm_tiled_matmul(k), OPENGEMM)
        for k in SIZES
    }


def test_opengemm_geomean_speedup_about_2x(opengemm_results):
    sp = [speedup(r, "both") for r in opengemm_results.values()]
    g = geomean(sp)
    assert 1.7 <= g <= 2.6, f"geomean {g} outside the paper's ≈2× band"
    assert max(sp) >= 2.2  # paper: up to 2.71×


def test_opengemm_each_optimization_helps(opengemm_results):
    for k, r in opengemm_results.items():
        assert speedup(r, "dedup") > 1.0, f"dedup regression at K={k}"
        assert speedup(r, "both") >= speedup(r, "dedup") * 0.99
        assert speedup(r, "both") >= speedup(r, "overlap") * 0.99


def test_opengemm_invocation_logs_identical(opengemm_results):
    # evaluate_levels asserts this internally; re-assert explicitly for K=64
    r = opengemm_results[64]
    logs = {lvl: res.trace.log_signature() for lvl, res in r.items()}
    base = logs.pop("baseline")
    for lvl, log in logs.items():
        assert log == base, lvl


def test_gemmini_geomean_about_10pct():
    sp = []
    for k in [16, 32, 64, 128, 256, 512]:
        r = evaluate_levels(
            lambda k=k: matmul_driver.gemmini_tiled_matmul(k), GEMMINI,
            levels=("baseline", "dedup"),
        )
        sp.append(speedup(r, "dedup"))
    g = geomean(sp)
    assert 1.04 <= g <= 1.20, f"geomean {g} outside the paper's ≈10.5% band"


def test_roofline_placement_moves_as_predicted():
    """§4.7: dedup moves points up AND right; overlap moves points up only."""
    r = evaluate_levels(lambda: matmul_driver.opengemm_tiled_matmul(64), OPENGEMM)
    base, ded, ovl = r["baseline"].point, r["dedup"].point, r["overlap"].point
    assert ded.i_oc > base.i_oc  # rightward: fewer config bytes
    assert ded.performance > base.performance  # upward
    # overlap: ~unchanged I_OC (±15%: the software pipeline stages one extra
    # setup in the prologue and after the final launch, Fig. 9) — far from
    # dedup's rightward jump
    assert abs(ovl.i_oc - base.i_oc) / base.i_oc < 0.15
    assert ovl.i_oc < ded.i_oc * 0.5
    assert ovl.performance > base.performance  # upward only


def test_configuration_bound_region_transition():
    """Fig. 12: at size 128 dedup pushes OpenGeMM out of the config-bound
    region (the paper calls this out explicitly)."""
    r = evaluate_levels(lambda: matmul_driver.opengemm_tiled_matmul(128), OPENGEMM)
    assert r["baseline"].point.bound == "configuration"
    assert r["dedup"].point.i_oc > r["baseline"].point.i_oc * 1.5


def test_gemmini_sequential_never_exceeds_concurrent_roofline():
    r = evaluate_levels(
        lambda: matmul_driver.gemmini_tiled_matmul(128), GEMMINI,
        levels=("baseline", "dedup"),
    )
    for res in r.values():
        p = res.point
        assert p.performance <= p.attainable_concurrent * 1.01
