"""repro.sched: state-cache elision/eviction, tenant isolation, affinity
placement, sequential-vs-concurrent queue timelines, telemetry exports, and
the cached-never-sends-more property."""

from dataclasses import replace

from hypothesis import given, settings, strategies as st

from repro.core import accelerators, matmul_driver, timeline
from repro.core.interp import run as interp_run
from repro.core.passes import baseline
from repro.sched import (
    AdmissionQueue,
    ConfigStateCache,
    LaunchQueue,
    LaunchRequest,
    Scheduler,
    requests_from_trace,
)

SEQ = accelerators.gemmini_like()
CONC = accelerators.opengemm_like()


def _fields(**kw):
    base = {"M": 8, "K": 8, "N": 8, "A": 0x1000, "B": 0x2000}
    base.update(kw)
    return base


# ----------------------------------------------------------- state cache


def test_identical_redispatch_elides_every_field():
    cache = ConfigStateCache()
    first = cache.dispatch("t0", _fields())
    again = cache.dispatch("t0", _fields())
    assert len(first.sent) == 5 and first.bytes_elided == 0
    assert len(again.sent) == 0 and again.bytes_sent == 0
    assert again.bytes_elided == first.bytes_sent


def test_partial_change_sends_only_the_delta():
    cache = ConfigStateCache()
    cache.dispatch("t0", _fields())
    plan = cache.dispatch("t0", _fields(A=0x1040))  # one address advances
    assert set(plan.sent) == {"A"}
    assert set(plan.elided) == {"M", "K", "N", "B"}


def test_lru_eviction_forces_full_resend():
    cache = ConfigStateCache(max_contexts=1)
    cache.dispatch("t0", _fields())
    cache.dispatch("t1", _fields())  # evicts t0's context
    plan = cache.dispatch("t0", _fields())
    assert len(plan.sent) == 5 and plan.bytes_elided == 0
    assert cache.stats.evictions == 2
    assert not plan.context_hit


def test_tenant_contexts_are_isolated():
    """Same register values from another tenant never justify elision: each
    tenant's context is private (no cross-tenant information flow)."""
    cache = ConfigStateCache(max_contexts=4)
    cache.dispatch("t0", _fields())
    plan = cache.dispatch("t1", _fields())  # bit-identical fields, new tenant
    assert len(plan.sent) == 5 and plan.bytes_elided == 0


def test_invalidate_clobbers_cached_state():
    cache = ConfigStateCache()
    cache.dispatch("t0", _fields())
    cache.invalidate("t0")  # runtime effects="all"
    assert len(cache.dispatch("t0", _fields()).sent) == 5


# ----------------------------------------------------------------- queue


def test_sequential_queue_stalls_host_until_retirement():
    q = LaunchQueue(SEQ, depth=4)  # depth ignored for sequential devices
    t = q.submit(100.0, duration=50.0)
    assert t.start == 100.0 and t.end == 150.0
    assert t.host_after == 150.0 and t.stall == 50.0


def test_concurrent_queue_stages_up_to_depth():
    q = LaunchQueue(CONC, depth=2)
    t1 = q.submit(0.0, duration=100.0)
    assert t1.host_after == 0.0 and t1.stall == 0.0  # staged, host free
    t2 = q.submit(10.0, duration=100.0)
    assert t2.host_after == 10.0 and t2.start == 100.0  # queued behind t1
    t3 = q.submit(20.0, duration=100.0)  # ring full: waits for t1
    assert t3.host_after == 100.0 and t3.stall == 80.0
    assert q.drain(t3.host_after) == 300.0


def test_admission_delay_probe_is_side_effect_free():
    """Placement scoring probes candidate queues with hypothetical future
    timestamps; that must never retire real in-flight launches."""
    q = LaunchQueue(CONC, depth=1)
    q.submit(0.0, duration=100.0)  # retires at t=100
    assert q.admission_delay(110.0) == 0.0  # hypothetical probe past t=100
    t = q.submit(50.0, duration=10.0)  # real dispatch: ring still full
    assert t.host_after == 100.0 and t.stall == 50.0


def test_deeper_staging_reduces_host_stall():
    def total_stall(depth):
        q = LaunchQueue(CONC, depth=depth)
        host = stall = 0.0
        for _ in range(8):
            t = q.submit(host, duration=64.0)
            host, stall = t.host_after + 4.0, stall + t.stall
        return stall

    assert total_stall(4) < total_stall(1)


def test_sequential_vs_concurrent_timelines():
    """The same stream makespan-dominates on a sequential device: config of
    launch i+1 cannot overlap macro-op i (§2.2 vs §6.2)."""
    reqs = [
        LaunchRequest("t0", (16, 16, 16), {"A": 0x1000 + 64 * i})
        for i in range(8)
    ]

    def makespan(model):
        s = Scheduler({"dev": model}, depth=2)
        return s.run([LaunchRequest(r.tenant, r.dims, dict(r.extra)) for r in reqs]).makespan

    seq = makespan(accelerators.AcceleratorModel(
        name="seq", p_peak=512.0, concurrent=False, host_cpi=3.0,
        bytes_per_field=8, fields_per_write=2, instrs_per_write=3))
    conc = makespan(accelerators.AcceleratorModel(
        name="conc", p_peak=512.0, concurrent=True, host_cpi=3.0,
        bytes_per_field=8, fields_per_write=2, instrs_per_write=3))
    assert conc < seq


# ------------------------------------------------------------- scheduler


def _pinned_streams(n=12):
    reqs = []
    for i in range(n):
        for t, base in (("t0", 0x1000), ("t1", 0x90000)):
            reqs.append(LaunchRequest(t, (16, 16, 16),
                                      {"A": base + 64 * i, "B": base + 0x8000}))
    return reqs


def test_affinity_pins_tenants_to_their_devices():
    s = Scheduler.from_registry({"opengemm": 2}, policy="affinity")
    rep = s.run(_pinned_streams())
    placements = rep.placements
    # each tenant lands wholly on one device, and not the same one
    homes = {t: max(p, key=p.get) for t, p in placements.items()}
    assert all(len(p) == 1 for p in placements.values())
    assert homes["t0"] != homes["t1"]


def test_affinity_beats_round_robin_on_config_traffic():
    def bursty(n=12):
        # 2:1 bursts misalign with the round-robin cycle, so round-robin
        # keeps moving tenants between devices
        reqs = []
        for i in range(n):
            reqs.append(LaunchRequest("t0", (16, 16, 16), {"A": 0x1000 + 64 * i}))
            reqs.append(LaunchRequest("t0", (16, 16, 16), {"A": 0x1040 + 64 * i}))
            reqs.append(LaunchRequest("t1", (16, 16, 16), {"A": 0x90000 + 64 * i}))
        return reqs

    affine = Scheduler.from_registry({"opengemm": 2}, policy="affinity",
                                     max_contexts=1)
    rr = Scheduler.from_registry({"opengemm": 2}, policy="round_robin",
                                 max_contexts=1)
    a = affine.run(bursty())
    b = rr.run(bursty())
    # round-robin migrates tenants between devices, thrashing the
    # single-context caches; affinity keeps each tenant on its home device
    assert a.bytes_sent < b.bytes_sent
    assert a.hit_rate() > b.hit_rate()


def test_kind_restricted_requests_only_use_that_kind():
    s = Scheduler.from_registry({"gemmini": 1, "opengemm": 1})
    reqs = [LaunchRequest("t0", (8, 8, 8), accel="gemmini") for _ in range(3)]
    rep = s.run(reqs)
    assert rep.devices["gemmini:0"].launches == 3
    assert rep.devices["opengemm:0"].launches == 0


def test_scheduler_invalidate_forces_resend():
    s = Scheduler.from_registry({"opengemm": 1})
    s.dispatch(LaunchRequest("t0", (8, 8, 8), {"A": 1}))
    s.invalidate()
    s.dispatch(LaunchRequest("t0", (8, 8, 8), {"A": 1}))
    rep = s.finish()
    assert rep.bytes_elided == 0  # second dispatch re-sent everything


# ------------------------------------------------------------- telemetry


def test_telemetry_traces_render_and_share_the_time_axis():
    s = Scheduler.from_registry({"gemmini": 1, "opengemm": 1})
    reqs = [LaunchRequest(f"t{i % 2}", (16, 16, 16), {"A": 64 * i},
                          accel=("gemmini" if i % 2 else "opengemm"))
            for i in range(8)]
    rep = s.run(reqs)
    traces = rep.traces()
    assert all(t.total_cycles == rep.makespan for t in traces.values())
    text = timeline.compare(traces, width=40)
    assert len(text.splitlines()) == 2 and "accel busy" in text


def test_roofline_points_reflect_elision():
    def i_oc(cache_enabled):
        s = Scheduler.from_registry({"opengemm": 1}, cache_enabled=cache_enabled)
        rep = s.run([LaunchRequest("t0", (16, 16, 16), {"A": 64 * i})
                     for i in range(10)])
        (pt,) = rep.roofline_points()
        assert pt.p_peak == CONC.p_peak and pt.bw_config == CONC.bw_config
        return pt.i_oc

    # elision sends fewer bytes for identical ops: I_OC moves right (Fig. 12)
    assert i_oc(True) > i_oc(False)


def test_compiled_program_replays_through_scheduler():
    module = matmul_driver.opengemm_tiled_matmul(32)
    baseline(module)
    trace = interp_run(module, {"gemmini": SEQ, "opengemm": CONC})
    reqs = requests_from_trace(trace, "tenant")
    assert len(reqs) == len(trace.invocations) > 0
    rep = Scheduler.from_registry({"opengemm": 1}).run(reqs)
    assert rep.devices["opengemm:0"].total_ops == trace.total_ops
    assert rep.elision_ratio > 0.5  # dims/strides/zero-points are static


def test_scheduled_executor_elides_static_descriptor_fields():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.dispatch import ScheduledExecutor

    @jax.jit
    def device_fn(state, args):
        return state + args["bias"]

    def host_prep(step):
        return {"bias": jnp.float32(0.5), "layout": np.arange(8, dtype=np.int32),
                "pos": np.int32(step)}

    _, rep = ScheduledExecutor(device_fn, host_prep, depth=2).run(
        jnp.zeros((4,)), 6
    )
    assert rep.steps == 6
    # bias/layout are static after the first step; pos changes every step
    assert rep.bytes_elided_per_step > 0
    assert 0 < rep.bytes_per_step < rep.bytes_elided_per_step


# ------------------------------------------------------- edge cases (ISSUE 2)


def test_sequential_fallback_under_deep_burst():
    """depth>1 never lets a sequential device stage: a burst of requests
    serializes completely, every launch stalling the host to retirement."""
    s = Scheduler({"g": SEQ}, depth=4)
    assert s.devices[0].queue.depth == 1  # forced down for sequential devices
    reqs = [LaunchRequest("t0", (8, 8, 8), {"A": 64 * i}) for i in range(6)]
    rep = s.run(reqs)
    dev = rep.devices["g"]
    recs = rep.launch_log()
    # no overlap: each launch starts at or after the previous retirement
    for a, b in zip(recs, recs[1:]):
        assert b.start >= a.end
    # the host was captive for every macro-op
    assert dev.stall_cycles >= sum(r.end - r.start for r in recs)


def test_lru_eviction_under_tenant_churn():
    """More tenants than context slots, round-robin re-admission: every
    dispatch is a miss and the cache degenerates to full re-sends."""
    cache = ConfigStateCache(max_contexts=2)
    for round_ in range(4):
        for t in ("t0", "t1", "t2"):  # 3 tenants, 2 slots: LRU always evicts
            plan = cache.dispatch(t, _fields())
            assert len(plan.sent) == 5 and not plan.bytes_elided
    assert cache.stats.hits == 0 and cache.stats.misses == 12
    assert cache.stats.evictions == 10  # every admission after the first two


def test_arrival_time_idles_the_host_and_sets_queue_delay():
    s = Scheduler.from_registry({"opengemm": 1})
    rep = s.run_open_loop([
        LaunchRequest("t0", (8, 8, 8), {"A": 1}, arrival_time=500.0),
        LaunchRequest("t0", (8, 8, 8), {"A": 2}, arrival_time=1_000.0),
    ])
    (a, b) = rep.launch_log()
    assert a.arrival == 500.0 and a.issue == 500.0  # host idled to arrival
    assert a.queue_delay >= 0.0 and b.latency > 0.0
    assert rep.makespan > 1_000.0


def test_open_loop_admits_in_arrival_order():
    s = Scheduler.from_registry({"opengemm": 1})
    reqs = [LaunchRequest("t0", (8, 8, 8), {"A": i},
                          arrival_time=float(1_000 - i))
            for i in range(4)]
    rep = s.run_open_loop(reqs)
    arrivals = [r.arrival for r in rep.launch_log()]
    assert arrivals == sorted(arrivals)


# ------------------------------------------------------------- preemption


def test_queue_preempt_tail_cancels_only_unstarted_lower_priority():
    q = LaunchQueue(CONC, depth=2)
    q.submit(0.0, duration=100.0, priority=0, token="a")  # running by t=10
    t2 = q.submit(10.0, duration=100.0, priority=0, token="b")  # staged
    assert t2.start == 100.0
    # "a" already started at host=10: only the tail "b" is preemptible
    victim = q.preempt_tail(10.0, priority=1)
    assert victim is not None and victim.token == "b"
    assert q.outstanding == 1 and q.device_free == 100.0
    # equal priority never preempts
    q.submit(20.0, duration=50.0, priority=1, token="c")
    assert q.preempt_tail(20.0, priority=1) is None


def test_high_priority_request_preempts_staged_launch():
    s = Scheduler.from_registry({"opengemm": 1}, depth=2)
    big = {"A": 1, "B": 2, "C": 3, "zp": 0}
    s.dispatch(LaunchRequest("bulk", (64, 64, 64), dict(big)))  # running
    s.dispatch(LaunchRequest("bulk", (64, 64, 64), dict(big)))  # staged
    # ring full (depth=2): a priority arrival would stall; instead it preempts
    s.dispatch(LaunchRequest("vip", (8, 8, 8), {"A": 9}, priority=2))
    rep = s.finish()
    assert rep.preemptions == 1
    # the victim was re-dispatched, so no launch was lost
    assert sum(d.launches for d in rep.devices.values()) == 3
    vip = [r for r in rep.launch_log() if r.tenant == "vip"]
    bulk = [r for r in rep.launch_log() if r.tenant == "bulk"]
    # vip starts before the re-dispatched bulk launch retires
    assert vip[0].start < max(b.end for b in bulk)


def test_priority_never_preempts_started_work():
    s = Scheduler.from_registry({"opengemm": 1}, depth=2)
    s.dispatch(LaunchRequest("bulk", (64, 64, 64), {"A": 1}))
    # ring not full: priority arrival just stages normally, nothing cancelled
    s.dispatch(LaunchRequest("vip", (8, 8, 8), {"A": 9}, priority=5))
    rep = s.finish()
    assert rep.preemptions == 0


def test_scheduled_executor_incremental_launch_api():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.dispatch import ScheduledExecutor

    @jax.jit
    def device_fn(state, args):
        return state + args["bias"]

    ex = ScheduledExecutor(device_fn, depth=2)
    state = jnp.zeros((4,))
    for step in range(5):
        state = ex.launch(state, {"bias": jnp.float32(1.0),
                                  "pos": np.int32(step)})
    ex.drain()
    rep = ex.report(wall_s=1.0)
    assert ex.launches == rep.steps == 5
    assert rep.bytes_elided_per_step > 0  # bias static after first launch
    np.testing.assert_allclose(np.asarray(state), 5.0)


# --------------------------------------------------------- EDF admission


def test_admission_queue_arrival_mode_matches_sorted_order():
    reqs = [LaunchRequest("t", (8, 8, 8), {"A": i}, arrival_time=float(9 - i))
            for i in range(4)]
    q = AdmissionQueue(reqs, mode="arrival")
    popped = [q.pop(0.0) for _ in range(4)]
    assert [r.arrival_time for r in popped] == [6.0, 7.0, 8.0, 9.0]


def test_edf_reorders_only_the_arrived_backlog():
    """A tight deadline overtakes looser work it arrived behind — but EDF
    never dispatches the future: an early-deadline request that has not
    arrived yet cannot jump a request being popped now."""
    loose = LaunchRequest("loose", (8, 8, 8), arrival_time=0.0, deadline=9_000.0)
    tight = LaunchRequest("tight", (8, 8, 8), arrival_time=5.0, deadline=100.0)
    future = LaunchRequest("early", (8, 8, 8), arrival_time=500.0, deadline=50.0)
    q = AdmissionQueue([loose, tight, future], mode="edf")
    # host clock 10: loose and tight have arrived; tight's deadline wins
    assert q.pop(10.0).tenant == "tight"
    assert q.pop(10.0).tenant == "loose"
    assert q.pop(10.0).tenant == "early"  # admitted once the clock reaches it


def test_edf_without_deadlines_falls_back_to_priority_order():
    a = LaunchRequest("a", (8, 8, 8), arrival_time=0.0, priority=0)
    b = LaunchRequest("b", (8, 8, 8), arrival_time=1.0, priority=3)
    q = AdmissionQueue([a, b], mode="edf")
    assert q.pop(10.0).tenant == "b"  # both arrived, higher class first


def test_edf_mixed_deadline_and_best_effort_queues():
    """ISSUE 4 satellite: finite-deadline work overtakes the deadline-free
    backlog it arrived behind, while the best-effort requests keep their
    own arrival order among themselves (deadline=None sorts last)."""
    best_effort = [LaunchRequest(f"b{i}", (8, 8, 8), arrival_time=float(i))
                   for i in range(3)]
    tight = LaunchRequest("d", (8, 8, 8), arrival_time=2.5, deadline=100.0)
    q = AdmissionQueue([*best_effort, tight], mode="edf")
    order = [q.pop(10.0).tenant for _ in range(4)]
    assert order == ["d", "b0", "b1", "b2"]


def test_edf_deadline_ties_break_by_arrival_order():
    """Equal deadlines are ordered by arrival, deterministically — not by
    tenant name (the later-named tenant arriving earlier still wins)."""
    early = LaunchRequest("zz", (8, 8, 8), arrival_time=0.0, deadline=500.0)
    late = LaunchRequest("aa", (8, 8, 8), arrival_time=5.0, deadline=500.0)
    q = AdmissionQueue([early, late], mode="edf")
    assert [q.pop(20.0).tenant, q.pop(20.0).tenant] == ["zz", "aa"]
    # a full tie (same arrival too) falls back to tenant order — still
    # deterministic across runs
    a = LaunchRequest("aa", (8, 8, 8), arrival_time=0.0, deadline=500.0)
    z = LaunchRequest("zz", (8, 8, 8), arrival_time=0.0, deadline=500.0)
    q = AdmissionQueue([z, a], mode="edf")
    assert [q.pop(20.0).tenant, q.pop(20.0).tenant] == ["aa", "zz"]


def test_preemption_counters_consistent_after_edf_reordering():
    """EDF admission composes with priority preemption: after a reordered
    drain with a preempting arrival, every request still retires exactly
    once, the preemption is counted once, and the wasted config cycles are
    exposed — the counters stay mutually consistent."""
    big = 64 * 8  # long macro-ops so the staging ring is still full
    reqs = [
        LaunchRequest("bulk0", (big, 8, 8), accel="opengemm",
                      arrival_time=0.0, deadline=90_000.0),
        LaunchRequest("bulk1", (big, 8, 8), accel="opengemm",
                      arrival_time=1.0, deadline=80_000.0),
        LaunchRequest("bulk2", (big, 8, 8), accel="opengemm",
                      arrival_time=2.0, deadline=70_000.0),
        # arrives once the bulk burst is already staged, with the tightest
        # deadline AND a preempting priority: EDF pops it ahead of any
        # still-queued work, and it cancels the newest staged-not-started
        # bulk launch to take its ring slot
        LaunchRequest("vip", (8, 8, 8), accel="opengemm",
                      arrival_time=50.0, priority=2, deadline=500.0),
    ]
    s = Scheduler.from_registry({"opengemm": 1}, depth=2)
    rep = s.run_open_loop(list(reqs), order="edf")
    dev = rep.devices["opengemm:0"]
    assert dev.preemptions == 1
    assert dev.preempted_config_cycles > 0.0
    # the victim re-entered placement: every request retired exactly once
    assert dev.launches == len(reqs)
    assert len(rep.launch_log()) == len(reqs)
    by_tenant = {r.tenant for r in rep.launch_log()}
    assert by_tenant == {"bulk0", "bulk1", "bulk2", "vip"}
    # deadline accounting saw all four deadline-carrying launches
    assert rep.deadline_launches() == len(reqs)


def test_edf_lowers_deadline_misses_under_bursty_traffic():
    """The ISSUE's satellite acceptance: on a bursty open-loop stream with
    mixed slack classes, EDF admission strictly lowers deadline misses vs.
    the priority-only (arrival) order at identical work."""
    from repro.cluster import TenantProfile, generate

    profiles = [
        TenantProfile("tight", dims=(8, 16, 16), accel="opengemm", weight=1.0),
        TenantProfile("loose", dims=(8, 16, 16), accel="opengemm", weight=2.0),
    ]
    slack = {"tight": 400.0, "loose": 6_000.0}
    reqs = generate(profiles, rate=1 / 12, horizon=40_000, process="bursty",
                    seed=5)
    reqs = [replace(r, deadline=r.arrival_time + slack[r.tenant]) for r in reqs]

    def misses(order):
        s = Scheduler.from_registry({"opengemm": 1})
        rep = s.run_open_loop(list(reqs), order=order)
        assert rep.deadline_launches() == len(reqs)
        assert sum(d.launches for d in rep.devices.values()) == len(reqs)
        return rep.deadline_misses()

    fifo, edf = misses("arrival"), misses("edf")
    assert edf < fifo, (edf, fifo)


# -------------------------------------------------- property: never worse


@st.composite
def request_streams(draw):
    n_tenants = draw(st.integers(1, 3))
    reqs = []
    for _ in range(draw(st.integers(1, 24))):
        t = draw(st.integers(0, n_tenants - 1))
        dims = tuple(8 * draw(st.integers(1, 3)) for _ in range(3))
        extra = {}
        for name in draw(st.lists(st.sampled_from(["A", "B", "C", "zp"]),
                                  min_size=0, max_size=4, unique=True)):
            extra[name] = draw(st.integers(0, 3)) * 64
        kind = draw(st.sampled_from(["gemmini", "opengemm", None]))
        reqs.append(LaunchRequest(f"t{t}", dims, extra, accel=kind))
    return reqs


@settings(max_examples=40, deadline=None)
@given(request_streams(), st.integers(1, 3), st.integers(1, 4))
def test_cached_dispatch_never_sends_more_bytes(reqs, max_contexts, depth):
    """For any stream, placement policy held fixed, enabling the state cache
    never increases the config bytes crossing the host→device boundary."""
    def bytes_sent(cache_enabled):
        s = Scheduler.from_registry(
            {"gemmini": 1, "opengemm": 1}, policy="round_robin",
            cache_enabled=cache_enabled, max_contexts=max_contexts, depth=depth,
        )
        return s.run(list(reqs)).bytes_sent

    assert bytes_sent(True) <= bytes_sent(False)


# ------------------------------- property: the cache never invents warmth


@st.composite
def descriptor_sequences(draw):
    """Random multi-tenant descriptor streams: few tenants, few field
    names, tiny value domains — maximal collision pressure on the
    context-LRU and the per-field comparison."""
    seq = []
    for _ in range(draw(st.integers(1, 30))):
        tenant = f"t{draw(st.integers(0, 3))}"
        fields = {
            f"r{j}": draw(st.integers(0, 2))
            for j in range(draw(st.integers(1, 5)))
        }
        seq.append((tenant, fields))
    return seq


@settings(max_examples=60, deadline=None)
@given(descriptor_sequences(), st.integers(1, 3))
def test_elided_bytes_never_exceed_previously_sent(seq, max_contexts):
    """ISSUE 4 satellite: device-resident state is only ever state the
    host actually wrote — no dispatch may report more elided bytes than
    this tenant has cumulatively sent before it (the cache cannot invent
    warmth, across any interleaving or eviction pattern)."""
    cache = ConfigStateCache(max_contexts=max_contexts)
    sent_before: dict[str, int] = {}
    for tenant, fields in seq:
        plan = cache.dispatch(tenant, fields)
        assert plan.bytes_elided <= sent_before.get(tenant, 0), (
            tenant, plan, sent_before)
        sent_before[tenant] = sent_before.get(tenant, 0) + plan.bytes_sent


@settings(max_examples=60, deadline=None)
@given(descriptor_sequences(), st.integers(1, 2))
def test_eviction_always_forces_full_resend(seq, max_contexts):
    """ISSUE 4 satellite: a tenant whose context is not resident (first
    dispatch, or LRU-evicted since its last) always pays a full re-send —
    zero elision, every field on the wire — and is resident afterwards."""
    cache = ConfigStateCache(max_contexts=max_contexts)
    for tenant, fields in seq:
        resident = tenant in cache.tenants()
        plan = cache.dispatch(tenant, fields)
        assert plan.context_hit == resident
        if not resident:
            assert plan.bytes_elided == 0
            assert set(plan.sent) == set(fields)
        assert tenant in cache.tenants()  # dispatch installs the context
