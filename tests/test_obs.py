"""repro.obs: metrics-registry semantics, tracer neutrality, cycle
attribution (the conservation invariant, property-tested), the exposed-
config reproduction pin, and the golden chrome-trace schema."""

import dataclasses
import json

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import Cluster, Host, percentile as cluster_percentile
from repro.obs import (
    MetricsRegistry,
    Tracer,
    attribute,
    chrome_trace,
    percentile,
    validate_trace,
    write_trace,
)
from repro.sched import LaunchRequest, Scheduler

# ------------------------------------------------------------- metrics


def test_counter_gauge_histogram_semantics():
    m = MetricsRegistry()
    m.counter("c", device="d0").add(3.0)
    m.counter("c", device="d0").add(2.0)
    m.counter("c", device="d1").inc()
    assert m.counter("c", device="d0").value == 5.0
    assert m.total("c") == 6.0
    assert m.total("c", device="d1") == 1.0

    m.gauge("g").set(7.0)
    m.gauge("g").set(4.0)  # last write wins
    assert m.gauge("g").value == 4.0

    h = m.histogram("h", tenant="t0")
    h.extend([1.0, 2.0, 3.0, 4.0])
    assert h.count == 4 and h.mean == 2.5
    assert m.samples("h") == [1.0, 2.0, 3.0, 4.0]


def test_counter_rollback_accepts_negative_deltas():
    m = MetricsRegistry()
    c = m.counter("sched.busy_cycles", device="d0")
    c.add(100.0)
    c.add(-40.0)  # preemption rollback is a first-class event
    assert c.value == 60.0


def test_label_sets_are_order_insensitive_and_kind_checked():
    m = MetricsRegistry()
    a = m.counter("x", host="h0", device="d0")
    b = m.counter("x", device="d0", host="h0")
    assert a is b
    with pytest.raises(AssertionError):
        m.gauge("x", host="h0", device="d0")


def test_absorb_relabels_and_folds():
    child = MetricsRegistry()
    child.counter("n", device="d0").add(2.0)
    child.gauge("mk").set(9.0)
    child.histogram("lat").extend([1.0, 3.0])
    parent = MetricsRegistry()
    parent.counter("n", device="d0", host="h1").add(1.0)
    parent.absorb(child, host="h0")
    assert parent.total("n") == 3.0
    assert parent.total("n", host="h0") == 2.0
    assert parent.gauge("mk", host="h0").value == 9.0
    assert parent.samples("lat", host="h0") == [1.0, 3.0]
    rows = parent.collect()
    assert all(set(r) >= {"name", "kind", "labels"} for r in rows)


def test_histogram_percentile_edges():
    m = MetricsRegistry()
    h = m.histogram("lat")
    # empty: the deterministic zero, not an exception
    assert h.percentile(50) == 0.0 and h.percentile(99) == 0.0
    # single sample: every quantile is that sample
    h.observe(7.0)
    assert h.percentile(0) == h.percentile(50) == h.percentile(100) == 7.0
    # duplicate-heavy: interpolation between equal order statistics must
    # not drift off the plateau value
    h2 = m.histogram("dup")
    h2.extend([5.0] * 99 + [500.0])
    assert h2.percentile(50) == 5.0
    assert h2.percentile(95) == 5.0
    assert h2.percentile(100) == 500.0
    assert h2.count == 100 and h2.mean == pytest.approx(9.95)


def test_absorb_disjoint_and_overlapping_label_sets():
    parent = MetricsRegistry()
    child = MetricsRegistry()
    # disjoint labels: the child's device label survives beside the
    # parent's relabel
    child.counter("n", device="d0").add(2.0)
    # overlapping: the child already carries host=...; absorb's extra
    # label wins (the absorber owns the namespace it files children under)
    child.counter("n", host="stale", device="d1").add(5.0)
    parent.absorb(child, host="h0")
    assert parent.total("n", host="h0", device="d0") == 2.0
    assert parent.total("n", host="h0", device="d1") == 5.0
    assert parent.total("n", host="stale") == 0.0
    assert parent.total("n") == 7.0


def test_counter_totals_are_monotone_under_host_merge():
    """Folding host registries into a cluster registry must never lose or
    double-book counts: after each absorb the merged total equals the sum
    of everything absorbed so far (the conservation rule the cluster
    report's roll-up relies on)."""
    parent = MetricsRegistry()
    running = 0.0
    totals = []
    for i, add in enumerate([3.0, 4.0, 5.0]):
        child = MetricsRegistry()
        child.counter("sched.launches", device="d0").add(add)
        parent.absorb(child, host=f"h{i}")
        running += add
        totals.append(parent.total("sched.launches"))
        assert totals[-1] == running
    assert totals == sorted(totals)  # merge only ever grows a counter


def test_percentile_is_the_shared_implementation():
    # the cluster layer re-exports the obs implementation — one definition
    assert cluster_percentile is percentile
    vals = [5.0, 1.0, 9.0, 3.0]
    assert percentile(vals, 0) == 1.0
    assert percentile(vals, 100) == 9.0
    assert percentile(vals, 50) == 4.0  # linear interpolation between 3 and 5


def test_unified_geomean_definition():
    # both historical entry points resolve to core.stats.geomean
    from repro.core.evaluate import geomean as core_geomean
    from repro.core.stats import geomean
    from repro.sched import geomean as sched_geomean

    assert core_geomean is geomean and sched_geomean is geomean
    assert geomean([2.0, 8.0]) == pytest.approx(4.0)
    assert geomean([]) == 0.0 and geomean([1.0, 0.0]) == 0.0


# -------------------------------------------------- tracer / conservation


def _stream(seed_reqs):
    return [LaunchRequest(t, dims, extra, accel=accel, arrival_time=at)
            for t, dims, extra, accel, at in seed_reqs]


@st.composite
def obs_streams(draw):
    """Mixed-pool request streams in the style of test_engine's generator:
    random arrivals, tile sizes, and write-plan shapes."""
    reqs, t = [], 0.0
    for i in range(draw(st.integers(2, 16))):
        t += float(draw(st.integers(0, 150)))
        dims = tuple(8 * draw(st.integers(1, 5)) for _ in range(3))
        nfields = draw(st.integers(0, 32))
        extra = {f"p{j}": draw(st.integers(0, 3)) * 64 + j
                 for j in range(nfields)}
        accel = draw(st.sampled_from(["opengemm", "gemmini"]))
        reqs.append((f"t{draw(st.integers(0, 2))}", dims, extra, accel, t))
    return reqs


@settings(max_examples=25, deadline=None)
@given(obs_streams(), st.sampled_from(["csr", "noc", "pcie"]),
       st.sampled_from(["serialized", "overlapped"]))
def test_attribution_conserves_cycles_on_every_lane(seed_reqs, link, mode):
    """The hard invariant: per lane, components (idle included) sum to the
    makespan — no gap, no double-booking — under both overlap modes and
    every link class."""
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1},
                                link=link, overlap=mode)
    rep = s.run_open_loop(_stream(seed_reqs))
    att = attribute(rep).check(tolerance=1e-9)
    assert att.makespan == rep.makespan
    for lane in att.lanes.values():
        assert lane.residual <= max(1e-9 * lane.makespan, 1e-9)


@settings(max_examples=15, deadline=None)
@given(obs_streams(), st.sampled_from(["noc", "pcie"]),
       st.sampled_from(["serialized", "overlapped"]))
def test_attribution_reproduces_exposed_config_exactly(seed_reqs, link, mode):
    """attribution.exposed_config must equal the telemetry counter on
    preemption-free runs — same floats, same order, bit-exact."""
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1},
                                link=link, overlap=mode)
    rep = s.run_open_loop(_stream(seed_reqs))
    assert rep.preemptions == 0
    att = attribute(rep)
    assert att.exposed_config == rep.exposed_config_cycles
    if mode == "serialized":
        # a captive host exposes all of T_set: exposed == total config
        assert att.exposed_config == rep.config_cycles
        assert att.summary["overlapped_config"] == 0.0


def test_tracer_never_perturbs_timing():
    """A traced run is bit-identical to an untraced one — the property the
    golden-trace pin depends on."""
    reqs = [LaunchRequest(f"t{i % 2}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(12)},
                          arrival_time=30.0 * i) for i in range(8)]

    def run(tracer):
        s = Scheduler.from_registry({"opengemm": 1}, link="noc",
                                    overlap="overlapped", tracer=tracer)
        return s.run_open_loop(list(reqs))

    bare, traced = run(None), run(Tracer())
    assert bare.makespan == traced.makespan
    assert [r.end for r in bare.launch_log()] == \
           [r.end for r in traced.launch_log()]


def test_cluster_attribution_covers_shared_port():
    reqs = [LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(16)},
                          arrival_time=25.0 * i) for i in range(12)]
    cl = Cluster.uniform(2, {"opengemm": 1}, link="pcie",
                         overlap="overlapped", shared_port=True)
    rep = cl.run(list(reqs))
    att = attribute(rep).check(tolerance=1e-9)
    # the shared wire appears once, cluster-wide, not once per host
    shared = [name for name in att.lanes if name.endswith(":shared")]
    assert shared == ["cfg[pcie]:shared"]
    assert att.exposed_config == rep.exposed_config_cycles


def test_preempted_launches_stay_conserved():
    """A preemption leaves host/wire side effects that the attribution must
    still classify (preempted_config / preempted_transfer) — conservation
    holds through the rollback."""
    s = Scheduler.from_registry({"opengemm": 1}, link="noc", depth=1)
    reqs = [LaunchRequest("bulk", (40, 40, 40),
                          {f"p{j}": j for j in range(24)},
                          arrival_time=0.0),
            LaunchRequest("bulk2", (40, 40, 40),
                          {f"p{j}": 64 + j for j in range(24)},
                          arrival_time=1.0),
            LaunchRequest("vip", (8, 8, 8), {"p0": 1}, priority=5,
                          arrival_time=2.0)]
    rep = s.run_open_loop(reqs)
    att = attribute(rep).check()
    if rep.preemptions:
        assert sum(l.components.get("preempted_config", 0.0) +
                   l.components.get("preempted_transfer", 0.0)
                   for l in att.lanes.values()) >= 0.0


# ------------------------------------------------------------ golden trace


GOLDEN_REQS = [("a", 0.0), ("b", 10.0), ("a", 200.0), ("b", 260.0)]


def _golden_tracer():
    tr = Tracer()
    s = Scheduler.from_registry({"opengemm": 1}, link="noc",
                                overlap="overlapped", tracer=tr)
    reqs = [LaunchRequest(t, (16, 16, 16),
                          {f"p{j}": int(at) + j for j in range(8)},
                          arrival_time=at) for t, at in GOLDEN_REQS]
    rep = s.run_open_loop(reqs)
    return tr, rep


def test_golden_trace_schema_and_lanes():
    tr, rep = _golden_tracer()
    doc = chrome_trace(tr)
    assert validate_trace(doc) == []
    assert doc["displayTimeUnit"] == "ms"
    # pinned lane vocabulary: the three-resource model plus tenant lanes
    assert tr.lanes() == ["cfg[noc]", "host", "compute[opengemm:0]",
                          "tenant[a]", "tenant[b]"]
    # pinned span taxonomy on the host lane
    host_names = {s.name for s in tr.spans_on("host")}
    assert "config-issue" in host_names
    # every launch leaves exactly one compute span and one launch span
    assert len(tr.spans_on("compute[opengemm:0]")) == len(GOLDEN_REQS)
    launches = [s for s in tr.spans if s.cat == "launch"]
    assert len(launches) == len(GOLDEN_REQS)
    # spans never exceed the makespan and the first issue is pinned
    assert max(s.end for s in tr.spans) <= rep.makespan
    first = min(s.start for s in tr.spans_on("host"))
    assert first == 0.0
    # exported events: metadata first, then ts-ordered
    events = doc["traceEvents"]
    body = [e for e in events if e["ph"] != "M"]
    assert [e["ts"] for e in body] == sorted(e["ts"] for e in body)
    # config-done instants mark the config-complete edge on compute lanes
    assert sum(1 for i in tr.instants if i.name == "config-done") == \
           len(GOLDEN_REQS)


def test_golden_trace_is_deterministic():
    a, _ = _golden_tracer()
    b, _ = _golden_tracer()
    assert chrome_trace(a) == chrome_trace(b)


def test_write_trace_embeds_attribution_and_metrics(tmp_path):
    tr, rep = _golden_tracer()
    path = tmp_path / "trace.json"
    att = attribute(rep).check()
    doc = write_trace(tr, str(path), attribution=att, metrics=rep.metrics)
    loaded = json.loads(path.read_text())
    assert loaded == doc
    assert loaded["attribution"]["max_residual"] <= 1e-3
    assert loaded["attribution"]["exposed_config"] == \
           loaded["attribution"]["reported_exposed_config"]
    names = {row["name"] for row in loaded["metrics"]}
    assert "sched.exposed_config_cycles" in names


# ----------------------------------------------------- registry-backed views


def test_scheduler_report_views_are_registry_backed():
    s = Scheduler.from_registry({"opengemm": 1}, link="noc")
    reqs = [LaunchRequest("t0", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(8)},
                          arrival_time=20.0 * i) for i in range(5)]
    rep = s.run_open_loop(reqs)
    assert rep.metrics is s.metrics
    assert rep.exposed_config_cycles == \
           s.metrics.total("sched.exposed_config_cycles")
    assert rep.bytes_sent == int(s.metrics.total("sched.bytes_sent"))
    assert s.metrics.gauge("sched.makespan").value == rep.makespan


def test_cluster_report_folds_host_registries():
    reqs = [LaunchRequest(f"t{i % 3}", (16, 16, 16),
                          {f"p{j}": i * 64 + j for j in range(8)},
                          arrival_time=20.0 * i) for i in range(9)]
    cl = Cluster.uniform(2, {"opengemm": 1}, link="noc")
    rep = cl.run(list(reqs))
    m = rep.metrics
    assert m is not None
    # per-host series exist and sum to the cluster view
    per_host = sum(m.total("sched.bytes_sent", host=h) for h in rep.hosts)
    assert rep.bytes_sent == int(per_host)
    # tail histograms carry every launch
    assert len(m.samples("cluster.latency")) == len(rep.records)
    assert m.gauge("cluster.makespan").value == rep.makespan


# --------------------------------------------------------- closed-loop bridge


@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get
    from repro.models.model import Model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), remat="none")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return model, params, ServingEngine.compile_decode(model)


def test_bridge_trace_end_to_end(small_model, tmp_path):
    """The acceptance scenario: a closed-loop serving run exports a
    Perfetto-loadable trace whose host/wire/compute lanes satisfy the
    conservation invariant and reproduce exposed_config_cycles."""
    from repro.bridge import ClosedLoopDriver, TenantEngine
    from repro.serving import Request, ServingEngine

    model, params, decode_fn = small_model
    tenants = []
    for i in range(2):
        eng = ServingEngine(model, params, max_slots=4, max_len=64,
                            decode_fn=decode_fn)
        eng.submit(Request(uid=0, prompt=[3 + i, 5], max_new_tokens=3))
        tenants.append(TenantEngine(f"t{i}", eng, accel="opengemm",
                                    slo_cycles=2_000.0))
    tracer = Tracer()
    cluster = Cluster.uniform(1, {"opengemm": 1}, sticky=True, link="noc",
                              overlap="overlapped", tracer=tracer)
    rep = ClosedLoopDriver(tenants, cluster).run()

    att = attribute(rep).check(tolerance=1e-9)
    assert att.exposed_config == rep.cluster.exposed_config_cycles
    lanes = tracer.lanes()
    assert any(l.startswith("cfg[") for l in lanes)
    assert any(l.startswith("compute[") for l in lanes)
    assert "host" in lanes
    assert any(l.startswith("step[") for l in lanes)

    path = tmp_path / "bridge_trace.json"
    doc = write_trace(tracer, str(path), attribution=att,
                      metrics=rep.metrics)
    assert validate_trace(doc) == []
    # bridge.* series landed beside the sched.* ones in one registry
    assert rep.metrics.total("bridge.tokens") == rep.tokens
    assert rep.overlap_summary()["config_cycles"] == \
        pytest.approx(rep.metrics.total("bridge.config_cycles"))
