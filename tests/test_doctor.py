"""repro.obs diagnosis layer: the what-if replay's 15% validation pin
(the ISSUE acceptance bar — predictions vs actual re-simulated savings
for each recommendation class), regime classification, differential diff
lane matching, sliding-window monitor primitives, the ShedTrigger
regression pin after its SustainedThreshold refactor, and the doctor CLI."""

import json

import pytest

from repro.cluster import Cluster, ShedTrigger, TenantProfile, generate
from repro.fabric import MigrationPlanner
from repro.obs import (
    StreamMonitor,
    SustainedThreshold,
    Tracer,
    WindowSeries,
    attribute,
    classify,
    classify_cell,
    diagnose_report,
    feed_step,
    predict_burst,
    predict_overlap,
    predict_staging,
    write_trace,
)
from repro.obs import diff as obs_diff
from repro.obs.whatif import extract_rows, replay
from repro.sched import LaunchRequest, Scheduler

# ----------------------------------------------------------- what-if replay


def _stream(n=14, fields=24, mixed=True, gap=0.0):
    """Every field value changes every launch, so the cache elides nothing
    and burst-eligible write plans stay large."""
    return [
        LaunchRequest(f"t{i % 3}", (16, 16, 16),
                      {f"f{j}": 96 * i + j for j in range(fields)},
                      accel=("opengemm" if i % 2 else "gemmini") if mixed
                      else "opengemm",
                      arrival_time=gap * i)
        for i in range(n)
    ]


def _run(link, mode, **kwargs):
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1}, link=link,
                                overlap=mode, **kwargs)
    return s.run_open_loop(_stream())


@pytest.mark.parametrize("link", ["csr", "noc", "pcie"])
@pytest.mark.parametrize("mode", ["serialized", "overlapped"])
def test_replay_reproduces_the_engine_bit_exactly(link, mode):
    """The dispatch-recurrence replay is the estimator's foundation: over
    the recorded launch log it must land on the engine's own makespan and
    exposed-config split exactly, on every link class and overlap mode."""
    rep = _run(link, mode)
    r = replay(extract_rows(rep), mode=mode,
               buffers=rep.staging_buffers)
    assert r.makespan == rep.makespan
    assert r.exposed_config == pytest.approx(rep.exposed_config_cycles)
    assert r.config_cycles == pytest.approx(rep.config_cycles)


def _pin(whatif, actual_savings):
    """The acceptance bar: predicted within 15% of the re-simulated truth."""
    assert whatif is not None
    assert actual_savings > 0.0
    err = abs(whatif.predicted_savings - actual_savings) / actual_savings
    assert err <= 0.15, (whatif.predicted_savings, actual_savings, err)


@pytest.mark.parametrize("link", ["noc", "pcie"])
def test_predict_overlap_within_15pct_of_resimulation(link):
    ser = _run(link, "serialized")
    wi = predict_overlap(ser)
    ov = _run(link, "overlapped")
    _pin(wi, ser.makespan - ov.makespan)
    assert wi.action == "enable_overlap"
    assert wi.knob == {"overlap": "overlapped"}
    assert wi.predicted_speedup > 1.0


def test_predict_burst_within_15pct_of_resimulation():
    """Force per-register MMIO, ask the doctor what burst DMA would buy,
    then actually flip the transport knob (≥8-field plans throughout, so
    the estimator's crossover filter matches the forced re-run)."""
    mmio = _run("noc", "serialized", transport="mmio")
    wi = predict_burst(mmio)
    burst = _run("noc", "serialized", transport="burst")
    _pin(wi, mmio.makespan - burst.makespan)
    assert wi.knob == {"transport": "burst"}
    assert wi.detail["repriced_launches"] == len(extract_rows(mmio))


def test_predict_staging_within_15pct_of_resimulation():
    """One more configuration bank on a bank-starved overlapped run: a
    single concurrent device with one bank serializes each async transfer
    behind the *previous* compute's retirement — the regime a second bank
    pipelines away."""
    def run(buffers):
        s = Scheduler.from_registry({"opengemm": 1}, link="noc",
                                    overlap="overlapped",
                                    staging_buffers=buffers)
        return s.run_open_loop(_stream(n=12, fields=32, mixed=False))

    one = run(1)
    wi = predict_staging(one, buffers=2)
    _pin(wi, one.makespan - run(2).makespan)
    assert wi.knob == {"staging_buffers": 2}


def test_predictors_decline_when_the_knob_is_moot():
    ov = _run("noc", "overlapped")
    assert predict_overlap(ov) is None  # already overlapped
    csr = _run("csr", "serialized")
    assert predict_burst(csr) is None  # CSR port has no DMA engine
    assert predict_overlap(csr) is None  # nothing async-eligible
    assert predict_staging(_run("noc", "serialized")) is None  # serialized
    assert predict_staging(ov, buffers=2) is None  # already there


# ----------------------------------------------------------- classification


def test_classify_precedence():
    # arrival-limited wins even with visible config: knobs can't help an
    # underloaded system
    r = classify(makespan=100.0, exposed_config=20.0, config_cycles=20.0,
                 host_busy=30.0, wire_busy=10.0, compute_busy=40.0)
    assert r.label == "arrival_limited"
    # exposed share ≥ 10% → config-bound even under dominant compute
    r = classify(makespan=100.0, exposed_config=12.0, config_cycles=40.0,
                 host_busy=60.0, wire_busy=30.0, compute_busy=95.0)
    assert r.label == "config_bound"
    assert r.exposed_share == pytest.approx(0.12)
    assert r.exposed_fraction == pytest.approx(0.3)
    # hidden transfers saturating the link → wire-bound
    r = classify(makespan=100.0, exposed_config=2.0, config_cycles=80.0,
                 host_busy=20.0, wire_busy=80.0, compute_busy=60.0)
    assert r.label == "wire_bound"
    r = classify(makespan=100.0, exposed_config=2.0, config_cycles=10.0,
                 host_busy=20.0, wire_busy=30.0, compute_busy=90.0)
    assert r.label == "compute_bound"


def test_classify_cell_matches_bench_schema():
    cell = {"makespan": 1000.0, "exposed_config_cycles": 400.0,
            "config_cycles": 500.0, "host_busy": 600.0, "wire_busy": 300.0,
            "compute_busy": 550.0}
    assert classify_cell(cell).label == "config_bound"


def test_diagnose_live_serialized_run_is_config_bound_with_ranked_recs():
    rep = _run("pcie", "serialized")
    diag = diagnose_report(rep)
    assert diag.regime.label == "config_bound"
    actions = [r.action for r in diag.recommendations]
    assert "enable_overlap" in actions
    savings = [r.predicted_savings or 0.0 for r in diag.recommendations]
    assert savings == sorted(savings, reverse=True)
    top = diag.recommendations[0]
    assert top.whatif is not None and top.predicted_savings > 0.0
    text = diag.render()
    assert "CONFIG-BOUND" in text and "enable_overlap" in text


# ----------------------------------------------------------------- diff


def _att_dict(rep):
    return attribute(rep).check().to_dict()


def test_diff_decomposes_the_overlap_win():
    ser = _att_dict(_run("noc", "serialized"))
    ov = _att_dict(_run("noc", "overlapped"))
    d = obs_diff.diff(ser, ov)
    assert d["makespan"]["delta"] == pytest.approx(
        ov["makespan"] - ser["makespan"])
    assert d["makespan"]["delta"] < 0.0  # overlap won
    assert all(l["status"] == "matched" for l in d["lanes"].values())
    deltas = [abs(r["delta"]) for r in d["ranked"]]
    assert deltas == sorted(deltas, reverse=True) and deltas
    assert "(no component moved)" not in obs_diff.render(d)


def test_diff_matches_renamed_and_orphan_lanes():
    base = {"makespan": 100.0, "exposed_config": 10.0,
            "summary": {"compute": 50.0},
            "lanes": {
                "cfg[noc]": {"kind": "wire",
                             "components": {"exposed_transfer": 10.0}},
                "compute[d0]": {"kind": "compute",
                                "components": {"busy": 50.0}},
            }}
    other = {"makespan": 110.0, "exposed_config": 12.0,
             "summary": {"compute": 55.0},
             "lanes": {
                 "cfg[noc2]": {"kind": "wire",
                               "components": {"exposed_transfer": 12.0}},
                 "compute[d0]": {"kind": "compute",
                                 "components": {"busy": 40.0}},
                 "compute[d1]": {"kind": "compute",
                                 "components": {"busy": 15.0}},
             }}
    d = obs_diff.diff(base, other)
    # the lone wire lanes pair up across the rename
    wire = d["lanes"]["cfg[noc2]"]
    assert wire["status"] == "renamed" and wire["base_lane"] == "cfg[noc]"
    assert wire["components"]["exposed_transfer"]["delta"] == 2.0
    # compute[d1] exists only on the other side
    assert d["lanes"]["compute[d1]"]["status"] == "added"
    assert d["lanes"]["compute[d1]"]["components"]["busy"]["base"] == 0.0


def test_diff_reads_trace_documents_and_metric_deltas():
    doc = {"attribution": {"makespan": 10.0, "exposed_config": 1.0,
                           "summary": {}, "lanes": {}},
           "metrics": [{"name": "n", "kind": "counter",
                        "labels": {"host": "h0"}, "value": 3.0}]}
    doc2 = json.loads(json.dumps(doc))
    doc2["metrics"][0]["value"] = 5.0
    d = obs_diff.diff(doc, doc2)
    (key, row), = d["metrics"].items()
    assert key == "n{host=h0}" and row["delta"] == 2.0


# ----------------------------------------------------------------- monitor


def test_window_series_trims_and_rates():
    s = WindowSeries(window=10.0)
    s.observe(0.0, 5.0)
    s.observe(4.0, 3.0)
    s.observe(10.0, 2.0)
    assert s.sum(now=10.0) == 5.0  # t=0 is at the edge and drops
    assert s.mean(now=10.0) == 2.5
    assert s.rate(now=10.0) == pytest.approx(0.5)  # 5 over a 10-cycle window
    assert s.count(now=14.0) == 1 and s.last() == 2.0
    assert s.count(now=20.5) == 0  # fully aged out
    s2 = WindowSeries(window=10.0)
    s2.observe(5.0, 1.0)
    with pytest.raises(AssertionError):
        s2.observe(4.0, 1.0)  # time must not run backwards


def test_sustained_threshold_debounce_ack_and_edge_hook():
    fired = []
    t = SustainedThreshold(sustain=2, on_alert=lambda k, s: fired.append(k))
    assert not t.update("h0", True)
    assert t.update("h0", True) and fired == ["h0"]
    assert t.update("h0", True) and fired == ["h0"]  # edge fires once
    t.reset("h0")  # acknowledged: must re-sustain
    assert not t.update("h0", True)
    assert t.update("h0", True) and fired == ["h0", "h0"]
    assert not t.update("h0", False)  # condition break zeroes the streak
    assert not t.update("h0", True)


def test_stream_monitor_serving_signals_and_alerts():
    m = StreamMonitor(window=1_000.0)
    for i in range(10):
        feed_step(m, tenant="t0", completion=100.0 * (i + 1), tokens=4,
                  latency=900.0 if i >= 5 else 100.0, config_cycles=50.0,
                  exposed_config=20.0, slo_cycles=500.0)
    now = 1_000.0
    assert m.exposed_config_ratio(now, tenant="t0") == pytest.approx(0.4)
    assert m.token_rate(now, tenant="t0") == pytest.approx(40.0)  # tok/kcyc
    assert m.slo_burn_rate(now, tenant="t0") == pytest.approx(0.5)
    a = m.alert("bridge.slo_miss", threshold=0.4, sustain=2, tenant="t0")
    assert m.check_alerts(now) == []  # one hot epoch: debounced
    assert m.check_alerts(now) == [a]  # sustained: fired


def test_shed_decisions_unchanged_after_monitor_refactor():
    """Regression pin for the SustainedThreshold refactor: on the PR 5
    bursty two-host scenario (everything landing on h0 of an affinity
    cluster), the trigger must make exactly the decisions the bespoke
    streak counters made — same victims, destinations, epochs, and wait
    numbers."""
    profiles = [
        TenantProfile("tight", dims=(16, 16, 16), accel="opengemm",
                      weight=1.0),
        TenantProfile("loose", dims=(16, 16, 16), accel="opengemm",
                      weight=2.0),
    ]
    reqs = generate(profiles, rate=1 / 8, horizon=40_000, process="bursty",
                    seed=5)
    reqs = sorted(reqs, key=lambda r: r.arrival_time)[:400]
    cluster = Cluster.uniform(2, {"opengemm": 1}, policy="affinity",
                              link="noc")
    monitor = StreamMonitor(window=5_000.0)
    trig = ShedTrigger(MigrationPlanner(link="noc"), k=1.5, sustain=2,
                       monitor=monitor)
    for i, req in enumerate(reqs):
        cluster.hosts[0].dispatch(req)
        if (i + 1) % 50 == 0:
            trig.observe(cluster.hosts, now=req.arrival_time)
    got = [(d.tenant, d.src, d.dst, round(d.now, 1), round(d.src_wait, 1),
            round(d.median_wait, 1)) for d in trig.decisions]
    assert got == [
        ("loose", "h0", "h1", 1513.2, 2715.1, 1357.5),
        ("loose", "h0", "h1", 2116.2, 6318.1, 3159.0),
        ("loose", "h0", "h1", 3097.3, 9543.0, 4771.5),
        ("loose", "h0", "h1", 3818.1, 13028.1, 6514.1),
    ]
    # the monitor saw the identical pressure signal the trigger acted on
    series = monitor.series("cluster.port_wait", host="h0")
    assert len(series) == 1 and series[0].last() is not None


# ------------------------------------------------------------- doctor CLI


def _export(tmp_path, name, link, mode):
    tracer = Tracer()
    s = Scheduler.from_registry({"opengemm": 1, "gemmini": 1}, link=link,
                                overlap=mode, tracer=tracer)
    rep = s.run_open_loop(_stream())
    path = tmp_path / name
    write_trace(tracer, str(path), attribution=attribute(rep).check(),
                metrics=rep.metrics)
    return path


def test_doctor_cli_diagnoses_and_diffs(tmp_path, capsys):
    from repro.obs.doctor import main

    ser = _export(tmp_path, "ser.json", "pcie", "serialized")
    ov = _export(tmp_path, "ov.json", "pcie", "overlapped")
    out = tmp_path / "doctor.json"
    assert main([str(ser), "--against", str(ov), "--json", str(out)]) == 0
    shown = capsys.readouterr().out
    assert "config-wall doctor" in shown and "trace diff" in shown
    payload = json.loads(out.read_text())
    assert payload["diagnosis"]["regime"]["label"] == "config_bound"
    # the serialized run reads *slower* than the overlapped baseline
    assert payload["diff"]["makespan"]["delta"] > 0.0
    recs = payload["diagnosis"]["recommendations"]
    assert any(r["action"] == "enable_overlap" and r["bound"] for r in recs)


def test_doctor_cli_rejects_attribution_free_documents(tmp_path):
    from repro.obs.doctor import load_trace

    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps({"traceEvents": []}))
    with pytest.raises(AssertionError):
        load_trace(str(bare))
