"""Substrate tests: data determinism/prefetch, checkpoint roundtrip +
corruption detection, fault-tolerant supervisor, straggler monitor, dispatch
planner + executors."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.dispatch import ConcurrentExecutor, ConfigPlan, SequentialExecutor, StepDescriptor
from repro.runtime import StragglerMonitor, TrainSupervisor


# ------------------------------------------------------------------- data


def test_data_deterministic_across_hosts():
    ds = SyntheticLMDataset(vocab_size=100, seq_len=8, batch_size=4, seed=7)
    a = ds.batch(step=3, shard=1, n_shards=4)
    b = ds.batch(step=3, shard=1, n_shards=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(step=3, shard=2, n_shards=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    full = ds.batch(step=0)
    assert full["tokens"].shape == (4, 8)


def test_prefetch_iterator_order_and_close():
    it = make_train_iterator(100, 8, 2, prefetch=3)
    steps = [next(it)[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]
    it.close()


# --------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    store.save(10, tree)
    assert store.latest_step() == 10
    out = store.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((8,))}
    for s in (1, 2, 3, 4):
        store.save(s, tree, blocking=False)
        store.wait()
    assert store.steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"x": jnp.arange(16)}
    store.save(1, tree)
    # flip bytes in the array file
    d = os.path.join(str(tmp_path), "step_1")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, fn), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError, match="CRC"):
        store.restore(1, tree)


# ------------------------------------------------------------------ runtime


def test_supervisor_restarts_from_checkpoint(tmp_path):
    store = CheckpointStore(str(tmp_path))

    @jax.jit
    def step_fn(state, batch):
        return state + batch

    failures = {"armed": True}

    def fault_hook(step):
        if step == 7 and failures["armed"]:
            failures["armed"] = False
            raise RuntimeError("simulated node failure")

    sup = TrainSupervisor(step_fn, store, ckpt_every=3)
    out = sup.run(
        jnp.zeros(()), lambda s: jnp.ones(()), 10, fault_hook=fault_hook
    )
    assert sup.restarts == 1
    assert float(out) == 10.0  # replay is exact (deterministic data)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0)
    for i in range(10):
        mon.observe(i, 0.01)
    assert not mon.flagged
    mon.observe(10, 0.5)
    assert len(mon.flagged) == 1 and mon.flagged[0][0] == 10


def test_elastic_reshard_single_device():
    state = {"w": jnp.ones((8, 8))}
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = TrainSupervisor.reshard(state, {"w": sh})
    assert out["w"].sharding == sh


# ----------------------------------------------------------------- dispatch


def test_config_plan_static_dynamic_split():
    descs = [
        StepDescriptor({"lr": 1e-3, "pos": i, "table": np.arange(4)})
        for i in range(5)
    ]
    plan = ConfigPlan.trace(descs)
    assert set(plan.static) == {"lr", "table"}
    assert plan.dynamic == ["pos"]
    # dedup shrinks per-launch config bytes: I_OC rises (§4.2)
    assert plan.bytes_deduped(descs[0]) < plan.bytes_baseline(descs[0])
    assert plan.i_oc_gain(descs[0]) > 2.0


def test_executors_equivalent_results():
    @jax.jit
    def device_fn(state, args):
        return state + args["x"]

    def host_prep(step):
        return {"x": jnp.float32(step)}

    seq, r1 = SequentialExecutor(device_fn, host_prep).run(jnp.float32(0), 20)
    conc, r2 = ConcurrentExecutor(device_fn, host_prep, depth=4).run(jnp.float32(0), 20)
    assert float(seq) == float(conc)
    assert r1.steps == r2.steps == 20


def test_concurrent_executor_overlaps_host_prep():
    """With host prep comparable to device time, the concurrent executor must
    be measurably faster — the paper's §5.5 overlap on a real runtime."""
    n = 512

    @jax.jit
    def device_fn(state, args):
        x = state
        for _ in range(2):
            x = jnp.tanh(x @ state) + args["x"]
        return x / jnp.linalg.norm(x)

    def host_prep(step):
        # blocking descriptor marshalling (T_calc); sleep (not spin) so the
        # single-core container can actually overlap host wait with the CPU
        # device thread — on real hardware the device runs regardless
        time.sleep(0.004)
        return {"x": jnp.float32(step)}

    state = jnp.eye(n) + 0.01
    device_fn(state, host_prep(0)).block_until_ready()  # compile warmup

    _, seq = SequentialExecutor(device_fn, host_prep).run(state, 15)
    _, conc = ConcurrentExecutor(device_fn, host_prep, depth=2).run(state, 15)
    # host prep (~4 ms/step) must mostly disappear behind device time
    assert conc.wall_s < seq.wall_s * 0.9, (seq.wall_s, conc.wall_s)
